"""jit'd public wrappers around the Pallas kernels.

``summarize_pallas`` is the full TPU Summarizer pipeline: bitonic-sort VMEM
tiles → per-tile exact histograms → merge (optionally via the fused merge
kernel).  On CPU the kernels run under ``interpret=True`` (Python-level
execution of the kernel body); on TPU set ``interpret=False``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.histogram import Histogram, merge
from repro.kernels.bucket_count import cumulative_counts_pallas
from repro.kernels.merge_cut import merge_pallas
from repro.kernels.ref import bucket_sizes_from_cumulative
from repro.kernels.tile_sort import pad_to_tiles, sort_tiles_pallas

__all__ = [
    "bucket_sizes_pallas",
    "summarize_pallas",
    "merge_histograms_pallas",
]


@functools.partial(jax.jit, static_argnames=("interpret", "block_rows"))
def bucket_sizes_pallas(
    x: jax.Array,
    boundaries: jax.Array,
    *,
    block_rows: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """True per-bucket counts of ``x`` under ``boundaries`` (validation op)."""
    cum = cumulative_counts_pallas(
        x, boundaries, block_rows=block_rows, interpret=interpret
    )
    return bucket_sizes_from_cumulative(cum)


def _tile_histograms(
    sorted_tiles: jax.Array, T: int, n: int | None = None
) -> Histogram:
    """Exact T-bucket histograms of each (already sorted) tile row.

    ``n`` is the total number of *real* values when the last tile carries a
    sentinel-padded ragged tail (``pad_to_tiles``): that tile's cut indices
    are computed from its true prefix length, so the padding never enters a
    boundary or a bucket count.  Cut indices are static (host-side integer
    arithmetic — exact floors, no float rounding).
    """
    tiles, tile_len = sorted_tiles.shape
    if n is None:
        n = tiles * tile_len
    n_i = np.minimum(
        tile_len, n - np.arange(tiles, dtype=np.int64) * tile_len
    )  # true values per tile; only the last can be short, never 0
    i = np.arange(T + 1, dtype=np.int64)
    cuts = (i[None, :] * n_i[:, None]) // T  # (tiles, T+1), exact floor
    idx = np.minimum(cuts, n_i[:, None] - 1).astype(np.int32)
    boundaries = jnp.take_along_axis(sorted_tiles, jnp.asarray(idx), axis=1)
    sizes = jnp.asarray(np.diff(cuts, axis=1).astype(np.float32))
    return Histogram(boundaries=boundaries, sizes=sizes)


@functools.partial(
    jax.jit, static_argnames=("tile_len", "T_tile", "T_out", "interpret", "fused_merge")
)
def summarize_pallas(
    x: jax.Array,
    *,
    tile_len: int = 4096,
    T_tile: int = 256,
    T_out: int = 1024,
    interpret: bool = True,
    fused_merge: bool = True,
) -> Histogram:
    """TPU Summarizer: tile-sort kernel + paper-merge of the tile summaries.

    Error vs. a fully exact histogram is bounded by the hierarchy composition
    (DESIGN.md §5): ``< 2n/T_tile`` from the tile level (the T_out-level
    output is itself a merge product; the Theorem-1 bound holds for unequal
    tile sizes, so a ragged last tile does not loosen it).  Ragged input
    lengths are handled by sentinel-padding the tail tile and masking its
    cut indices — no multiple-of-``tile_len`` requirement.
    """
    flat = x.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    assert n >= 1, "cannot summarize an empty array"
    flat = pad_to_tiles(flat, tile_len)
    xt = flat.reshape(flat.shape[0] // tile_len, tile_len)
    sorted_tiles = sort_tiles_pallas(xt, interpret=interpret)
    tiles_h = _tile_histograms(sorted_tiles, T_tile, n)
    if fused_merge:
        b, s = merge_pallas(
            tiles_h.boundaries, tiles_h.sizes, T_out, interpret=interpret
        )
        return Histogram(boundaries=b, sizes=s)
    return merge(tiles_h, T_out)


@functools.partial(jax.jit, static_argnames=("beta", "interpret"))
def merge_histograms_pallas(
    stacked: Histogram, beta: int, *, interpret: bool = True
) -> Histogram:
    """Fused Merger kernel over stacked summaries (k, T+1)/(k, T)."""
    b, s = merge_pallas(
        stacked.boundaries, stacked.sizes, beta, interpret=interpret
    )
    return Histogram(boundaries=b, sizes=s)
