"""Pallas TPU kernel: streaming bucket counting against fixed boundaries.

The validation/query hot spot of the histogram framework: given a boundary
sequence ``b_0..b_T`` and a large value stream, count how many values fall in
every bucket.  Used by (a) the exactness checker (μ_s measurement needs true
bucket sizes under approximate boundaries), (b) range-count queries, and
(c) quantization calibration.

TPU adaptation (vs. the scalar binary-search a CPU implementation would use):
no data-dependent control flow and no scatter.  Each grid step stages one
``(block_rows, 128)`` tile of the stream into VMEM and compares it against
the full boundary vector (also VMEM-resident, ``T ≤ 2048`` boundaries ⇒
≤8 KiB) with one broadcast ``(tile, T+1)`` less-than, reduced over the tile —
a pure VPU workload with arithmetic intensity ``T`` ops/byte, far above the
roofline knee for ``T ≥ 64``.  The per-bucket counts are the first
difference of the cumulative counts, taken by the wrapper.

Grid steps on TPU execute sequentially per core, so the kernel accumulates
partial counts into the output block across steps (the standard revisited-
output reduction pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["bucket_count_kernel", "cumulative_counts_pallas"]

LANE = 128  # TPU vector lane width; last dim of every VMEM tile


def bucket_count_kernel(x_ref, b_ref, out_ref):
    """One grid step: fold one VMEM tile of values into cumulative counts.

    out[: T+1] — # of values  < b_j   (cumulative counts)
    out[T+1]   — # of values == b_T   (paper: last bucket is right-closed)
    """
    i = pl.program_id(0)
    x = x_ref[...].reshape(-1, 1)  # (tile, 1)
    b = b_ref[...].reshape(1, -1)  # (1, T+1)
    lt = (x < b).astype(jnp.float32)
    partial_cum = jnp.sum(lt, axis=0)  # (T+1,)
    eq_last = jnp.sum((x[:, 0] == b[0, -1]).astype(jnp.float32))
    partial = jnp.concatenate([partial_cum, eq_last[None]])

    @pl.when(i == 0)
    def _init():
        out_ref[...] = partial.reshape(out_ref.shape)

    @pl.when(i > 0)
    def _acc():
        out_ref[...] = out_ref[...] + partial.reshape(out_ref.shape)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "interpret")
)
def cumulative_counts_pallas(
    x: jax.Array,
    boundaries: jax.Array,
    *,
    block_rows: int = 64,
    interpret: bool = True,
) -> jax.Array:
    """Cumulative ``< b_j`` counts of ``x`` (any shape) + ``== b_T`` count.

    Returns shape ``(T+2,)`` float32.  ``x`` is padded to a whole number of
    ``(block_rows, 128)`` tiles with ``+inf`` (never counted: strictly above
    every boundary and ``!= b_T``).
    """
    flat = x.reshape(-1).astype(jnp.float32)
    tile = block_rows * LANE
    n = flat.shape[0]
    n_pad = (-n) % tile
    flat = jnp.pad(flat, (0, n_pad), constant_values=jnp.inf)
    blocks = flat.shape[0] // tile
    xt = flat.reshape(blocks, block_rows, LANE)
    b = boundaries.astype(jnp.float32)
    T1 = b.shape[0]

    out = pl.pallas_call(
        bucket_count_kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((1, block_rows, LANE), lambda i: (i, 0, 0)),
            pl.BlockSpec((T1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((T1 + 1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((T1 + 1,), jnp.float32),
        interpret=interpret,
    )(xt, b)
    return out
