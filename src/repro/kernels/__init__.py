"""Pallas TPU kernels for the histogram framework's compute hot spots.

tile_sort     — bitonic sorting network over VMEM tiles (the Summarizer sort)
bucket_count  — streaming boundary-comparison bucket counting (validation/query)
merge_cut     — fused Algorithm-1 merge: kv-sort + prefix-sum + rank-select

Validated on CPU with ``interpret=True`` against the ``ref.py`` oracles;
``interpret=False`` on real TPUs.
"""
from repro.kernels.bucket_count import cumulative_counts_pallas
from repro.kernels.merge_cut import merge_pallas
from repro.kernels.ops import (
    bucket_sizes_pallas,
    merge_histograms_pallas,
    summarize_pallas,
)
from repro.kernels.tile_sort import sort_kv_pallas, sort_tiles_pallas
from repro.kernels import ref

__all__ = [
    "cumulative_counts_pallas",
    "merge_pallas",
    "bucket_sizes_pallas",
    "merge_histograms_pallas",
    "summarize_pallas",
    "sort_kv_pallas",
    "sort_tiles_pallas",
    "ref",
]
