"""Pallas TPU kernel: fused histogram merge (paper Algorithm 1, one shot).

Fuses the whole Merger into a single VMEM-resident kernel:

    sort boundaries (bitonic, key=boundary value, payload=bucket mass)
  → left-collapse cumulative sizes A  (Hillis–Steele log-depth prefix sum —
    shift+add vector ops, no serial scan)
  → cut selection: cut_j = Σ 1[A ≤ j·N/β]  (broadcast compare + row reduce,
    the batched form of `searchsorted(A, t, 'right')`)
  → boundary/prefix gather at the cuts as one-hot matmuls (MXU work, no
    dynamic gather).

Input is the flat concatenation of ``k`` summaries padded to a power of two
with ``+inf`` boundaries / zero mass; the pad sorts to the tail and carries
no mass, so A and the cuts are unaffected.  The last *real* boundary (the
global max) is selected with a one-hot at index ``L_real - 1``.

Everything is ``O(L log² L)`` vector work on a problem of size
``L = k(T+1)`` ≤ a few hundred KiB — one VMEM residence, zero HBM round
trips between the stages the unfused JAX path would take.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tile_sort import _bitonic_kv

__all__ = ["merge_cut_kernel", "merge_pallas"]


def _prefix_sum(x: jax.Array) -> jax.Array:
    """Hillis–Steele inclusive prefix sum: log2(n) shift+add stages."""
    n = x.shape[0]
    d = 1
    while d < n:
        shifted = jnp.pad(x, (d, 0))[:n]
        x = x + shifted
        d *= 2
    return x


def merge_cut_kernel(b_ref, m_ref, t_ref, last_ref, bo_ref, so_ref):
    pos = b_ref[...].reshape(-1)  # (L,) padded boundaries
    mass = m_ref[...].reshape(-1)  # (L,) aligned masses (0 for pads)
    targets = t_ref[...].reshape(-1)  # (β-1,) = j·N/β
    L = pos.shape[0]

    pos, mass = _bitonic_kv(pos, mass)
    cum = _prefix_sum(mass)  # (L,)  cum[i] = CDF at pos[i]
    # A[m] = A(m+1, H⁰) = cum[m]; valid for m in [0, L-2] (length L-1).
    # cut_j = #{m : A[m] <= t_j}  over the valid range.
    idx = jax.lax.iota(jnp.int32, L)
    a_valid = (idx < L - 1)
    le = (cum[None, :] <= targets[:, None]) & a_valid[None, :]
    cut = jnp.sum(le.astype(jnp.int32), axis=1)  # (β-1,) in [0, L-1]

    # interior boundaries: pos[cut]  (one-hot @ pos — MXU, no gather).
    # The +inf pads must be masked first: one-hot zeros times inf give NaN.
    pos_finite = jnp.where(jnp.isfinite(pos), pos, jnp.float32(0))
    onehot_cut = (idx[None, :] == cut[:, None]).astype(pos.dtype)
    interior = onehot_cut @ pos_finite
    # prefix size at the cut: cum[cut-1], 0 when cut == 0
    onehot_prev = (idx[None, :] == (cut[:, None] - 1)).astype(pos.dtype)
    s_at_cut = onehot_prev @ cum

    n_total = cum[L - 1]
    last_idx = last_ref[0] - 1  # L_real - 1: the global max boundary
    onehot_last = (idx == last_idx).astype(pos.dtype)
    b_last = jnp.sum(onehot_last * pos_finite)

    beta = so_ref.shape[-1]
    full = jnp.concatenate(
        [jnp.zeros((1,), cum.dtype), s_at_cut, n_total[None]]
    )
    bo = jnp.concatenate([pos[:1], interior, b_last[None]])
    bo_ref[...] = bo.reshape(bo_ref.shape)
    so_ref[...] = (full[1:] - full[:-1]).reshape(so_ref.shape)
    del beta


@functools.partial(jax.jit, static_argnames=("beta", "interpret"))
def merge_pallas(
    boundaries: jax.Array,
    sizes: jax.Array,
    beta: int,
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Merge stacked summaries ``boundaries (k, T+1)``, ``sizes (k, T)``.

    Returns ``(merged_boundaries (β+1,), merged_sizes (β,))`` — the fused
    equivalent of :func:`repro.core.histogram.merge`.
    """
    k, T1 = boundaries.shape
    if beta == 1:  # degenerate: one bucket spanning [min, max] — no cuts
        b = boundaries.astype(jnp.float32)
        return (
            jnp.stack([jnp.min(b), jnp.max(b)]),
            jnp.sum(sizes.astype(jnp.float32))[None],
        )
    mass = jnp.concatenate(
        [sizes.astype(jnp.float32), jnp.zeros((k, 1), jnp.float32)], axis=-1
    ).reshape(-1)
    flat = boundaries.astype(jnp.float32).reshape(-1)
    L_real = flat.shape[0]
    L = 1 << (L_real - 1).bit_length()  # next power of two
    flat = jnp.pad(flat, (0, L - L_real), constant_values=jnp.inf)
    mass = jnp.pad(mass, (0, L - L_real))
    n = jnp.sum(mass)
    targets = jnp.arange(1, beta, dtype=jnp.float32) * (n / beta)
    last = jnp.asarray([L_real], dtype=jnp.int32)

    bo, so = pl.pallas_call(
        merge_cut_kernel,
        in_specs=[
            pl.BlockSpec(flat.shape, lambda: tuple(0 for _ in flat.shape)),
            pl.BlockSpec(mass.shape, lambda: (0,)),
            pl.BlockSpec(targets.shape, lambda: (0,)),
            pl.BlockSpec((1,), lambda: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((beta + 1,), lambda: (0,)),
            pl.BlockSpec((beta,), lambda: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((beta + 1,), jnp.float32),
            jax.ShapeDtypeStruct((beta,), jnp.float32),
        ],
        interpret=interpret,
    )(flat, mass, targets, last)
    return bo, so
