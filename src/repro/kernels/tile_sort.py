"""Pallas TPU kernel: bitonic sorting network over VMEM tiles.

The Summarizer's cost is the partition sort.  A global HBM-resident sort is
the wrong algorithm on TPU (no efficient scatter, expensive data-dependent
movement); instead we sort *tiles that fit VMEM* with a bitonic network —
``log²`` compare-exchange stages of pure vector min/max/select, zero
data-dependent control flow, perfectly pipelineable — and let the *paper's
own merge theorem* combine per-tile exact histograms into the device summary
(kernels/ops.py::summarize_pallas).  This is the paper's insight recursed
one level down the memory hierarchy: HDFS partition → HBM shard → VMEM tile.

The compare-exchange partner ``i ^ j`` is realized as a reshape + reverse of
the trailing block pair — a relayout Mosaic handles — rather than a gather.

Key-value variant (``tile_sort_kv_kernel``) carries a payload through the
network (used by the fused merge kernel to keep bucket masses aligned with
their boundaries).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "tile_sort_kernel",
    "tile_sort_kv_kernel",
    "sort_tiles_pallas",
    "sort_kv_pallas",
    "pad_to_tiles",
]

LANE = 128


def pad_to_tiles(flat: jax.Array, tile_len: int) -> jax.Array:
    """Pad a 1-D array up to a whole number of tiles with a +inf sentinel.

    The sentinel (dtype max for integers) sorts past every real value, so a
    ragged tail becomes one partially-real tile whose true prefix length the
    caller masks out (kernels/ops.py) — the same padding contract as the
    shape-stable ``build_exact_padded`` (core/histogram.py).  The pad amount
    is static (derived from ``flat.shape``), so this composes with jit.
    """
    n = flat.shape[0]
    rem = (-n) % tile_len
    if rem == 0:
        return flat
    if jnp.issubdtype(flat.dtype, jnp.floating):
        fill = jnp.array(jnp.inf, flat.dtype)
    else:
        fill = jnp.array(jnp.iinfo(flat.dtype).max, flat.dtype)
    return jnp.concatenate([flat, jnp.full((rem,), fill, flat.dtype)])


def _bitonic(x: jax.Array) -> jax.Array:
    """Full ascending bitonic network on a power-of-two 1-D array."""
    n = x.shape[0]
    assert n & (n - 1) == 0, "bitonic network needs power-of-two length"
    idx = jax.lax.iota(jnp.int32, n)
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            # partner value at index i^j via reshape+reverse (no gather)
            xp = x.reshape(-1, 2, j)[:, ::-1, :].reshape(n)
            up = (idx & k) == 0  # ascending region of this stage
            lower = (idx & j) == 0  # i < partner
            take_min = lower == up
            x = jnp.where(take_min, jnp.minimum(x, xp), jnp.maximum(x, xp))
            j //= 2
        k *= 2
    return x


def _bitonic_kv(key: jax.Array, val: jax.Array) -> tuple[jax.Array, jax.Array]:
    """STABLE bitonic network: sorts ``key`` carrying ``val`` alongside.

    Stability matters for bit-parity with the reference merge: at tied
    boundary values the left-collapse cumulative masses within the tie
    group depend on visit order, and a rank-select cut landing inside the
    group would otherwise report (bound-compliant but) different bucket
    sizes than the stable-argsort reference.  The network therefore sorts
    the lexicographic pair (key, original_index) — a total order, so the
    result is exactly ``jnp.argsort(key, stable=True)`` applied to both
    arrays.
    """
    n = key.shape[0]
    assert n & (n - 1) == 0
    pos = jax.lax.iota(jnp.int32, n)
    tag = jax.lax.iota(jnp.int32, n)  # original index, travels with element
    k = 2
    while k <= n:
        j = k // 2
        while j >= 1:
            kp = key.reshape(-1, 2, j)[:, ::-1, :].reshape(n)
            vp = val.reshape(-1, 2, j)[:, ::-1, :].reshape(n)
            tp = tag.reshape(-1, 2, j)[:, ::-1, :].reshape(n)
            up = (pos & k) == 0
            lower = (pos & j) == 0
            take_min = lower == up
            # lexicographic (key, tag) comparison; min-role keeps on <=,
            # max-role on >= — (key, tag) pairs are unique so exactly one
            # side exchanges and no payload is duplicated or dropped.
            ties = key == kp
            lex_le = (key < kp) | (ties & (tag <= tp))
            lex_ge = (key > kp) | (ties & (tag >= tp))
            keep = jnp.where(take_min, lex_le, lex_ge)
            key = jnp.where(keep, key, kp)
            val = jnp.where(keep, val, vp)
            tag = jnp.where(keep, tag, tp)
            j //= 2
        k *= 2
    return key, val


def tile_sort_kernel(x_ref, o_ref):
    """Sort one VMEM tile ascending (tile = whole block, flattened)."""
    x = x_ref[...].reshape(-1)
    o_ref[...] = _bitonic(x).reshape(o_ref.shape)


def tile_sort_kv_kernel(k_ref, v_ref, ko_ref, vo_ref):
    k, v = _bitonic_kv(k_ref[...].reshape(-1), v_ref[...].reshape(-1))
    ko_ref[...] = k.reshape(ko_ref.shape)
    vo_ref[...] = v.reshape(vo_ref.shape)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_tiles_pallas(xt: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Sort each row of ``(tiles, tile_len)`` independently.

    ``tile_len`` must be a power of two and a multiple of 128 (one VMEM tile
    of shape ``(tile_len/128, 128)`` per grid step).
    """
    tiles, tile_len = xt.shape
    assert tile_len % LANE == 0 and tile_len & (tile_len - 1) == 0
    rows = tile_len // LANE
    xr = xt.reshape(tiles, rows, LANE)
    out = pl.pallas_call(
        tile_sort_kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((1, rows, LANE), lambda i: (i, 0, 0))],
        out_specs=pl.BlockSpec((1, rows, LANE), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((tiles, rows, LANE), xt.dtype),
        interpret=interpret,
    )(xr)
    return out.reshape(tiles, tile_len)


@functools.partial(jax.jit, static_argnames=("interpret",))
def sort_kv_pallas(
    keys: jax.Array, vals: jax.Array, *, interpret: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Row-wise key-value sort of ``(tiles, tile_len)`` pairs."""
    tiles, tile_len = keys.shape
    assert tile_len % LANE == 0 and tile_len & (tile_len - 1) == 0
    rows = tile_len // LANE
    kr = keys.reshape(tiles, rows, LANE)
    vr = vals.reshape(tiles, rows, LANE)
    ko, vo = pl.pallas_call(
        tile_sort_kv_kernel,
        grid=(tiles,),
        in_specs=[
            pl.BlockSpec((1, rows, LANE), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rows, LANE), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, rows, LANE), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, rows, LANE), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tiles, rows, LANE), keys.dtype),
            jax.ShapeDtypeStruct((tiles, rows, LANE), vals.dtype),
        ],
        interpret=interpret,
    )(kr, vr)
    return ko.reshape(tiles, tile_len), vo.reshape(tiles, tile_len)
