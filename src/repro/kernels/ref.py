"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth its kernel is tested against
(shape/dtype sweeps + assert_allclose in tests/test_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.histogram import Histogram, merge

__all__ = [
    "cumulative_counts_ref",
    "bucket_sizes_from_cumulative",
    "sort_tiles_ref",
    "sort_kv_ref",
    "merge_ref",
]


def cumulative_counts_ref(x: jax.Array, boundaries: jax.Array) -> jax.Array:
    """Oracle for bucket_count: (T+2,) = [#(x < b_j) for j] + [#(x == b_T)]."""
    flat = x.reshape(-1).astype(jnp.float32)
    b = boundaries.astype(jnp.float32)
    lt = (flat[:, None] < b[None, :]).astype(jnp.float32).sum(axis=0)
    eq = (flat == b[-1]).astype(jnp.float32).sum()
    return jnp.concatenate([lt, eq[None]])


def bucket_sizes_from_cumulative(cum: jax.Array) -> jax.Array:
    """Per-bucket sizes from the kernel/oracle output.

    Bucket i (i < T-1) holds ``[b_i, b_{i+1})``; the last bucket is
    right-closed (paper convention), so it additionally gets ``#(x == b_T)``.
    """
    lt, eq_last = cum[:-1], cum[-1]
    sizes = lt[1:] - lt[:-1]
    return sizes.at[-1].add(eq_last)


def sort_tiles_ref(xt: jax.Array) -> jax.Array:
    """Oracle for tile_sort: row-wise jnp.sort."""
    return jnp.sort(xt, axis=-1)


def sort_kv_ref(keys: jax.Array, vals: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for key-value tile sort (stable on keys)."""
    order = jnp.argsort(keys, axis=-1, stable=True)
    return (
        jnp.take_along_axis(keys, order, axis=-1),
        jnp.take_along_axis(vals, order, axis=-1),
    )


def merge_ref(
    boundaries: jax.Array, sizes: jax.Array, beta: int
) -> tuple[jax.Array, jax.Array]:
    """Oracle for merge_cut: the core-library vectorized merge."""
    h = merge(Histogram(boundaries, sizes), beta)
    return h.boundaries, h.sizes
