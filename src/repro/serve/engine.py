"""Serving engine: batched prefill + decode with histogram calibration.

Small but real: request queue → padded batch → jitted ``prefill`` →
token-by-token jitted ``decode_step`` with stop handling.  The histogram
integration is quantization calibration: per-tensor activation clip ranges
come from merged equi-depth summaries (``calibrate()``), giving int8 scale
factors with a bounded-rank-error quantile instead of an ad-hoc max.

:class:`HistogramService` is the always-on metrics sidecar of such an
engine: a crash-recoverable multi-tenant histogram server (the paper's
query plane as a service) whose startup replays the write-ahead log
against the last snapshot, so acked latency/throughput windows survive a
process kill (core/workers.py, "Write-ahead log" design note).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.histogram import Histogram, build_exact, merge_list, quantile
from repro.core.replication import DirTransport, Follower, Replicator
from repro.core.resilience import NotPrimary
from repro.core.tenant import TenantRegistry
from repro.models.model import decode_step, forward_hidden, init_cache, prefill
from repro.serve.subscriptions import Subscription, SubscriptionPlane


@dataclasses.dataclass
class ServeConfig:
    max_seq: int = 256
    max_new_tokens: int = 32
    temperature: float = 0.0  # 0 = greedy
    eos_id: int = 1
    cache_dtype: str = "float32"


class Engine:
    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig, rules=None):
        self.cfg, self.params, self.scfg, self.rules = cfg, params, scfg, rules
        self._prefill = jax.jit(
            lambda p, b, c: prefill(cfg, p, b, c, rules)
        )
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, rules),
            donate_argnums=(1,),
        )

    def _pad_batch(self, prompts: Sequence[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
        B = len(prompts)
        L = max(len(p) for p in prompts)
        toks = np.zeros((B, L), np.int32)
        lens = np.zeros((B,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, : len(p)] = p
            lens[i] = len(p)
        return toks, lens

    def generate(self, prompts: Sequence[np.ndarray], key=None) -> list[np.ndarray]:
        """Greedy/sampled continuation for a batch of token-id prompts."""
        cfg, scfg = self.cfg, self.scfg
        toks, lens = self._pad_batch(prompts)
        B, L = toks.shape
        dtype = jnp.float32 if scfg.cache_dtype == "float32" else jnp.bfloat16
        cache, _ = init_cache(cfg, B, scfg.max_seq, dtype=dtype)
        batch = {"tokens": jnp.asarray(toks)}
        if cfg.is_encoder_decoder:
            batch["frames"] = jnp.zeros(
                (B, cfg.encoder_seq, cfg.d_model), jnp.float32
            )
        logits, cache = self._prefill(self.params, batch, cache)
        out = [list(p) for p in prompts]
        key = key if key is not None else jax.random.PRNGKey(0)
        tok = self._sample(logits[:, -1], key)
        done = np.zeros((B,), bool)
        for step in range(scfg.max_new_tokens):
            t = np.asarray(tok)
            for i in range(B):
                if not done[i]:
                    out[i].append(int(t[i]))
                    done[i] |= int(t[i]) == scfg.eos_id
            if done.all():
                break
            pos = jnp.int32(L + step)
            key, sub = jax.random.split(key)
            logits, cache = self._decode(
                self.params, cache, tok[:, None], pos
            )
            tok = self._sample(logits[:, -1], sub)
        return [np.asarray(o, np.int32) for o in out]

    def _sample(self, logits: jax.Array, key) -> jax.Array:
        if self.scfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.scfg.temperature, axis=-1
        ).astype(jnp.int32)

    # ---- histogram-calibrated quantization --------------------------------
    def calibrate(
        self, sample_batches: Sequence[dict], q: float = 0.999, T: int = 512
    ) -> dict[str, float]:
        """Per-run activation clip scale from merged per-batch summaries.

        Runs the forward on each calibration batch, summarizes |final
        hidden| per batch with an exact T-bucket histogram, merges the
        summaries (the paper's Merger — batches are the partitions), and
        returns the q-quantile clip + int8 scale.  Theorem 1 bounds the
        clip's rank error by 2/T of the calibration mass.
        """
        summaries: list[Histogram] = []
        n_total = 0
        for b in sample_batches:
            hidden, _ = jax.jit(
                lambda p, bb: forward_hidden(self.cfg, p, bb, self.rules)
            )(self.params, b)
            flat = jnp.abs(hidden).reshape(-1).astype(jnp.float32)
            summaries.append(build_exact(flat, min(T, flat.shape[0])))
            n_total += flat.shape[0]
        merged = merge_list(summaries, min(T, 254))
        clip = float(quantile(merged, jnp.float32(q)))
        return {
            "clip": clip,
            "int8_scale": clip / 127.0,
            "rank_error_bound": 2.0 * n_total / T,
            "n_calibration_values": n_total,
        }


class HistogramService:
    """Crash-recoverable histogram server wrapping one data directory.

    The directory holds the two durability artifacts — ``registry.npz``
    (the last atomic snapshot) and ``wal/`` (the write-ahead log) — and
    startup is *recovery-aware*: ``TenantRegistry.recover`` loads the
    snapshot if present, replays the WAL suffix above its
    ``wal_stable_lsn`` (pid-dedup + watermark reconciliation), and routes
    all future ingest through the log.  A serving deployment therefore
    never loses an acked metric window: kill -9 between ``record`` and
    ``checkpoint`` replays on the next start, and ``checkpoint()``
    truncates the log down to the uncovered suffix.

    >>> svc = HistogramService(data_dir, num_buckets=128)
    >>> svc.recovery            # {'records_scanned': ..., 'replayed': ...}
    >>> svc.record("latency_ms", window_id, samples)
    >>> svc.quantile("latency_ms", lo, hi, 0.95)
    >>> svc.checkpoint()        # atomic snapshot + WAL truncation

    **Roles (core/replication.py).**  ``role="primary"`` (default) with
    ``replicate_to=[dir_or_transport, ...]`` ships every WAL byte to
    those followers *before the ingest ack* — zero acked loss across a
    primary kill.  ``role="replica"`` serves reads from the shipped
    directory instead: ``record``/``record_async`` raise
    :class:`~repro.core.resilience.NotPrimary`, ``sync()`` tails new
    shipped bytes, ``query_many`` answers with ``eps`` honestly widened
    by the replication-lag drift bound and ``degraded=True`` past the
    ``staleness_slo``, and ``promote()`` is the failover: fence the old
    primary, drain, adopt the shipped log, flip the role to primary.
    """

    def __init__(
        self,
        data_dir: str,
        *,
        salvage: bool = True,
        role: str = "primary",
        replicate_to=(),
        staleness_slo: float | None = None,
        **registry_kwargs,
    ):
        if role not in ("primary", "replica"):
            raise ValueError(f"role must be primary|replica, got {role!r}")
        self.data_dir = str(data_dir)
        os.makedirs(self.data_dir, exist_ok=True)
        self.snapshot_path = os.path.join(self.data_dir, "registry.npz")
        self.wal_dir = os.path.join(self.data_dir, "wal")
        self.role = role
        self.staleness_slo = staleness_slo
        self.replicator: Replicator | None = None
        self.follower: Follower | None = None
        if role == "replica":
            # the wal/ subdirectory is the *shipped* directory: startup
            # "recovery" is simply one tail pass over whatever the
            # primary has shipped so far
            self.follower = Follower(
                self.wal_dir,
                staleness_slo=staleness_slo,
                **registry_kwargs,
            )
            self.registry = self.follower.registry
            self.follower.tail()
            self.recovery = None
            self.salvage = None
            self._plane = None
            return
        # salvage=True (the service default): a snapshot whose payload
        # checksums fail is moved aside and the state rebuilt from the
        # WAL alone — a serving sidecar must start, not crash-loop on a
        # rotted file (core/scrub.py)
        self.registry = TenantRegistry.recover(
            self.snapshot_path, self.wal_dir, salvage=salvage,
            **registry_kwargs
        )
        #: replay stats from this startup (records scanned/replayed,
        #: torn records dropped) — surface these in the serving logs
        self.recovery = self.registry.last_recovery
        #: snapshot-verification report when salvage rebuilt from the WAL
        self.salvage = self.registry.last_salvage
        # standing-query plane, created on first subscribe()
        self._plane: SubscriptionPlane | None = None
        if replicate_to:
            # a string/PathLike names a standby *data_dir*: ship into its
            # wal/ subdirectory so the standby has the exact layout a
            # replica-role (and later promoted-primary) service expects
            transports = [
                DirTransport(os.path.join(str(t), "wal"))
                if isinstance(t, (str, os.PathLike)) else t
                for t in replicate_to
            ]
            self.replicator = Replicator(
                self.registry._wal, transports
            ).attach(self.registry)
            # a checkpoint may have truncated snapshot-covered history
            # out of the WAL: bootstrap-ship the snapshot so a fresh
            # standby is not silently missing that prefix (raises,
            # rather than under-replicating, when that history cannot
            # be shipped)
            self.replicator.bootstrap(self.snapshot_path)
            # followers start from the full shipped history: push
            # everything the log already holds before the first ack
            self.replicator.ship()

    # ---- ingest plane ----------------------------------------------------
    def record(self, metric: str, window_id: int, values) -> None:
        """Durably ingest one window of raw samples (fsynced before
        return; see the WAL design note in core/workers.py).  With
        replication attached the record is shipped to every follower
        before this returns."""
        if self.role != "primary":
            raise NotPrimary(f"record() on a {self.role}-role service")
        self.registry.ingest(metric, window_id, values)

    def record_async(self, metric: str, window_id: int, values) -> None:
        """Durable enqueue: the WAL append+fsync (and replication ship)
        happens before this returns, summarization on the worker pool."""
        if self.role != "primary":
            raise NotPrimary(f"record_async() on a {self.role}-role service")
        self.registry.ingest_async(metric, window_id, values)

    def flush(self) -> None:
        self.registry.flush()

    # ---- query plane -----------------------------------------------------
    def quantile(self, metric: str, lo: int, hi: int, q, beta=None):
        return self.registry[metric].quantile_query(lo, hi, q, beta)

    def query_many(
        self,
        panels,
        beta: int = 64,
        strict: bool = False,
        deadline: float | None = None,
    ):
        """Dashboard panel batch.  The service plane defaults to
        ``degraded_ok=True``: a failed merge dispatch (or a missed
        ``deadline``) serves last-known-good answers flagged
        ``degraded=True`` with honestly widened eps instead of a 500 —
        check ``ans.degraded`` (plain fresh answers read False).

        On a replica the batch is served from the follower's registry
        with ``eps`` widened by the lag-drift bound and ``lag_seconds``
        attached; ``degraded=True`` marks any answer that cannot be
        proven to bit-match the primary's acked state."""
        if self.follower is not None and self.role == "replica":
            return self.follower.query_many(
                panels, beta, strict=strict, deadline=deadline
            )
        return self.registry.query_many(
            panels, beta, strict=strict, degraded_ok=True, deadline=deadline
        )

    def sync(self) -> int:
        """Replica: apply newly shipped WAL bytes (one tail pass);
        returns records applied.  No-op (0) on a primary."""
        if self.follower is None or self.role != "replica":
            return 0
        return self.follower.tail()

    def metrics(self) -> list[str]:
        return self.registry.names()

    # ---- standing queries (push plane) -----------------------------------
    @property
    def subscriptions(self) -> SubscriptionPlane:
        """The service's standing-query plane (created on first use);
        its ``flush()`` is the push barrier, its ``stats()`` also rides
        ``health()['subscriptions']``."""
        if self._plane is None:
            self._plane = SubscriptionPlane(self.registry)
        return self._plane

    def subscribe(
        self,
        metric: str,
        lo: int,
        hi: int,
        beta: int = 64,
        *,
        policy: str = "coalesce",
        queue_cap: int = 8,
    ) -> Subscription:
        """Register a standing dashboard query: pushed ``Update``s arrive
        whenever windows ``lo..hi`` of the metric go stale — same answer
        (hist and composed eps) the pull path reports, deduplicated and
        batched into one merge dispatch per ingest tick across ALL
        subscriptions (serve/subscriptions.py)."""
        return self.subscriptions.subscribe(
            metric, lo, hi, beta, policy=policy, queue_cap=queue_cap
        )

    def unsubscribe(self, sub: Subscription) -> None:
        self.subscriptions.unsubscribe(sub)

    # ---- failover plane --------------------------------------------------
    def promote(self, *, fence=None, epoch: int | None = None,
                receivers=()) -> None:
        """Replica → primary failover (core/replication.py): fence the
        deposed primary (``fence`` = its ``Replicator.fence`` /
        ``WriteAheadLog.fence``, best-effort — a dead primary is fine),
        drain the shipped suffix, adopt the shipped log as this
        service's WAL, re-attach the subscription plane, flip the role.
        After this returns, ``record()`` works and ``query_many`` serves
        un-widened primary answers."""
        if self.follower is None or self.role != "replica":
            raise NotPrimary("promote() requires a replica-role service")
        planes = [self._plane] if self._plane is not None else []
        self.follower.promote(
            fence=fence, epoch=epoch, planes=planes, receivers=receivers
        )
        self.role = "primary"
        if any(self.follower._boot_mass.values()):
            # this replica was snapshot-bootstrapped: the adopted WAL
            # alone cannot rebuild the snapshot-covered prefix, so
            # persist a checkpoint now — a restart of the promoted
            # service must recover the full state, not just the suffix
            self.checkpoint()

    # ---- health plane ----------------------------------------------------
    def health(self) -> dict:
        """Serving-plane health aggregate (breakers, quarantine, WAL,
        degraded counters, last recovery/scrub, replication lag/epoch/
        role) — the /healthz payload."""
        out = self.registry.health()
        out["role"] = self.role
        if self.follower is not None:
            out["replication"] = self.follower.stats()
        return out

    def scrub(self, *, repair: bool = False) -> dict:
        """On-demand integrity scrub of every tenant (core/scrub.py);
        ``repair=True`` routes corrupted tenants through WAL-replay
        rebuild."""
        return self.registry.scrub(repair=repair)

    # ---- durability plane ------------------------------------------------
    def checkpoint(self) -> str:
        """Atomic snapshot (tempfile + fsync + rename + dir fsync) then
        WAL truncation of the covered prefix.  Returns the path."""
        self.registry.flush()
        self.registry.save(self.snapshot_path)
        return self.snapshot_path

    def wal_stats(self) -> dict | None:
        return self.registry.wal_stats()

    def close(self) -> None:
        self.registry.close()
