from repro.serve.engine import Engine, ServeConfig
