from repro.serve.engine import Engine, HistogramService, ServeConfig
