from repro.serve.engine import Engine, HistogramService, ServeConfig
from repro.serve.subscriptions import (
    Subscription,
    SubscriptionPlane,
    Update,
)
