"""Standing-query subscription plane: push-based dashboard fan-out.

The pull model re-asks the same dashboard windows forever: every refresh
is a ``query_many`` through the per-tenant LRU, and every ingest tick
invalidates them all.  This module inverts it.  Clients register a
standing query ``(tenant, lo, hi, beta)`` with :class:`SubscriptionPlane`
and receive pushed :class:`Update`\\ s only when their answer actually
went stale — staleness detected by the machinery that already exists:
``HistogramStore.version`` (the ``_VersionedDict`` mutation token behind
the version-keyed caches in ``core/tenant.py``) moves exactly when a
tenant's answers die.

Re-evaluation is *incremental and deduplicated*: one evaluation pass
collects every stale window across every tenant — subscribers sharing a
window share one evaluation, so 10k subscribers on 100 distinct windows
cost 100 evaluations — and answers them with ONE cross-tenant
``TenantRegistry.query_many`` merge dispatch (the arena gather pack),
then fans the answers out through bounded per-subscriber delivery
queues.  Overflow policy is explicit per subscription:

* ``coalesce`` (default, the dashboard policy) — a full queue drops its
  *oldest* updates to admit the newest (counted in ``coalesced``);
* ``block`` — delivery waits for the consumer to drain (backpressure
  onto the evaluation worker);
* ``drop`` — the newest update is discarded and counted (``dropped``).

Degraded-mode contract (same as ``query_many(degraded_ok=True)``): a
quarantined tenant's stale subscriptions — and every stale window while
the ``subs.eval`` failpoint is firing — are served the last-known-good
answer as an :class:`~repro.core.resilience.Answer` flagged
``degraded=True`` with honestly widened eps; the subscription stays
stale, so the next tick after the fault heals re-pushes fresh.  A
``subs.deliver`` fault leaves the subscriber at its old version (counted
in ``deliver_failures``); the next evaluation pass re-delivers from the
plane's answer cache without a new dispatch.  Nothing is silently lost.

Event-sequencing (no sleeps anywhere): the evaluation worker is a
single lazily-started daemon thread on the ``IngestPool`` pattern
(``core/workers.py``) — condition-variable wakeups, an epoch counter,
and a :meth:`SubscriptionPlane.flush` barrier that returns only after
every tick submitted before it has been evaluated AND delivered.

Lock ranks (``repro.analysis.witness``): ``subs.cv`` (6) and
``subs.queue`` (8) sit *below* ``registry._lock`` (10) — plane
bookkeeping may call into the registry, never the reverse; the worker
holds neither across the merge dispatch.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Iterable, NamedTuple

from repro.analysis.witness import OrderedRLock
from repro.core import faults

__all__ = ["POLICIES", "Subscription", "SubscriptionPlane", "Update"]

POLICIES = ("coalesce", "block", "drop")


class Update(NamedTuple):
    """One pushed answer: the same ``(hist, eps)`` the pull path reports,
    plus the provenance a dashboard needs to trust it."""

    tenant: str
    lo: int
    hi: int
    beta: int
    hist: object  # Histogram | None (the empty-window placeholder)
    eps: float
    version: object  # store version the answer was evaluated at
    seq: int  # plane-global delivery sequence number
    degraded: bool  # True ⇒ last-known-good serving (Answer contract)
    lag_seconds: float  # staleness mark → delivery


class Subscription:
    """One standing query's delivery endpoint: a bounded queue with an
    explicit overflow policy.  Consumers call :meth:`get` / :meth:`drain`;
    only the plane's evaluation worker enqueues."""

    def __init__(self, plane: "SubscriptionPlane", key, policy, queue_cap):
        if policy not in POLICIES:
            raise ValueError(f"policy must be one of {POLICIES}: {policy!r}")
        if int(queue_cap) < 1:
            raise ValueError(f"queue_cap must be >= 1: {queue_cap!r}")
        self.plane = plane
        self.key = key  # (tenant, lo, hi, beta)
        self.policy = policy
        self.queue_cap = int(queue_cap)
        # per-subscription delivery condition; keyed by identity so the
        # witness allows (never-needed) same-rank nesting deterministically
        self.cv = threading.Condition(OrderedRLock("subs.queue", key=id(self)))
        self._q: deque[Update] = deque()
        self.closed = False
        self.delivered = 0  # updates enqueued (consumer-visible)
        self.coalesced = 0  # stale updates displaced by newer (coalesce)
        self.dropped = 0  # newest-update discards (drop policy)
        # store version of the last successfully delivered FRESH answer —
        # owned by the evaluation worker thread after construction
        self._last_version: object = None

    # ------------------------------------------------------------ consumer
    def get(self, timeout: float | None = None) -> Update | None:
        """Pop the oldest pending update (blocking).  ``None`` on timeout
        or when the subscription is closed and empty."""
        with self.cv:
            while not self._q and not self.closed:
                if not self.cv.wait(timeout):
                    return None
            if not self._q:
                return None  # closed and empty
            update = self._q.popleft()
            self.cv.notify_all()  # wake a block-policy producer
            return update

    def drain(self) -> list[Update]:
        """Pop everything pending without blocking."""
        with self.cv:
            out = list(self._q)
            self._q.clear()
            if out:
                self.cv.notify_all()
            return out

    def pending(self) -> int:
        with self.cv:
            return len(self._q)

    def stats(self) -> dict:
        with self.cv:
            return {
                "key": self.key,
                "policy": self.policy,
                "pending": len(self._q),
                "delivered": self.delivered,
                "coalesced": self.coalesced,
                "dropped": self.dropped,
                "closed": self.closed,
            }

    # ------------------------------------------------------- plane-internal
    def _offer(self, update: Update, closing: threading.Event) -> bool:
        """Enqueue per policy; False ⇒ not delivered (closed/shutdown)."""
        with self.cv:
            if self.closed:
                return False
            if self.policy == "block":
                while (
                    len(self._q) >= self.queue_cap
                    and not self.closed
                    and not closing.is_set()
                ):
                    self.cv.wait()
                if self.closed or closing.is_set():
                    return False
            elif len(self._q) >= self.queue_cap:
                if self.policy == "coalesce":
                    while len(self._q) >= self.queue_cap:
                        self._q.popleft()
                        self.coalesced += 1
                else:  # drop: the newest update is the counted casualty
                    self.dropped += 1
                    return True
            self._q.append(update)
            self.delivered += 1
            self.cv.notify_all()
            return True

    def close(self) -> None:
        """Mark closed and wake blocked consumers/producers (idempotent)."""
        with self.cv:
            self.closed = True
            self.cv.notify_all()


class SubscriptionPlane:
    """Registry-level standing-query plane (see module docstring).

    Attaches to a :class:`~repro.core.tenant.TenantRegistry` as a
    stale-listener: every registry ingest/sweep/eviction tick calls
    :meth:`mark_stale` with the touched tenant names.  The evaluation
    worker then re-checks *store versions* (the authoritative staleness
    signal — a hint can be missed, a version move cannot), evaluates all
    stale distinct windows with one ``query_many`` dispatch, and fans
    out.  ``registry.close()`` closes attached planes.
    """

    def __init__(self, registry):
        self.registry = registry
        # plane condition: subscription table, dirty hints, epoch barrier
        self.cv = threading.Condition(OrderedRLock("subs.cv"))
        self._subs: dict[tuple, list[Subscription]] = {}
        self._tenant_refs: dict[str, int] = {}  # tenant → live window count
        self._marks: dict[str, float] = {}  # tenant → first stale-mark time
        self._epoch = 0  # bumped per tick/flush; the worker's work signal
        self._completed = 0  # highest epoch fully evaluated AND delivered
        self._closing = threading.Event()
        self._thread: threading.Thread | None = None
        # evaluation-worker-owned state (never touched by other threads):
        # window key → (store version, (hist, eps)) of the last fresh eval
        self._seen: dict[tuple, tuple] = {}
        # ---- counters (GIL-coarse ints; read by stats()/health()) ----
        self.ticks = 0  # mark_stale calls that touched a subscribed tenant
        self.eval_passes = 0  # worker passes that evaluated >= 1 window
        self.eval_batches = 0  # query_many calls (merge dispatch attempts)
        self.windows_evaluated = 0  # distinct stale windows re-evaluated
        self.updates_delivered = 0  # fan-out deliveries accepted by queues
        self.dedup_saved = 0  # subscriber evals saved by window dedup
        self.degraded_pushed = 0  # degraded Answers pushed (quarantine/fault)
        self.eval_failures = 0  # subs.eval faults (pass served degraded)
        self.deliver_failures = 0  # subs.deliver faults (retried next pass)
        self.seq = 0  # plane-global update sequence
        self.last_lag_seconds = 0.0
        self.max_lag_seconds = 0.0
        registry._stale_listeners.append(self)

    # ------------------------------------------------------------- register
    def subscribe(
        self,
        tenant: str,
        lo: int,
        hi: int,
        beta: int,
        *,
        policy: str = "coalesce",
        queue_cap: int = 8,
    ) -> Subscription:
        """Register a standing query; the initial answer is pushed on the
        next tick or :meth:`flush` (subscribing never wakes the worker, so
        between-flush counter accounting stays deterministic)."""
        name = str(tenant)
        # create the tenant eagerly (outside the plane lock: registry._lock
        # ranks above subs.cv only in the plane→registry direction)
        self.registry.tenant(name)
        key = (name, int(lo), int(hi), int(beta))
        sub = Subscription(self, key, policy, queue_cap)
        with self.cv:
            if self._closing.is_set():
                raise RuntimeError("subscription plane is closed")
            self._subs.setdefault(key, []).append(sub)
            self._tenant_refs[name] = self._tenant_refs.get(name, 0) + 1
        return sub

    def unsubscribe(self, sub: Subscription) -> None:
        """Remove and close one subscription (idempotent)."""
        with self.cv:
            lst = self._subs.get(sub.key)
            if lst is not None and sub in lst:
                lst.remove(sub)
                name = sub.key[0]
                n = self._tenant_refs.get(name, 1) - 1
                if n:
                    self._tenant_refs[name] = n
                else:
                    self._tenant_refs.pop(name, None)
                if not lst:
                    del self._subs[sub.key]
        sub.close()

    def __len__(self) -> int:
        with self.cv:
            return sum(len(v) for v in self._subs.values())

    # ----------------------------------------------------------- tick plane
    def mark_stale(self, names: Iterable[str] | str) -> None:
        """Registry tick: the named tenants' versions may have moved.
        Cheap when none of them carry subscriptions; otherwise wakes the
        evaluation worker (the hint is a wakeup — version comparison in
        the worker is the authoritative staleness check)."""
        if isinstance(names, str):
            names = (names,)
        now = time.monotonic()
        with self.cv:
            if self._closing.is_set():
                return
            relevant = [
                n for n in map(str, names) if self._tenant_refs.get(n)
            ]
            if not relevant:
                return
            self.ticks += 1
            for n in relevant:
                self._marks.setdefault(n, now)
            self._epoch += 1
            self._ensure_worker()
            self.cv.notify_all()

    def flush(self) -> None:
        """Barrier: every tick submitted before this call has been fully
        evaluated and delivered when it returns.  Also forces one
        evaluation pass, so fresh subscriptions receive their initial
        answer (and faulted deliveries their retry) without a tick.

        A ``block``-policy subscriber that never drains blocks delivery
        and therefore blocks this barrier — that is the policy's contract.
        """
        with self.cv:
            if self._closing.is_set():
                return
            self._epoch += 1
            target = self._epoch
            self._ensure_worker()
            self.cv.notify_all()
            while self._completed < target and not self._closing.is_set():
                self.cv.wait()

    def reattach(self, new_registry) -> None:
        """Re-home this plane onto another registry — the failover leg of
        ``Follower.promote()`` (core/replication.py): live subscriptions
        keep their keys and queues, evaluation continues against the
        promoted registry's stores, and every subscribed tenant is marked
        stale so subscribers receive a fresh post-failover answer (their
        ``version`` counters may regress; ``seq`` stays monotonic).

        The new registry's tenants are created eagerly *before* the swap
        (the evaluation worker assumes subscribed tenants exist), and the
        listener hookup moves atomically under the plane condition.
        """
        with self.cv:
            names = list(self._tenant_refs)
        for name in names:
            new_registry.tenant(name)  # outside cv: registry._lock ranks above
        old = self.registry
        with self.cv:
            if self._closing.is_set():
                return
            self.registry = new_registry
            # force a full re-evaluation: versions on the new registry are
            # not comparable to the cached ones
            self._seen.clear()
            now = time.monotonic()
            for name in names:
                self._marks.setdefault(name, now)
            if names:
                self._epoch += 1
                self._ensure_worker()
                self.cv.notify_all()
        try:
            old._stale_listeners.remove(self)
        except ValueError:
            pass
        new_registry._stale_listeners.append(self)

    def close(self) -> None:
        """Stop the worker (finishing any pending pass), close every
        subscription, detach from the registry.  Idempotent."""
        with self.cv:
            already = self._closing.is_set()
            self._closing.set()
            self.cv.notify_all()
            thread = self._thread
            subs = [s for lst in self._subs.values() for s in lst]
        for sub in subs:
            sub.close()  # wakes block-policy producers and idle consumers
        if thread is not None:
            thread.join()
        if not already:
            try:
                self.registry._stale_listeners.remove(self)
            except ValueError:
                pass

    # ------------------------------------------------------------ telemetry
    def stats(self) -> dict:
        """Counters for ``health()``: subscription/window counts, lag,
        dedup and overflow accounting."""
        with self.cv:
            subs = [s for lst in self._subs.values() for s in lst]
            windows = len(self._subs)
            tenants = len(self._tenant_refs)
        pending = coalesced = dropped = 0
        for s in subs:
            st = s.stats()
            pending += st["pending"]
            coalesced += st["coalesced"]
            dropped += st["dropped"]
        return {
            "subscriptions": len(subs),
            "windows": windows,
            "tenants": tenants,
            "ticks": self.ticks,
            "eval_passes": self.eval_passes,
            "eval_batches": self.eval_batches,
            "windows_evaluated": self.windows_evaluated,
            "updates_delivered": self.updates_delivered,
            "dedup_saved": self.dedup_saved,
            "degraded_pushed": self.degraded_pushed,
            "eval_failures": self.eval_failures,
            "deliver_failures": self.deliver_failures,
            "pending": pending,
            "coalesced": coalesced,
            "dropped": dropped,
            "last_lag_seconds": self.last_lag_seconds,
            "max_lag_seconds": self.max_lag_seconds,
        }

    # ---------------------------------------------------- evaluation worker
    def _ensure_worker(self) -> None:
        # caller holds self.cv
        t = self._thread
        if t is None or not t.is_alive():
            t = threading.Thread(
                target=self._loop, name="subs-eval", daemon=True
            )
            self._thread = t
            t.start()

    def _loop(self) -> None:
        while self._run_once():
            pass

    def _run_once(self) -> bool:
        with self.cv:
            while (
                not self._closing.is_set() and self._completed >= self._epoch
            ):
                self.cv.wait()
            if self._closing.is_set() and self._completed >= self._epoch:
                return False  # drained: nothing submitted before close
            target = self._epoch
            table = {k: list(v) for k, v in self._subs.items() if v}
            marks = dict(self._marks)
            self._marks.clear()
        try:
            self._evaluate(table, marks)
        finally:
            with self.cv:
                if target > self._completed:
                    self._completed = target
                self.cv.notify_all()
        return True  # the top-of-loop predicate decides drained-on-close

    def _quarantined(self, name: str) -> bool:
        reg = self.registry
        if reg.breaker_policy is None:
            return False
        with reg._lock:
            b = reg._breakers.get(name)
        return b is not None and b.state != "closed"

    def _evaluate(self, table: dict, marks: dict) -> None:
        """One incremental pass: version-diff every subscribed window,
        answer all stale ones with one ``query_many`` dispatch per beta,
        fan out to every subscriber not already at the answer's version."""
        reg = self.registry
        t_pass = time.monotonic()
        # one version read per distinct subscribed tenant
        versions: dict[str, object] = {}
        for key in table:
            name = key[0]
            if name not in versions:
                versions[name] = (
                    reg[name].version if name in reg else None
                )
        stale = [
            key
            for key in sorted(table)
            if key not in self._seen
            or self._seen[key][0] != versions[key[0]]
        ]
        degraded: dict[tuple, object] = {}  # key → Answer(degraded=True)
        fresh: dict[tuple, tuple] = {}  # key → (version, (hist, eps))
        to_eval: list[tuple] = []
        for key in stale:
            if self._quarantined(key[0]):
                # the quarantine contract: last-known-good, honestly
                # widened, flagged — exactly query_many(degraded_ok=True)
                degraded[key] = reg._degraded_answer(key)
            else:
                to_eval.append(key)
        if to_eval:
            try:
                faults.hit("subs.eval", windows=len(to_eval))
            except BaseException:
                self.eval_failures += 1
                for key in to_eval:
                    degraded[key] = reg._degraded_answer(key)
            else:
                by_beta: dict[int, list[tuple]] = {}
                for key in to_eval:
                    by_beta.setdefault(key[3], []).append(key)
                for beta, keys in sorted(by_beta.items()):
                    # ONE cross-tenant merge dispatch for every stale
                    # window at this beta (the arena gather pack)
                    answers = reg.query_many(
                        [(k[0], k[1], k[2]) for k in keys],
                        beta,
                        strict=False,
                        degraded_ok=True,
                    )
                    self.eval_batches += 1
                    for key, ans in zip(keys, answers):
                        if getattr(ans, "degraded", False):
                            degraded[key] = ans
                        else:
                            fresh[key] = (versions[key[0]], ans)
            self.eval_passes += 1
            self.windows_evaluated += len(to_eval)
            self.dedup_saved += sum(
                len(table[k]) - 1 for k in to_eval
            )
        for key, (version, ans) in fresh.items():
            self._seen[key] = (version, ans)
        # fan-out: every subscriber whose delivered version lags the
        # answer's version gets an update; degraded answers never advance
        # the subscriber's version (the window stays stale until healed)
        for key in sorted(table):
            name, lo, hi, beta = key
            if key in degraded:
                ans, version, is_degraded = degraded[key], None, True
            elif key in self._seen:
                version, ans = self._seen[key]
                is_degraded = False
            else:
                continue  # never evaluated (eval itself unavailable)
            mark_t = marks.get(name, t_pass)
            for sub in table[key]:
                if not is_degraded and sub._last_version == version:
                    continue  # already current — their result isn't stale
                self.seq += 1
                now = time.monotonic()
                lag = max(0.0, now - mark_t)
                update = Update(
                    name, lo, hi, beta,
                    ans[0], float(ans[1]),
                    version, self.seq, is_degraded, lag,
                )
                try:
                    faults.hit(
                        "subs.deliver", tenant=name, policy=sub.policy
                    )
                    ok = sub._offer(update, self._closing)
                except BaseException:
                    # leave sub._last_version stale: the next pass
                    # re-delivers from self._seen without a new dispatch
                    self.deliver_failures += 1
                    continue
                if not ok:
                    continue  # closed mid-delivery
                self.updates_delivered += 1
                if is_degraded:
                    self.degraded_pushed += 1
                else:
                    sub._last_version = version
                self.last_lag_seconds = lag
                if lag > self.max_lag_seconds:
                    self.max_lag_seconds = lag
        # prune evaluation cache entries whose last subscriber left
        for key in list(self._seen):
            if key not in table:
                del self._seen[key]
