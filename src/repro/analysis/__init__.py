"""Repo-specific static analysis + runtime lock-discipline witness.

Submodules (import what you need; this ``__init__`` stays cheap because
``repro.core`` imports :mod:`repro.analysis.witness` at module load):

- :mod:`repro.analysis.witness`   — OrderedLock/OrderedRLock runtime witness
- :mod:`repro.analysis.findings`  — Finding records + ratchet baseline
- :mod:`repro.analysis.lint`      — AST lint rules from the repo's bug history
- :mod:`repro.analysis.lockgraph` — static lock-acquisition graph + rank check

CLI entry point: ``scripts/analyze.py`` (see ANALYSIS.md).
"""
from repro.analysis.witness import (  # noqa: F401
    LockOrderError,
    OrderedLock,
    OrderedRLock,
    RANKS,
    arm,
    armed,
    disarm,
)
