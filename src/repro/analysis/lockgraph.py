"""Static lock-acquisition graph + hierarchy check.

Extracts, from the AST of the core modules, *which documented locks may
be acquired while which others are held* — across function calls — and
checks every edge against the rank table shared with the runtime witness
(:data:`repro.analysis.witness.RANKS`).  Rank inversions and cycles are
reported as findings; the runtime witness then re-checks the same
discipline on every real acquisition the test suite drives, so the two
analyses bracket each other (static = all *syntactic* paths, runtime =
the *executed* ones with exact object identity).

Precision notes (deliberate, documented approximations):

- Lock expressions are recognized by declarative pattern tables
  (``CLASS_ATTR_LOCKS`` for ``self.X`` inside a known class,
  ``RECEIVER_CLASS`` leaf-name hints for ``store._lock`` /
  ``reg._lock``-style cross-object accesses).  Unknown lock-ish
  expressions are ignored, not guessed.
- Calls resolve to: same-class methods (``self.m()``), methods of a
  hinted receiver class (``self.wal.append()`` → ``WriteAheadLog``),
  configured callback bindings (``self.wrap_error`` is a constructor
  argument — invisible to a naive call graph), or a *globally unique*
  function name.  Ambiguous names and builtin-ish container methods
  (``append``/``get``/``put``…) are skipped rather than over-linked —
  except through the hint tables above, which is why ``wal.append`` still
  resolves while ``errors.append`` does not.
- ``stack.enter_context(lock)`` and bare ``lock.acquire()`` hold until
  function exit (``release()`` drops); branches union their held-sets.

The transitive summary is a fixed point of "locks this function may
acquire"; an edge ``(held → acquired)`` is emitted for every direct
acquisition and every call made while holding a lock.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field

from repro.analysis.findings import Finding
from repro.analysis.lint import SourceFile, _dotted, _receiver_leaf
from repro.analysis.witness import RANKS

# (class, self-attribute) → documented lock id
CLASS_ATTR_LOCKS: dict[tuple[str, str], str] = {
    ("TenantRegistry", "_lock"): "registry._lock",
    ("HistogramStore", "_lock"): "store._lock",
    ("WriteAheadLog", "_lock"): "wal._lock",
    ("WriteAheadLog", "_commit_lock"): "wal._commit_lock",
    ("IngestPool", "ingest_mutex"): "pool.ingest_mutex",
    ("IngestPool", "_state_lock"): "pool._state_lock",
    ("IngestPool", "cv"): "pool.cv",
    ("NodeArena", "_lock"): "arena._lock",
    ("SubscriptionPlane", "cv"): "subs.cv",
    ("Subscription", "cv"): "subs.queue",
    ("Replicator", "_lock"): "repl.replicator",
    ("Follower", "_lock"): "repl.follower",
}

# module-level lock names → lock id (qualified by defining basename)
MODULE_LOCKS: dict[tuple[str, str], str] = {
    ("interval_tree.py", "_COUNTER_LOCK"): "tree.counters",
    ("faults.py", "_LOCK"): "faults.registry",
}

# receiver-leaf-name → class, for cross-object lock/method accesses
RECEIVER_CLASS: dict[str, str] = {
    "store": "HistogramStore",
    "stores": "HistogramStore",
    "_stores": "HistogramStore",
    "summarized": "HistogramStore",  # tenant.py's {name: (store, …)} map
    "reg": "TenantRegistry",
    "registry": "TenantRegistry",
    "wal": "WriteAheadLog",
    "_wal": "WriteAheadLog",
    "pool": "IngestPool",
    "_pool": "IngestPool",
    "arena": "NodeArena",
    "_arena": "NodeArena",
    "tree": "IntervalTree",
    "_tree": "IntervalTree",
    "plane": "SubscriptionPlane",  # tenant.py's _notify_stale loop var
    "sub": "Subscription",
    "replicator": "Replicator",
    "_replication": "Replicator",
    "follower": "Follower",
}

# constructor-argument callbacks: attribute call on self that is really a
# bound method of another class (invisible to syntactic resolution)
CALLBACK_BINDINGS: dict[str, list[tuple[str, str]]] = {
    "apply_batch": [
        ("HistogramStore", "_apply_batch"),
        ("HistogramStore", "_apply_worker_batch"),
        ("TenantRegistry", "_apply_worker_batch"),
    ],
    "wrap_error": [
        ("HistogramStore", "_wrap_async_error"),
        ("TenantRegistry", "_wrap_async_error"),
    ],
    "on_batch_end": [
        ("HistogramStore", "_sweep_after_batch"),
        ("TenantRegistry", "_sweep_after_batch"),
    ],
    "wal_record": [],
}

# container/stdlib method names never resolved on unknown receivers
SKIP_METHODS = frozenset({
    "append", "extend", "add", "discard", "remove", "pop", "popleft",
    "clear", "update", "get", "put", "get_nowait", "put_nowait", "items",
    "keys", "values", "copy", "sort", "index", "count", "join", "start",
    "is_alive", "read", "write", "flush", "seek", "tell", "truncate",
    "fileno",
    "close", "open", "strip", "split", "format", "encode", "decode",
    "startswith", "endswith", "setdefault", "tolist", "astype", "reshape",
    "acquire", "release", "wait", "notify", "notify_all", "set",
    "is_set", "locked",
})

# locks safe to re-acquire with another instance (RLock and/or keyed
# same-rank family whose sorted order the runtime witness checks)
REENTRANT = frozenset({
    "registry._lock", "store._lock", "arena._lock", "pool.cv",
    "subs.cv", "subs.queue",
})


@dataclass
class _Func:
    key: str                 # "basename.py:Class.name" (or ":name")
    cls: str | None
    name: str
    path: str
    node: ast.AST
    acquires: list = field(default_factory=list)  # (lock, held, line)
    calls: list = field(default_factory=list)     # (callees, held, line, label)
    trans: set = field(default_factory=set)       # fixed-point lock set


class LockGraph:
    def __init__(self, files: list[SourceFile]):
        self.files = [f for f in files if not f.is_test]
        self.funcs: dict[str, _Func] = {}
        self.by_class: dict[tuple[str, str], list[str]] = {}
        self.by_name: dict[str, list[str]] = {}
        self._index()
        for fn in self.funcs.values():
            self._scan(fn)
        self._fixed_point()

    # ------------------------------------------------------------ indexing
    def _index(self) -> None:
        for sf in self.files:
            base = os.path.basename(sf.path)

            def add(node, cls):
                name = f"{cls}.{node.name}" if cls else node.name
                fn = _Func(
                    key=f"{base}:{name}", cls=cls, name=node.name,
                    path=sf.path, node=node,
                )
                self.funcs[fn.key] = fn
                if cls:
                    self.by_class.setdefault((cls, node.name), []).append(
                        fn.key
                    )
                self.by_name.setdefault(node.name, []).append(fn.key)

            for child in ast.iter_child_nodes(sf.tree):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    add(child, None)
                elif isinstance(child, ast.ClassDef):
                    for sub in ast.iter_child_nodes(child):
                        if isinstance(
                            sub, (ast.FunctionDef, ast.AsyncFunctionDef)
                        ):
                            add(sub, child.name)

    # ------------------------------------------------------- lock resolution
    def _resolve_lock(self, expr: ast.AST, fn: _Func) -> str | None:
        base = os.path.basename(fn.path)
        if isinstance(expr, ast.Name):
            return MODULE_LOCKS.get((base, expr.id))
        if not isinstance(expr, ast.Attribute):
            return None
        recv = expr.value
        # strip subscripts: summarized[name][0]._lock → leaf 'summarized'
        while isinstance(recv, ast.Subscript):
            recv = recv.value
        leaf = _receiver_leaf(recv)
        if leaf == "self" and fn.cls:
            return CLASS_ATTR_LOCKS.get((fn.cls, expr.attr))
        if isinstance(recv, ast.Attribute):
            # self.wal._lock / self._pool.cv — hint on the inner attribute
            leaf = recv.attr
        cls = RECEIVER_CLASS.get(leaf or "")
        if cls:
            return CLASS_ATTR_LOCKS.get((cls, expr.attr))
        return None

    # ------------------------------------------------------- call resolution
    def _resolve_call(self, node: ast.Call, fn: _Func) -> tuple[list[str], str]:
        func = node.func
        if isinstance(func, ast.Name):
            name = func.id
            base = os.path.basename(fn.path)
            local = [k for k in self.by_name.get(name, ())
                     if k.startswith(f"{base}:") and ":" + name == k[len(base):]]
            if local:
                return local, name
            cands = [
                k for k in self.by_name.get(name, ())
                if self.funcs[k].cls is None
            ]
            return (cands, name) if len(cands) == 1 else ([], name)
        if not isinstance(func, ast.Attribute):
            return [], "?"
        meth = func.attr
        recv = func.value
        while isinstance(recv, ast.Subscript):
            recv = recv.value
        leaf = _receiver_leaf(recv)
        if leaf == "self" and fn.cls:
            own = self.by_class.get((fn.cls, meth))
            if own:
                return own, f"self.{meth}"
            bound = [
                k
                for cls, m in CALLBACK_BINDINGS.get(meth, ())
                for k in self.by_class.get((cls, m), ())
            ]
            return bound, f"self.{meth} (callback)"
        if isinstance(recv, ast.Attribute):
            leaf = recv.attr
        cls = RECEIVER_CLASS.get(leaf or "")
        if cls:
            return self.by_class.get((cls, meth), []), f"{leaf}.{meth}"
        if meth in SKIP_METHODS:
            return [], meth
        cands = self.by_name.get(meth, [])
        return (cands, meth) if len(cands) == 1 else ([], meth)

    # ----------------------------------------------------------- scanning
    def _scan(self, fn: _Func) -> None:
        body = getattr(fn.node, "body", [])
        self._walk_body(body, frozenset(), fn)

    def _walk_body(self, body, held, fn):
        for stmt in body:
            held = self._walk_stmt(stmt, held, fn)
        return held

    def _walk_stmt(self, stmt, held, fn):
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            # nested defs execute later; scan them with empty held context
            # (their closure may outlive the enclosing with-block) AND with
            # the current one (they may run inline) — conservative: current
            self._walk_body(getattr(stmt, "body", []), held, fn)
            return held
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            inner = held
            for item in stmt.items:
                self._scan_expr(item.context_expr, inner, fn)
                lock = self._resolve_lock_expr(item.context_expr, fn)
                if lock:
                    fn.acquires.append((lock, inner, item.context_expr.lineno
                                        if hasattr(item.context_expr, "lineno")
                                        else stmt.lineno))
                    inner = inner | {lock}
            self._walk_body(stmt.body, inner, fn)
            return held  # the with-block released its locks
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter, held, fn)
            held = self._walk_body(stmt.body, held, fn)
            return self._walk_body(stmt.orelse, held, fn)
        if isinstance(stmt, ast.While):
            self._scan_expr(stmt.test, held, fn)
            held = self._walk_body(stmt.body, held, fn)
            return self._walk_body(stmt.orelse, held, fn)
        if isinstance(stmt, ast.If):
            self._scan_expr(stmt.test, held, fn)
            a = self._walk_body(stmt.body, held, fn)
            b = self._walk_body(stmt.orelse, held, fn)
            return a | b
        if isinstance(stmt, ast.Try):
            h = self._walk_body(stmt.body, held, fn)
            for handler in stmt.handlers:
                h |= self._walk_body(handler.body, held, fn)
            h |= self._walk_body(stmt.orelse, h, fn)
            return self._walk_body(stmt.finalbody, h, fn)
        # plain statement: scan its expressions for calls/acquire/release
        return self._scan_stmt_exprs(stmt, held, fn)

    def _scan_stmt_exprs(self, stmt, held, fn):
        for node in ast.walk(stmt):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func) or ""
            leaf = callee.split(".")[-1]
            if leaf == "enter_context" and node.args:
                lock = self._resolve_lock_expr(node.args[0], fn)
                if lock:
                    fn.acquires.append((lock, held, node.lineno))
                    held = held | {lock}
                    continue
            if leaf == "acquire" and isinstance(node.func, ast.Attribute):
                lock = self._resolve_lock(node.func.value, fn)
                if lock:
                    fn.acquires.append((lock, held, node.lineno))
                    held = held | {lock}
                    continue
            if leaf == "release" and isinstance(node.func, ast.Attribute):
                lock = self._resolve_lock(node.func.value, fn)
                if lock:
                    held = held - {lock}
                    continue
            callees, label = self._resolve_call(node, fn)
            if callees:
                fn.calls.append((callees, held, node.lineno, label))
        return held

    def _scan_expr(self, expr, held, fn):
        if expr is not None:
            self._scan_stmt_exprs(ast.Expr(value=expr), held, fn)

    def _resolve_lock_expr(self, expr, fn):
        if isinstance(expr, ast.Call):
            return None  # ExitStack(), Condition(...) etc.
        return self._resolve_lock(expr, fn)

    # --------------------------------------------------------- fixed point
    def _fixed_point(self) -> None:
        for fn in self.funcs.values():
            fn.trans = {lock for lock, _h, _l in fn.acquires}
        changed = True
        while changed:
            changed = False
            for fn in self.funcs.values():
                for callees, _held, _line, _label in fn.calls:
                    for key in callees:
                        extra = self.funcs[key].trans - fn.trans
                        if extra:
                            fn.trans |= extra
                            changed = True

    # -------------------------------------------------------------- edges
    def edges(self):
        """Yield (held, acquired, path, line, scope, via)."""
        for fn in self.funcs.values():
            scope = fn.key.split(":", 1)[1]
            for lock, held, line in fn.acquires:
                for h in held:
                    yield h, lock, fn.path, line, scope, None
            for callees, held, line, label in fn.calls:
                if not held:
                    continue
                for key in callees:
                    for lock in self.funcs[key].trans:
                        for h in held:
                            yield h, lock, fn.path, line, scope, label

    def check(self) -> list[Finding]:
        out = []
        seen: set[tuple] = set()
        graph: dict[str, set[str]] = {}
        provenance: dict[tuple[str, str], tuple] = {}
        for h, a, path, line, scope, via in self.edges():
            graph.setdefault(h, set()).add(a)
            provenance.setdefault((h, a), (path, line, scope, via))
            if h == a:
                ok = a in REENTRANT
            else:
                ok = RANKS[h] < RANKS[a]
            if ok:
                continue
            key = (h, a, scope)
            if key in seen:
                continue
            seen.add(key)
            via_txt = f" via call to {via}" if via else ""
            if h == a:
                msg = (
                    f"possible self-deadlock: {scope} may re-acquire "
                    f"non-reentrant {a!r}{via_txt}"
                )
            else:
                msg = (
                    f"lock-rank inversion: {scope} acquires {a!r} (rank "
                    f"{RANKS[a]}) while holding {h!r} (rank {RANKS[h]})"
                    f"{via_txt}"
                )
            out.append(
                Finding(
                    rule="lock-order",
                    path=path,
                    line=line,
                    scope=scope,
                    message=msg,
                    token=f"{h}->{a}",
                )
            )
        out += self._cycles(graph)
        return out

    def _cycles(self, graph: dict[str, set[str]]) -> list[Finding]:
        out = []
        state: dict[str, int] = {}
        stack: list[str] = []
        reported: set[frozenset] = set()

        def dfs(node):
            state[node] = 1
            stack.append(node)
            for nxt in sorted(graph.get(node, ())):
                if nxt == node:
                    continue  # reentrant self-edges are rank-checked above
                if state.get(nxt, 0) == 1:
                    cyc = stack[stack.index(nxt):] + [nxt]
                    key = frozenset(cyc)
                    if key not in reported:
                        reported.add(key)
                        out.append(
                            Finding(
                                rule="lock-cycle",
                                path="<lock-graph>",
                                line=0,
                                scope="<graph>",
                                message="lock acquisition cycle: "
                                + " -> ".join(cyc),
                                token="|".join(sorted(key)),
                            )
                        )
                elif state.get(nxt, 0) == 0:
                    dfs(nxt)
            stack.pop()
            state[node] = 2

        for node in sorted(graph):
            if state.get(node, 0) == 0:
                dfs(node)
        return out


def run_lockgraph(files: list[SourceFile]) -> list[Finding]:
    return LockGraph(files).check()
