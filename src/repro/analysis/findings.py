"""Finding records + the ratchet baseline.

A finding is one rule violation at one source location.  Its
**fingerprint** deliberately excludes line numbers — ``rule | path |
enclosing scope | detail token`` — so unrelated edits above a legacy
finding don't churn the baseline, while moving the offending code to a
new function *does* (at which point it should be fixed, not re-blessed).

The baseline (``analysis_baseline.json``) is a **ratchet**: every entry
must carry a human-written justification, new findings always fail the
gate, and entries whose finding no longer exists are reported as stale
(so the file only ever shrinks).  ``scripts/analyze.py --update-baseline``
rewrites it, preserving justifications for surviving fingerprints.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field

BASELINE_SCHEMA = "analysis_baseline/v1"


@dataclass(frozen=True)
class Finding:
    rule: str       # rule id, e.g. "resource-leak"
    path: str       # repo-relative posix path
    line: int       # 1-based; informational only (not fingerprinted)
    scope: str      # dotted enclosing scope, e.g. "IngestPool._run_batch"
    message: str    # human-readable description
    token: str = ""  # rule-chosen stable detail (symbol name, lock pair…)

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}|{self.path}|{self.scope}|{self.token}"

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


@dataclass
class BaselineResult:
    new: list[Finding] = field(default_factory=list)        # fail the gate
    suppressed: list[Finding] = field(default_factory=list)  # baselined
    stale: list[str] = field(default_factory=list)  # fingerprints gone


def load_baseline(path: str) -> dict[str, str]:
    """Return {fingerprint: justification}.  Missing file → empty."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    if data.get("schema") != BASELINE_SCHEMA:
        raise ValueError(
            f"{path}: expected schema {BASELINE_SCHEMA!r}, "
            f"got {data.get('schema')!r}"
        )
    out: dict[str, str] = {}
    for entry in data.get("findings", []):
        just = entry.get("justification", "").strip()
        if not just:
            raise ValueError(
                f"{path}: baseline entry {entry.get('fingerprint')!r} has "
                "no justification — every ratcheted finding must say why "
                "it is acceptable"
            )
        out[entry["fingerprint"]] = just
    return out


def save_baseline(path: str, findings: list[Finding],
                  justifications: dict[str, str]) -> None:
    entries = [
        {
            "fingerprint": f.fingerprint,
            "rule": f.rule,
            "path": f.path,
            "justification": justifications.get(
                f.fingerprint, "TODO: justify or fix"
            ),
        }
        for f in sorted(findings, key=lambda f: f.fingerprint)
    ]
    with open(path, "w") as f:
        json.dump(
            {"schema": BASELINE_SCHEMA, "findings": entries}, f, indent=2
        )
        f.write("\n")


def apply_baseline(findings: list[Finding],
                   baseline: dict[str, str]) -> BaselineResult:
    res = BaselineResult()
    seen: set[str] = set()
    for f in findings:
        seen.add(f.fingerprint)
        if f.fingerprint in baseline:
            res.suppressed.append(f)
        else:
            res.new.append(f)
    res.stale = sorted(fp for fp in baseline if fp not in seen)
    return res
