"""AST lint rules distilled from this repo's own bug history.

Every rule here is a shipped bug turned into a machine check:

- ``resource-leak``    — the PR 3 / PR 6 NpzFile-fd leaks: a resource
  factory (``np.load``/``open``/``os.fdopen``/…) whose handle is neither
  context-managed, ``enter_context``-ed, stored on ``self`` (object
  lifetime), nor ``.close()``-d in the same scope.
- ``fsync-order``      — the ``atomic_savez`` contract: ``os.replace``
  publishing a temp-built path must fsync the payload *before* the
  rename and the directory *after* it (crash-consistency of PR 6's
  recovery plane).  Skipped for test files.
- ``cv-wait``          — ``Condition.wait`` outside a ``while``-predicate
  loop (spurious wakeups turn a missed predicate into a hang — the
  enqueue-vs-close wedge class).
- ``thread-daemon``    — serving-plane ``threading.Thread`` without
  ``daemon=True``: a wedged worker must never block interpreter exit.
  Skipped for test files (tests join their threads explicitly).
- ``test-sleep``       — ``time.sleep`` in ``tests/``: the suite's
  zero-sleep discipline (deterministic interleavings come from
  failpoints and events, not timing).
- ``bare-except``      — ``except:`` anywhere (swallows KeyboardInterrupt
  and the witness's LockOrderError alike).
- ``swallowed-oserror``— an ``except OSError: pass/continue`` in a
  durability module; legitimate cleanup sites are ratcheted in
  ``analysis_baseline.json`` with per-site justifications.
- ``failpoint-*``      — every ``faults.hit`` site name must be a member
  of ``faults.SITES`` (declared exactly once), every member must have a
  live site, and every member must be referenced by at least one test.

All rules are stdlib-``ast`` only.  See ANALYSIS.md for the catalogue
and how to add a rule.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field

from repro.analysis.findings import Finding

RESOURCE_FACTORIES = {
    "open",
    "io.open",
    "os.fdopen",
    "np.load",
    "numpy.load",
    "gzip.open",
    "bz2.open",
    "lzma.open",
}

# .wait() receivers assumed to be Conditions unless the file assigns them
# threading.Event(); file-local `threading.Condition(...)` assignments
# extend this set.
COND_NAME_HINTS = {"cv", "_cv", "cond", "condition"}

SWALLOWED_EXCS = {
    "OSError",
    "IOError",
    "EnvironmentError",
    "FileNotFoundError",
    "PermissionError",
    "InterruptedError",
}

# modules whose error handling guards on-disk state
DURABILITY_BASENAMES = {
    "workers.py",
    "stream.py",
    "checkpoint.py",
    "tenant.py",
    "scrub.py",
    "faults.py",
}


@dataclass
class SourceFile:
    path: str          # repo-relative posix path
    tree: ast.Module
    is_test: bool
    source: str = ""

    @classmethod
    def parse(cls, path: str, source: str, is_test: bool | None = None):
        if is_test is None:
            parts = path.replace(os.sep, "/").split("/")
            is_test = "tests" in parts or os.path.basename(path).startswith(
                "test_"
            )
        return cls(
            path=path.replace(os.sep, "/"),
            tree=ast.parse(source, filename=path),
            is_test=is_test,
            source=source,
        )


def _dotted(node: ast.AST) -> str | None:
    """'np.load' for Attribute chains over Names, 'open' for Names."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _receiver_leaf(node: ast.AST) -> str | None:
    """Last segment before the method: 'cv' for ``self.pool.cv.wait``."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _iter_local(node: ast.AST, *, into_defs: bool = False):
    """Walk descendants without crossing into nested def/class bodies."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not into_defs and isinstance(
            n, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef, ast.Lambda)
        ):
            continue
        stack.extend(ast.iter_child_nodes(n))


def _scopes(tree: ast.Module):
    """Yield (scope_name, scope_node) for the module and every def."""
    yield "<module>", tree

    def rec(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                name = f"{prefix}{child.name}"
                yield name, child
                yield from rec(child, f"{name}.")
            elif isinstance(child, ast.ClassDef):
                yield from rec(child, f"{prefix}{child.name}.")
            else:
                yield from rec(child, prefix)

    yield from rec(tree, "")


@dataclass
class _FileFacts:
    cond_names: set[str] = field(default_factory=set)
    event_names: set[str] = field(default_factory=set)
    from_time_sleep: bool = False
    from_threading_thread: bool = False


def _file_facts(sf: SourceFile) -> _FileFacts:
    facts = _FileFacts()
    for node in ast.walk(sf.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            callee = _dotted(node.value.func)
            names = {
                _receiver_leaf(t)
                for t in node.targets
                if isinstance(t, (ast.Name, ast.Attribute))
            }
            names.discard(None)
            if callee in ("threading.Condition", "Condition"):
                facts.cond_names |= names
            elif callee in ("threading.Event", "Event"):
                facts.event_names |= names
        elif isinstance(node, ast.ImportFrom):
            if node.module == "time":
                if any(a.name == "sleep" for a in node.names):
                    facts.from_time_sleep = True
            if node.module == "threading":
                if any(a.name == "Thread" for a in node.names):
                    facts.from_threading_thread = True
    return facts


def _managed_calls(scope: ast.AST) -> set[int]:
    """ids() of Call nodes whose handle is lifetime-managed in scope."""
    managed: set[int] = set()
    for node in _iter_local(scope):
        if isinstance(node, (ast.With, ast.AsyncWith)):
            for item in node.items:
                if isinstance(item.context_expr, ast.Call):
                    managed.add(id(item.context_expr))
        elif isinstance(node, ast.Call):
            callee = _dotted(node.func)
            if callee and callee.split(".")[-1] == "enter_context":
                for arg in node.args:
                    if isinstance(arg, ast.Call):
                        managed.add(id(arg))
    return managed


def _closed_names(scope: ast.AST) -> set[str]:
    out: set[str] = set()
    for node in _iter_local(scope):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "close"
            and isinstance(node.func.value, ast.Name)
        ):
            out.add(node.func.value.id)
    return out


def _assignment_target(scope: ast.AST, call: ast.Call):
    """(kind, name) where kind ∈ {'name', 'self-attr', None}."""
    for node in _iter_local(scope):
        if isinstance(node, ast.Assign) and node.value is call:
            t = node.targets[0]
            if isinstance(t, ast.Name):
                return "name", t.id
            if isinstance(t, ast.Attribute) and isinstance(
                t.value, ast.Name
            ) and t.value.id == "self":
                return "self-attr", t.attr
        elif isinstance(node, ast.withitem) and node.context_expr is call:
            return "with", None
    return None, None


# --------------------------------------------------------------------- rules


def _rule_resource_leak(sf: SourceFile) -> list[Finding]:
    out = []
    for scope_name, scope in _scopes(sf.tree):
        managed = _managed_calls(scope)
        closed = _closed_names(scope)
        for node in _iter_local(scope):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if callee not in RESOURCE_FACTORIES:
                continue
            if id(node) in managed:
                continue
            kind, name = _assignment_target(scope, node)
            if kind == "self-attr":
                continue  # object-lifetime handle (closed by the owner)
            if kind == "name" and name in closed:
                continue
            out.append(
                Finding(
                    rule="resource-leak",
                    path=sf.path,
                    line=node.lineno,
                    scope=scope_name,
                    message=(
                        f"{callee}(...) handle is never context-managed or "
                        f"closed in this scope — fd/NpzFile leak"
                    ),
                    token=callee,
                )
            )
    return out


def _temp_path_names(scope: ast.AST) -> set[str]:
    temps: set[str] = set()
    for node in _iter_local(scope):
        if not isinstance(node, ast.Assign) or not isinstance(
            node.value, ast.Call
        ):
            continue
        callee = _dotted(node.value.func) or ""
        t = node.targets[0]
        if callee.endswith("mkstemp") and isinstance(t, ast.Tuple):
            if len(t.elts) == 2 and isinstance(t.elts[1], ast.Name):
                temps.add(t.elts[1].id)
        elif callee.endswith(("mkdtemp", "mktemp")) and isinstance(
            t, ast.Name
        ):
            temps.add(t.id)
        elif (
            callee.endswith("path.join")
            and node.value.args
            and isinstance(node.value.args[0], ast.Name)
            and node.value.args[0].id in temps
            and isinstance(t, ast.Name)
        ):
            temps.add(t.id)  # paths derived from a temp dir
    return temps


def _is_temp_derived(node: ast.AST, temps: set[str]) -> bool:
    if isinstance(node, ast.Name):
        return node.id in temps
    if isinstance(node, ast.Call):
        callee = _dotted(node.func) or ""
        if callee.endswith("path.join") and node.args:
            return _is_temp_derived(node.args[0], temps)
    return False


def _rule_fsync_order(sf: SourceFile) -> list[Finding]:
    if sf.is_test:
        return []
    out = []
    for scope_name, scope in _scopes(sf.tree):
        temps = _temp_path_names(scope)
        if not temps:
            continue
        fsync_lines = []  # lines with os.fsync(...) or *fsync* helper calls
        replaces = []
        for node in _iter_local(scope):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func) or ""
            if "fsync" in callee.split(".")[-1]:
                fsync_lines.append(node.lineno)
            elif callee in ("os.replace", "os.rename") and node.args:
                if _is_temp_derived(node.args[0], temps):
                    replaces.append(node)
        for idx, rep in enumerate(replaces):
            tok = f"replace#{idx}"
            if not any(ln < rep.lineno for ln in fsync_lines):
                out.append(
                    Finding(
                        rule="fsync-order",
                        path=sf.path,
                        line=rep.lineno,
                        scope=scope_name,
                        message=(
                            "os.replace publishes a temp-built path with no "
                            "fsync of the payload before the rename — a "
                            "crash can publish torn data (atomic_savez "
                            "contract)"
                        ),
                        token=f"{tok}:pre-fsync",
                    )
                )
            if not any(ln > rep.lineno for ln in fsync_lines):
                out.append(
                    Finding(
                        rule="fsync-order",
                        path=sf.path,
                        line=rep.lineno,
                        scope=scope_name,
                        message=(
                            "no directory fsync after os.replace — the "
                            "rename itself may not survive a crash "
                            "(atomic_savez contract)"
                        ),
                        token=f"{tok}:dir-fsync",
                    )
                )
    return out


def _rule_cv_wait(sf: SourceFile, facts: _FileFacts) -> list[Finding]:
    cond_names = (facts.cond_names | COND_NAME_HINTS) - facts.event_names
    out = []

    def rec(node, scope_name, while_depth):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                rec(child, f"{scope_name}.{child.name}"
                    if scope_name != "<module>" else child.name, 0)
                continue
            if isinstance(child, ast.ClassDef):
                rec(child, child.name if scope_name == "<module>"
                    else f"{scope_name}.{child.name}", while_depth)
                continue
            depth = while_depth + (1 if isinstance(child, ast.While) else 0)
            if (
                isinstance(child, ast.Call)
                and isinstance(child.func, ast.Attribute)
                and child.func.attr == "wait"
                and _receiver_leaf(child.func.value) in cond_names
                and while_depth == 0
            ):
                out.append(
                    Finding(
                        rule="cv-wait",
                        path=sf.path,
                        line=child.lineno,
                        scope=scope_name,
                        message=(
                            "Condition.wait outside a while-predicate loop "
                            "— spurious wakeup turns a missed predicate "
                            "into a lost signal or hang"
                        ),
                        token=_receiver_leaf(child.func.value) or "cv",
                    )
                )
            rec(child, scope_name, depth)

    rec(sf.tree, "<module>", 0)
    return out


def _rule_thread_daemon(sf: SourceFile, facts: _FileFacts) -> list[Finding]:
    if sf.is_test:
        return []
    out = []
    thread_callees = {"threading.Thread"}
    if facts.from_threading_thread:
        thread_callees.add("Thread")
    for scope_name, scope in _scopes(sf.tree):
        daemon_assigned = any(
            isinstance(n, ast.Assign)
            and isinstance(n.targets[0], ast.Attribute)
            and n.targets[0].attr == "daemon"
            for n in _iter_local(scope)
        )
        for node in _iter_local(scope):
            if not isinstance(node, ast.Call):
                continue
            if _dotted(node.func) not in thread_callees:
                continue
            has_daemon = any(
                kw.arg == "daemon"
                and isinstance(kw.value, ast.Constant)
                and kw.value.value is True
                for kw in node.keywords
            )
            if not has_daemon and not daemon_assigned:
                out.append(
                    Finding(
                        rule="thread-daemon",
                        path=sf.path,
                        line=node.lineno,
                        scope=scope_name,
                        message=(
                            "serving-plane Thread without daemon=True — a "
                            "wedged worker would block interpreter exit"
                        ),
                        token="Thread",
                    )
                )
    return out


def _rule_test_sleep(sf: SourceFile, facts: _FileFacts) -> list[Finding]:
    if not sf.is_test:
        return []
    out = []
    for scope_name, scope in _scopes(sf.tree):
        for node in _iter_local(scope):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func)
            if callee == "time.sleep" or (
                callee == "sleep" and facts.from_time_sleep
            ):
                out.append(
                    Finding(
                        rule="test-sleep",
                        path=sf.path,
                        line=node.lineno,
                        scope=scope_name,
                        message=(
                            "time.sleep in a test — interleavings must come "
                            "from failpoints/events, not wall-clock timing "
                            "(zero-sleep discipline)"
                        ),
                        token="sleep",
                    )
                )
    return out


def _exc_names(node: ast.AST | None) -> set[str]:
    if node is None:
        return set()
    if isinstance(node, ast.Tuple):
        return set().union(*(_exc_names(e) for e in node.elts))
    name = _dotted(node)
    return {name.split(".")[-1]} if name else set()


def _rule_excepts(sf: SourceFile) -> list[Finding]:
    out = []
    durability = os.path.basename(sf.path) in DURABILITY_BASENAMES
    for scope_name, scope in _scopes(sf.tree):
        idx = 0
        for node in _iter_local(scope):
            if not isinstance(node, ast.ExceptHandler):
                continue
            if node.type is None:
                out.append(
                    Finding(
                        rule="bare-except",
                        path=sf.path,
                        line=node.lineno,
                        scope=scope_name,
                        message="bare except: swallows KeyboardInterrupt, "
                        "LockOrderError and every other invariant signal",
                        token=f"bare#{idx}",
                    )
                )
                idx += 1
                continue
            names = _exc_names(node.type)
            if (
                durability
                and not sf.is_test
                and names
                and names <= SWALLOWED_EXCS
                and all(
                    isinstance(s, (ast.Pass, ast.Continue)) for s in node.body
                )
            ):
                out.append(
                    Finding(
                        rule="swallowed-oserror",
                        path=sf.path,
                        line=node.lineno,
                        scope=scope_name,
                        message=(
                            f"except {'/'.join(sorted(names))}: "
                            f"{'pass' if isinstance(node.body[0], ast.Pass) else 'continue'}"
                            " in a durability path — a swallowed disk error "
                            "here can silently drop acked data (justify in "
                            "the ratchet baseline or handle it)"
                        ),
                        token=f"{'+'.join(sorted(names))}#{idx}",
                    )
                )
                idx += 1
    return out


# ------------------------------------------------------- failpoint project rule


def _string_constants(tree: ast.AST) -> set[str]:
    return {
        n.value
        for n in ast.walk(tree)
        if isinstance(n, ast.Constant) and isinstance(n.value, str)
    }


def run_failpoint_rule(files: list[SourceFile]) -> list[Finding]:
    declared: dict[str, tuple[str, int]] = {}  # name -> (path, line)
    declarations = 0
    hits: list[tuple[str, SourceFile, int]] = []
    injects: list[tuple[str, SourceFile, int]] = []
    test_strings: set[str] = set()
    sites_file = None

    for sf in files:
        if sf.is_test:
            test_strings |= _string_constants(sf.tree)
        base = os.path.basename(sf.path)
        for node in ast.walk(sf.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = _dotted(node.func) or ""
            leaf = callee.split(".")[-1]
            if (
                isinstance(node.func, ast.Name)
                or callee.startswith("faults.")
            ) and leaf in ("hit", "inject"):
                if base == "faults.py":
                    continue  # the registry's own internals
                if node.args and isinstance(node.args[0], ast.Constant):
                    name = node.args[0].value
                    (hits if leaf == "hit" else injects).append(
                        (name, sf, node.lineno)
                    )
        if base == "faults.py" and not sf.is_test:
            for node in ast.walk(sf.tree):
                if (
                    isinstance(node, ast.Assign)
                    and isinstance(node.targets[0], ast.Name)
                    and node.targets[0].id == "SITES"
                ):
                    declarations += 1
                    sites_file = sf
                    for c in ast.walk(node.value):
                        if isinstance(c, ast.Constant) and isinstance(
                            c.value, str
                        ):
                            declared[c.value] = (sf.path, node.lineno)

    out: list[Finding] = []
    if sites_file is None:
        return out  # no registry in the analyzed set — nothing to check
    if declarations != 1:
        out.append(
            Finding(
                rule="failpoint-declared-once",
                path=sites_file.path,
                line=1,
                scope="<module>",
                message=f"faults.SITES assigned {declarations} times — the "
                "site registry must be declared exactly once",
                token="SITES",
            )
        )
    hit_names = {name for name, _sf, _ln in hits if not _sf.is_test}
    for name, sf, line in hits + injects:
        if name not in declared:
            out.append(
                Finding(
                    rule="failpoint-undeclared",
                    path=sf.path,
                    line=line,
                    scope="<module>",
                    message=f"failpoint {name!r} is not declared in "
                    "faults.SITES (typo, or add it to the registry)",
                    token=name,
                )
            )
    for name, (path, line) in sorted(declared.items()):
        if name not in hit_names:
            out.append(
                Finding(
                    rule="failpoint-unused",
                    path=path,
                    line=line,
                    scope="<module>",
                    message=f"declared failpoint {name!r} has no live "
                    "faults.hit site in src",
                    token=name,
                )
            )
        elif name not in test_strings:
            out.append(
                Finding(
                    rule="failpoint-untested",
                    path=path,
                    line=line,
                    scope="<module>",
                    message=f"failpoint {name!r} is referenced by no test — "
                    "an injectable fault nobody injects",
                    token=name,
                )
            )
    return out


# ---------------------------------------------------------------- entry point


def run_lint(files: list[SourceFile]) -> list[Finding]:
    findings: list[Finding] = []
    for sf in files:
        facts = _file_facts(sf)
        findings += _rule_resource_leak(sf)
        findings += _rule_fsync_order(sf)
        findings += _rule_cv_wait(sf, facts)
        findings += _rule_thread_daemon(sf, facts)
        findings += _rule_test_sleep(sf, facts)
        findings += _rule_excepts(sf)
    findings += run_failpoint_rule(files)
    return _dedupe_fingerprints(findings)


def _dedupe_fingerprints(findings: list[Finding]) -> list[Finding]:
    """Suffix repeated fingerprints so each finding ratchets separately."""
    seen: dict[str, int] = {}
    out = []
    for f in findings:
        n = seen.get(f.fingerprint, 0)
        seen[f.fingerprint] = n + 1
        if n:
            f = Finding(
                rule=f.rule, path=f.path, line=f.line, scope=f.scope,
                message=f.message, token=f"{f.token}~{n}",
            )
        out.append(f)
    return out
