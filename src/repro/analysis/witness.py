"""Runtime lock-discipline witness: rank-ordered lock wrappers.

The serving plane's documented lock hierarchy (ANALYSIS.md) is only a
comment until something *checks* it.  This module provides drop-in
wrappers — :class:`OrderedLock` / :class:`OrderedRLock` — that carry a
numeric **rank** (and, for same-rank families like the per-tenant store
locks, a sortable **key**) and assert on every ``acquire`` that the
calling thread only ever acquires locks in strictly increasing rank
order (ascending key order within a rank).  A violation raises
:class:`LockOrderError` immediately, at the acquisition site, with both
sides of the inversion named — instead of a once-a-month deadlock in CI.

Cost model (the reason this can wrap *production* locks, not test
doubles): the witness is **disarmed by default** and the disarmed
``acquire``/``release`` fast path is a single module-global read
(``if _ARMED:``) on top of the raw lock call.  ``benchmarks/faults.py``
measures and schema-gates that claim next to the failpoint overhead.
The whole test suite arms it via ``REPRO_LOCK_WITNESS=1`` (see
``tests/conftest.py``), so every lock acquisition the suite drives —
including the chaos lane's crash/retry interleavings — doubles as a
hierarchy check.

Deliberately stdlib-only and import-free of ``repro.core`` (core modules
import *this*; a cycle here would be an import-order landmine).
"""
from __future__ import annotations

import threading

__all__ = [
    "LockOrderError",
    "OrderedLock",
    "OrderedRLock",
    "RANKS",
    "arm",
    "disarm",
    "armed",
    "acquire_count",
    "reset_acquire_count",
    "held_locks",
]


class LockOrderError(AssertionError):
    """A thread acquired a lock out of the documented rank order."""


# The documented hierarchy (see ANALYSIS.md for the diagram and the
# rationale per edge).  Lower rank = acquired first (outermost).  Gaps
# are deliberate — future locks slot in without renumbering.
RANKS: dict[str, int] = {
    "repl.replicator": 2,       # Replicator._lock (ship serialization)
    "repl.follower": 4,         # Follower._lock (tail/apply state)
    "repl.dirgate": 5,          # per-follower-dir send-vs-fence gate
    "subs.cv": 6,               # SubscriptionPlane.cv's underlying RLock
    "subs.queue": 8,            # Subscription.cv (delivery queue, key=id)
    "registry._lock": 10,       # TenantRegistry._lock (RLock)
    "store._lock": 20,          # HistogramStore._lock (RLock, key=tenant)
    "pool.ingest_mutex": 30,    # IngestPool.ingest_mutex
    "pool._state_lock": 32,     # IngestPool._state_lock
    "pool.cv": 34,              # IngestPool.cv's underlying RLock
    "wal._commit_lock": 40,     # WriteAheadLog._commit_lock (group commit)
    "wal._lock": 42,            # WriteAheadLog._lock (append/rotate)
    "arena._lock": 50,          # NodeArena._lock (RLock)
    "tree.counters": 60,        # interval_tree._COUNTER_LOCK
    "faults.registry": 70,      # faults._LOCK (failpoint table)
}

_ARMED = False  # the disarmed fast path is this one module-global read

# armed-mode acquisition counter (read by benchmarks/faults.py to bound
# the witness overhead analytically; GIL-coarse increments are fine for
# that purpose)
_ACQUIRES = 0


class _Held(threading.local):
    def __init__(self):
        # acquisition-ordered stack of (lock, rank, key, name)
        self.stack: list[tuple[object, int, object, str]] = []


_TLS = _Held()


def arm() -> None:
    """Enable order checking globally (all wrapped locks, all threads)."""
    global _ARMED
    _ARMED = True


def disarm() -> None:
    global _ARMED
    _ARMED = False


def armed() -> bool:
    return _ARMED


def acquire_count() -> int:
    return _ACQUIRES


def reset_acquire_count() -> None:
    global _ACQUIRES
    _ACQUIRES = 0


def held_locks() -> list[str]:
    """Names of wrapped locks the calling thread holds (debug aid)."""
    return [name for _l, _r, _k, name in _TLS.stack]


class _OrderedBase:
    """Shared acquire/release/order-check machinery.

    Also speaks :class:`threading.Condition`'s custom-lock protocol
    (``_is_owned`` / ``_release_save`` / ``_acquire_restore``) so an
    ``OrderedRLock`` can back a Condition: ``wait()`` transparently pops
    the witness stack while the lock is released and re-checks order on
    re-acquisition.
    """

    _reentrant = False

    __slots__ = ("_raw", "name", "rank", "key")

    def __init__(self, name: str, rank: int | None = None, key=None):
        if rank is None:
            rank = RANKS[name]
        self._raw = self._make_raw()
        self.name = name
        self.rank = rank
        self.key = key  # sortable id within a same-rank family (or None)

    @staticmethod
    def _make_raw():
        raise NotImplementedError

    # ------------------------------------------------------------- checks
    def _check_order(self) -> None:
        held = _TLS.stack
        if not held:
            return
        if any(entry[0] is self for entry in held):
            if self._reentrant:
                return  # re-entering a lock we own is always fine
            raise LockOrderError(
                f"self-deadlock: thread already holds non-reentrant "
                f"{self.name!r} (held: {held_locks()})"
            )
        top = max(entry[1] for entry in held)
        if self.rank > top:
            return
        if self.rank == top:
            same = [e for e in held if e[1] == self.rank]
            if self.key is not None and all(
                e[2] is not None and e[2] < self.key for e in same
            ):
                return  # ascending-key acquisition within the rank family
            raise LockOrderError(
                f"same-rank order violation: acquiring {self.name!r} "
                f"(rank {self.rank}, key {self.key!r}) while holding "
                f"{[(e[3], e[2]) for e in same]!r} — same-rank locks must "
                f"be keyed and taken in ascending key order"
            )
        raise LockOrderError(
            f"lock-rank inversion: acquiring {self.name!r} (rank "
            f"{self.rank}) while holding rank {top} (held: "
            f"{held_locks()}) — see ANALYSIS.md lock hierarchy"
        )

    # ---------------------------------------------------------- lock API
    def acquire(self, blocking: bool = True, timeout: float = -1):
        if _ARMED:
            self._check_order()
        got = self._raw.acquire(blocking, timeout)
        if got and _ARMED:
            global _ACQUIRES
            _ACQUIRES += 1
            _TLS.stack.append((self, self.rank, self.key, self.name))
        return got

    def release(self):
        self._raw.release()
        if _ARMED:
            held = _TLS.stack
            for i in range(len(held) - 1, -1, -1):
                if held[i][0] is self:
                    del held[i]
                    break

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc):
        self.release()
        return False

    def locked(self) -> bool:
        return self._raw.locked()

    def __repr__(self):
        return (
            f"<{type(self).__name__} {self.name!r} rank={self.rank} "
            f"key={self.key!r}>"
        )

    # ----------------------- threading.Condition custom-lock protocol
    def _is_owned(self):
        return self._raw._is_owned()

    def _release_save(self):
        # Condition.wait releases the lock fully (all recursion levels);
        # pop every witness entry for this lock and remember how many so
        # _acquire_restore can rebalance the stack.
        depth = 0
        if _ARMED:
            held = _TLS.stack
            for i in range(len(held) - 1, -1, -1):
                if held[i][0] is self:
                    del held[i]
                    depth += 1
        return (self._raw._release_save(), depth)

    def _acquire_restore(self, saved):
        state, depth = saved
        if _ARMED:
            self._check_order()
        self._raw._acquire_restore(state)
        if _ARMED:
            global _ACQUIRES
            _ACQUIRES += 1
            entry = (self, self.rank, self.key, self.name)
            _TLS.stack.extend([entry] * max(depth, 1))


class OrderedLock(_OrderedBase):
    """Rank-checked wrapper over :class:`threading.Lock`."""

    _reentrant = False
    __slots__ = ()

    @staticmethod
    def _make_raw():
        return threading.Lock()


class OrderedRLock(_OrderedBase):
    """Rank-checked wrapper over :class:`threading.RLock`.

    Usable as the backing lock of a :class:`threading.Condition`.
    """

    _reentrant = True
    __slots__ = ()

    @staticmethod
    def _make_raw():
        return threading.RLock()

    def locked(self):  # RLock grew .locked() only in 3.12 — emulate
        if self._raw.acquire(blocking=False):
            self._raw.release()
            return False
        return True
