"""Shared model primitives: params-with-specs, norms, RoPE, chunked ops.

Parameters are plain nested dicts of ``jnp`` arrays.  Every init function
returns a mirrored tree of *logical sharding specs* — tuples of logical axis
names (``"embed"``, ``"heads"``, ``"mlp"``, ``"experts"``, ``"vocab"``,
``"layers"``, ``None``) that ``repro.sharding`` later maps to mesh
``PartitionSpec`` per (mesh, shape-kind, arch divisibility).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Params = Any  # nested dict of arrays
Specs = Any  # mirrored nested dict of logical-axis tuples


@dataclasses.dataclass
class Init:
    """Sequential PRNG splitter for parameter initialization.

    ``abstract=True`` yields ShapeDtypeStructs instead of arrays — the
    dry-run path builds 400B-parameter trees without allocating a byte.
    """

    key: jax.Array
    abstract: bool = False

    def take(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def normal(self, shape, scale, dtype=jnp.float32):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return (
            jax.random.normal(self.take(), shape, dtype=jnp.float32) * scale
        ).astype(dtype)

    def dense(self, shape, *, fan_in=None, dtype=jnp.float32):
        fan_in = fan_in if fan_in is not None else shape[0]
        return self.normal(shape, 1.0 / np.sqrt(fan_in), dtype)

    def zeros(self, shape, dtype=jnp.float32):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    def ones(self, shape, dtype=jnp.float32):
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.ones(shape, dtype)

    def const(self, fn, shape, dtype=jnp.float32):
        """Materialize ``fn()`` normally; a struct when abstract."""
        if self.abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return fn().astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layer_norm(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5
) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Chunked softmax attention core (pure-JAX flash-style; bounds the memory
# roofline term: logits only ever materialize one (q_chunk × S) block)
# ---------------------------------------------------------------------------


def chunked_attention(
    q: jax.Array,  # (B, Sq, Hkv, G, hd)
    k: jax.Array,  # (B, Skv, Hkv, hd)
    v: jax.Array,  # (B, Skv, Hkv, hd)
    *,
    causal: bool,
    window: int | None = None,
    logit_cap: float | None = None,
    q_chunk: int = 512,
    kv_positions: jax.Array | None = None,
    q_offset: int = 0,
) -> jax.Array:
    """Grouped-query attention, scanned over query chunks.

    Returns ``(B, Sq, Hkv, G, hd)``.  ``window`` masks keys more than
    ``window`` positions behind the query (sliding-window local attention);
    ``logit_cap`` is gemma-2 tanh softcapping.
    """
    B, Sq, Hkv, G, hd = q.shape
    Skv = k.shape[1]
    scale = hd**-0.5
    q_chunk = min(q_chunk, Sq)
    Sq_orig = Sq
    pad = (-Sq) % q_chunk
    if pad:  # non-divisible query lengths (whisper's 1500 frames): pad+slice
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        Sq = Sq + pad
    n_chunks = Sq // q_chunk
    kv_pos = (
        kv_positions
        if kv_positions is not None
        else jnp.arange(Skv, dtype=jnp.int32)
    )

    qc = q.reshape(B, n_chunks, q_chunk, Hkv, G, hd)
    qc = jnp.moveaxis(qc, 1, 0)  # (n_chunks, B, C, Hkv, G, hd)

    def one_chunk(args):
        qi, chunk_idx = args
        logits = jnp.einsum(
            "bckgh,bskh->bkgcs", qi.astype(jnp.float32), k.astype(jnp.float32)
        ) * scale
        logits = softcap(logits, logit_cap)
        q_pos = q_offset + chunk_idx * q_chunk + jnp.arange(q_chunk)
        mask = jnp.ones((q_chunk, Skv), dtype=bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        logits = jnp.where(mask[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bkgcs,bskh->bckgh", probs, v.astype(jnp.float32))
        return out.astype(q.dtype)

    outs = jax.lax.map(
        one_chunk, (qc, jnp.arange(n_chunks, dtype=jnp.int32))
    )  # (n_chunks, B, C, Hkv, G, hd)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq, Hkv, G, hd)
    return out[:, :Sq_orig]


def decode_attention(
    q: jax.Array,  # (B, 1, Hkv, G, hd)
    k_cache: jax.Array,  # (B, Smax, Hkv, hd)
    v_cache: jax.Array,
    position: jax.Array,  # scalar int — index of the token being produced
    *,
    window: int | None = None,
    logit_cap: float | None = None,
) -> jax.Array:
    """Single-token attention against a (possibly seq-sharded) KV cache."""
    Smax = k_cache.shape[1]
    hd = q.shape[-1]
    scale = hd**-0.5
    logits = jnp.einsum(
        "bokgh,bskh->bkgos", q.astype(jnp.float32), k_cache.astype(jnp.float32)
    ) * scale
    logits = softcap(logits, logit_cap)
    kv_pos = jnp.arange(Smax, dtype=jnp.int32)
    mask = kv_pos <= position
    if window is not None:
        mask &= kv_pos > position - window
    logits = jnp.where(mask[None, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgos,bskh->bokgh", probs, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked cross-entropy (avoids materializing (B, S, V) logits at once)
# ---------------------------------------------------------------------------


def chunked_softmax_xent(
    hidden: jax.Array,  # (B, S, d) final hidden states
    unemb: jax.Array,  # (V, d) unembedding
    targets: jax.Array,  # (B, S) int32
    mask: jax.Array,  # (B, S) {0,1}
    *,
    s_chunk: int = 512,
    final_cap: float | None = None,
) -> jax.Array:
    """Mean CE loss, scanned over sequence chunks of the logit computation."""
    B, S, d = hidden.shape
    s_chunk = min(s_chunk, S)
    n = S // s_chunk
    assert S % s_chunk == 0
    hc = jnp.moveaxis(hidden.reshape(B, n, s_chunk, d), 1, 0)
    tc = jnp.moveaxis(targets.reshape(B, n, s_chunk), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, n, s_chunk), 1, 0)

    def one(args):
        h, t, m = args
        logits = jnp.einsum(
            "bsd,vd->bsv", h.astype(jnp.float32), unemb.astype(jnp.float32)
        )
        logits = softcap(logits, final_cap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, t[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * m
        return jnp.sum(nll), jnp.sum(m)

    losses, counts = jax.lax.map(one, (hc, tc, mc))
    return jnp.sum(losses) / jnp.maximum(jnp.sum(counts), 1.0)


def sinusoidal_positions(length: int, dim: int) -> jax.Array:
    pos = np.arange(length)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    emb = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(emb, dtype=jnp.float32)
