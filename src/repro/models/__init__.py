"""Model zoo: pattern-assembled transformers/SSMs for the assigned archs."""
from repro.models.model import (
    init_model,
    loss_fn,
    forward_hidden,
    init_cache,
    prefill,
    decode_step,
)

__all__ = [
    "init_model", "loss_fn", "forward_hidden",
    "init_cache", "prefill", "decode_step",
]
