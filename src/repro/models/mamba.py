"""Mamba (S6) mixer for the Jamba hybrid — chunked parallel scan.

TPU adaptation: the CUDA selective-scan kernel's job is to avoid
materializing the ``(B, S, d_inner, d_state)`` decay tensor in HBM.  We get
the same effect structurally: an outer ``lax.scan`` over sequence chunks
(carrying the ``(B, d_inner, d_state)`` state) with an *associative* scan
inside each chunk, so only ``(B, chunk, d_inner, d_state)`` exists
transiently — sized to stay VMEM/HBM-friendly via ``cfg.mamba_chunk`` —
while keeping ``O(log chunk)`` depth within a chunk.

Recurrence: ``h_t = a_t ⊙ h_{t-1} + b_t`` with ``a_t = exp(Δ_t A)``,
``b_t = Δ_t B_t x_t``; combine((a₁,b₁),(a₂,b₂)) = (a₁a₂, a₂b₁ + b₂).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Init


def init_mamba(cfg, rng: Init):
    d = cfg.d_model
    d_in = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    K = cfg.mamba_d_conv
    dt_rank = max(d // 16, 1)
    params = {
        "wx": rng.dense((d, d_in)),
        "wz": rng.dense((d, d_in)),
        "conv_w": rng.dense((d_in, K), fan_in=K),
        "conv_b": rng.zeros((d_in,)),
        "w_dbc": rng.dense((d_in, dt_rank + 2 * n)),
        "w_dt": rng.dense((dt_rank, d_in)),
        "dt_bias": rng.normal((d_in,), 0.1),
        "A_log": rng.const(
            lambda: jnp.log(
                jnp.broadcast_to(
                    jnp.arange(1, n + 1, dtype=jnp.float32)[None, :],
                    (d_in, n),
                )
            ),
            (d_in, n),
        ),
        "D": rng.ones((d_in,)),
        "w_out": rng.dense((d_in, d), fan_in=d_in),
    }
    specs = {
        "wx": ("embed", "mamba_inner"),
        "wz": ("embed", "mamba_inner"),
        "conv_w": ("mamba_inner", None),
        "conv_b": ("mamba_inner",),
        "w_dbc": ("mamba_inner", None),
        "w_dt": (None, "mamba_inner"),
        "dt_bias": ("mamba_inner",),
        "A_log": ("mamba_inner", None),
        "D": ("mamba_inner",),
        "w_out": ("mamba_inner", "embed"),
    }
    return params, specs


def _split_dbc(cfg, dbc):
    d = cfg.d_model
    dt_rank = max(d // 16, 1)
    n = cfg.mamba_d_state
    return (
        dbc[..., :dt_rank],
        dbc[..., dt_rank : dt_rank + n],
        dbc[..., dt_rank + n :],
    )


def _causal_depthwise_conv(x, w, b):
    """x: (B, S, d_in); w: (d_in, K) — causal depthwise conv."""
    B, S, d_in = x.shape
    K = w.shape[-1]
    xt = jnp.moveaxis(x, 1, 2)  # (B, d_in, S)
    out = jax.lax.conv_general_dilated(
        xt,
        w[:, None, :],  # (d_in, 1, K)
        window_strides=(1,),
        padding=[(K - 1, 0)],
        feature_group_count=d_in,
        dimension_numbers=("NCH", "OIH", "NCH"),
    )
    return jnp.moveaxis(out, 1, 2) + b


def _ssm_inputs(cfg, p, x1, dt_chunkable=True):
    """Common Δ/B/C/A computation. x1: (..., d_in) post-conv activations."""
    dt_x, Bc, Cc = _split_dbc(cfg, jnp.einsum(
        "...i,ij->...j", x1, p["w_dbc"].astype(x1.dtype)
    ))
    dt = jax.nn.softplus(
        jnp.einsum("...r,ri->...i", dt_x, p["w_dt"].astype(x1.dtype)).astype(
            jnp.float32
        )
        + p["dt_bias"]
    )
    A = -jnp.exp(p["A_log"])  # (d_in, n) fp32
    return dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32), A


def apply_mamba(
    cfg, p, x: jax.Array, h0: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) → (y, final_state).  S must divide by mamba_chunk."""
    B, S, d = x.shape
    d_in = cfg.mamba_expand * d
    n = cfg.mamba_d_state
    dt_ = x.dtype
    c = min(cfg.mamba_chunk, S)
    n_full = S // c
    rem = S - n_full * c

    x1 = jnp.einsum("bsd,di->bsi", x, p["wx"].astype(dt_))
    z = jnp.einsum("bsd,di->bsi", x, p["wz"].astype(dt_))
    x1 = jax.nn.silu(_causal_depthwise_conv(x1, p["conv_w"].astype(dt_), p["conv_b"].astype(dt_)))

    if h0 is None:
        h0 = jnp.zeros((B, d_in, n), jnp.float32)

    # §Perf P6: the (B,c,d_in,n) decay/scan tensors dominate jamba's memory
    # traffic; exponentials/products stay fp32-computed but can be *stored*
    # and scanned in bf16 (carry h and the final state remain fp32).
    scan_dt = (
        jnp.bfloat16 if cfg.mamba_scan_dtype == "bfloat16" else jnp.float32
    )

    def chunk(h, x1_c):
        dt, Bc, Cc, A = _ssm_inputs(cfg, p, x1_c)  # dt (B,c,d_in)
        da = jnp.exp(dt[..., None] * A).astype(scan_dt)  # (B,c,d_in,n)
        db = (
            dt[..., None] * Bc[:, :, None, :]
            * x1_c.astype(jnp.float32)[..., None]
        ).astype(scan_dt)

        def combine(l, r):
            al, bl = l
            ar, br = r
            return al * ar, ar * bl + br

        cum_a, cum_b = jax.lax.associative_scan(combine, (da, db), axis=1)
        h_all = (
            cum_a.astype(jnp.float32) * h[:, None]
            + cum_b.astype(jnp.float32)
        )  # (B,c,d_in,n) fp32
        y = jnp.einsum("bcin,bcn->bci", h_all, Cc) + p["D"] * x1_c.astype(
            jnp.float32
        )
        return h_all[:, -1], y.astype(dt_)

    if cfg.remat_policy != "none":
        # Inner remat: without it, a rematerialized *layer* backward holds
        # every chunk's (B, c, d_in, n) fp32 decay/scan intermediates alive
        # at once (jamba train_4k: 253 GB/dev temp).  Recomputing per chunk
        # bounds the live set to one chunk — §Perf iteration 3.
        chunk = jax.checkpoint(chunk)

    ys = []
    h_final = h0
    if n_full:
        x1c = jnp.moveaxis(
            x1[:, : n_full * c].reshape(B, n_full, c, d_in), 1, 0
        )
        h_final, yc = jax.lax.scan(chunk, h0, x1c)
        ys.append(jnp.moveaxis(yc, 0, 1).reshape(B, n_full * c, d_in))
    if rem:  # non-divisible tail (e.g. prefill of S+1 tokens)
        h_final, y_tail = chunk(h_final, x1[:, -rem:])
        ys.append(y_tail)
    y = jnp.concatenate(ys, axis=1)
    y = y * jax.nn.silu(z)
    return jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(dt_)), h_final


def init_mamba_cache(cfg, batch: int, dtype=jnp.bfloat16):
    d_in = cfg.mamba_expand * cfg.d_model
    cache = {
        "h": jnp.zeros((batch, d_in, cfg.mamba_d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.mamba_d_conv - 1, d_in), dtype),
    }
    specs = {
        "h": ("batch_kv", "mamba_inner", None),
        "conv": ("batch_kv", None, "mamba_inner"),
    }
    return cache, specs


def decode_mamba_step(cfg, p, x: jax.Array, cache: dict):
    """x: (B, 1, d) → (y, new_cache). O(1) state update — no KV growth."""
    B = x.shape[0]
    dt_ = x.dtype
    x1 = jnp.einsum("bsd,di->bsi", x, p["wx"].astype(dt_))[:, 0]
    z = jnp.einsum("bsd,di->bsi", x, p["wz"].astype(dt_))[:, 0]
    window = jnp.concatenate([cache["conv"], x1[:, None].astype(cache["conv"].dtype)], axis=1)
    conv_out = (
        jnp.einsum("bki,ik->bi", window.astype(dt_), p["conv_w"].astype(dt_))
        + p["conv_b"].astype(dt_)
    )
    x1 = jax.nn.silu(conv_out)
    dt, Bc, Cc, A = _ssm_inputs(cfg, p, x1)
    da = jnp.exp(dt[..., None] * A)  # (B, d_in, n)
    db = dt[..., None] * Bc[:, None, :] * x1.astype(jnp.float32)[..., None]
    h = da * cache["h"] + db
    y = jnp.einsum("bin,bn->bi", h, Cc) + p["D"] * x1.astype(jnp.float32)
    y = y.astype(dt_) * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, p["w_out"].astype(dt_))[:, None]
    return out, {"h": h, "conv": window[:, 1:]}
