"""Grouped-query attention with the assigned archs' variants.

Covers: GQA/MQA/MHA (kv groups), RoPE, qk-norm (qwen3), tanh logit
softcapping (gemma-2), sliding-window local layers (gemma-2 local/global
alternation), cross-attention (whisper decoder), and single-token decode
against a sharded KV cache.

Weights are stored head-major — ``(d, H, hd)`` — so the logical "heads"
axis shards over the TP mesh axis whenever divisible and falls back to
replication otherwise (smollm's 9 heads; every kv=8 arch on a 16-way TP
axis keeps KV replicated and relies on sequence-sharding of the KV *cache*
for memory — DESIGN.md §7).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import (
    Init,
    apply_rope,
    chunked_attention,
    decode_attention,
    rms_norm,
)


def init_attention(cfg, rng: Init) -> tuple[Any, Any]:
    d, Hq, Hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    params = {
        "wq": rng.dense((d, Hq, hd)),
        "wk": rng.dense((d, Hkv, hd)),
        "wv": rng.dense((d, Hkv, hd)),
        "wo": rng.dense((Hq, hd, d), fan_in=Hq * hd),
    }
    specs = {
        "wq": ("embed", "heads", None),
        "wk": ("embed", "kv_heads", None),
        "wv": ("embed", "kv_heads", None),
        "wo": ("heads", None, "embed"),
    }
    if cfg.qk_norm:
        params["q_norm"] = rng.zeros((hd,))
        params["k_norm"] = rng.zeros((hd,))
        specs["q_norm"] = (None,)
        specs["k_norm"] = (None,)
    return params, specs


def _project_qkv(cfg, p, x, positions, *, rope: bool = True):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def apply_attention(
    cfg,
    p,
    x: jax.Array,  # (B, S, d)
    positions: jax.Array,  # (S,)
    *,
    kind: str = "global",  # "global" | "local"
    causal: bool = True,
    rope: bool = True,
) -> jax.Array:
    """Full-sequence attention (train / prefill)."""
    B, S, d = x.shape
    Hkv, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    q, k, v = _project_qkv(cfg, p, x, positions, rope=rope)
    q = q.reshape(B, S, Hkv, G, cfg.head_dim)
    window = cfg.sliding_window if kind == "local" else None
    out = chunked_attention(
        q,
        k,
        v,
        causal=causal,
        window=window,
        logit_cap=cfg.attn_softcap,
        q_chunk=cfg.attn_q_chunk,
    )
    out = out.reshape(B, S, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def apply_cross_attention(
    cfg,
    p,
    x: jax.Array,  # (B, S, d) decoder stream
    enc_kv: tuple[jax.Array, jax.Array] | None,
    enc_states: jax.Array | None = None,
) -> jax.Array:
    """Whisper-style cross-attention: KV from encoder states (no RoPE)."""
    B, S, d = x.shape
    Hkv, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if enc_kv is None:
        k = jnp.einsum("bsd,dhk->bshk", enc_states, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_states, p["wv"].astype(dt))
    else:
        k, v = enc_kv
    q = q.reshape(B, S, Hkv, G, cfg.head_dim)
    out = chunked_attention(
        q, k, v, causal=False, q_chunk=cfg.attn_q_chunk
    )
    out = out.reshape(B, S, cfg.num_heads, cfg.head_dim)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


def encode_cross_kv(cfg, p, enc_states: jax.Array):
    """Precompute cross-attention KV once per request (prefill-time)."""
    dt = enc_states.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_states, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_states, p["wv"].astype(dt))
    return k, v


def init_kv_cache(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16):
    shape = (batch, max_seq, cfg.num_kv_heads, cfg.head_dim)
    cache = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    specs = {
        "k": ("batch_kv", "kv_seq", "kv_heads_cache", None),
        "v": ("batch_kv", "kv_seq", "kv_heads_cache", None),
    }
    return cache, specs


def prefill_attention(
    cfg, p, x, positions, cache, *, kind: str = "global"
):
    """Full-sequence attention that also fills the KV cache [0, S)."""
    B, S, d = x.shape
    Hkv, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    q, k, v = _project_qkv(cfg, p, x, positions, rope=cfg.use_rope)
    qg = q.reshape(B, S, Hkv, G, cfg.head_dim)
    window = cfg.sliding_window if kind == "local" else None
    out = chunked_attention(
        qg, k, v, causal=True, window=window,
        logit_cap=cfg.attn_softcap, q_chunk=cfg.attn_q_chunk,
    ).reshape(B, S, cfg.num_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    new_cache = {
        "k": jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)
        ),
        "v": jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)
        ),
    }
    return y, new_cache


def decode_attention_step(
    cfg,
    p,
    x: jax.Array,  # (B, 1, d)
    position: jax.Array,  # scalar: index of the query token
    cache: dict,
    *,
    kind: str = "global",
):
    """One-token decode: project, write cache at `position`, attend."""
    B = x.shape[0]
    Hkv, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    q, k, v = _project_qkv(cfg, p, x, position[None], rope=cfg.use_rope)
    k_cache = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, position, 0, 0)
    )
    v_cache = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, position, 0, 0)
    )
    qg = q.reshape(B, 1, Hkv, G, cfg.head_dim)
    window = cfg.sliding_window if kind == "local" else None
    out = decode_attention(
        qg, k_cache, v_cache, position,
        window=window, logit_cap=cfg.attn_softcap,
    ).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, {"k": k_cache, "v": v_cache}
