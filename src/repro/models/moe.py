"""Mixture-of-Experts layer — GShard-style capacity dispatch, EP-shardable.

Top-k routing with grouped capacity: the sequence is split into groups of
``moe_group_size`` tokens; each group dispatches at most
``C = group·k·capacity_factor/E`` tokens per expert through one-hot einsum
dispatch/combine tensors (no data-dependent gathers — XLA SPMD turns the
expert-sharded einsums into the all-to-all pattern).  Experts shard over the
TP/EP mesh axis (16e → 1/device, 128e → 8/device on a 16-way axis).

Histogram integration (DESIGN.md §3): the router-logit distribution is
summarized with the paper's mergeable histograms (per-device exact summary,
merged across the mesh) so operators can watch routing collapse and pick
capacity factors from measured logit quantiles instead of folklore.  Gated
by ``cfg.moe_telemetry`` because it adds a small all-gather per layer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Init


def init_moe(cfg, rng: Init):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    params = {
        "w_router": rng.dense((d, E)),
        "w_gate": rng.dense((E, d, f)),
        "w_up": rng.dense((E, d, f)),
        "w_down": rng.dense((E, f, d), fan_in=f),
    }
    specs = {
        "w_router": ("embed", None),
        "w_gate": ("experts", "embed", "expert_mlp"),
        "w_up": ("experts", "embed", "expert_mlp"),
        "w_down": ("experts", "expert_mlp", "embed"),
    }
    return params, specs


def _pin_experts(t: jax.Array, rules, axis: int) -> jax.Array:
    """Constrain the expert dim of an activation to the EP mesh axis.

    Without this, GSPMD propagation has no opinion on the dispatch output's
    expert dim and resolves the expert einsum by ALL-GATHERING the expert
    weights over the EP axis (measured: 21.5 GB f32 per matrix per layer on
    llama4 — §Perf iteration 4).  One constraint keeps expert compute local.
    """
    if rules is None:
        return t
    spec = rules(
        tuple("experts_act" if i == axis else None for i in range(t.ndim))
    )
    if spec is None or all(s is None for s in spec):
        return t
    return jax.lax.with_sharding_constraint(t, spec)


def apply_moe(cfg, p, x: jax.Array, rules=None) -> tuple[jax.Array, dict]:
    """x: (B, S, d) → (y, aux) with load-balance and router-z losses."""
    B0, S0, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_token
    # Decode (S==1): fold the batch into the sequence/group role, otherwise
    # capacity degenerates to one slot per expert per token — an E/k×
    # overcompute.  Grouping across the batch restores C ≈ B·k·cf/E.
    decode_fold = S0 == 1 and B0 > 1
    if decode_fold:
        x = x.reshape(1, B0, d)
    B, S, _ = x.shape
    g = min(cfg.moe_group_size, S)
    S_real = S
    pad = (-S) % g
    if pad:  # pad to whole groups; pads sit at the end so real tokens'
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))  # queue positions are
        S = S + pad  # unchanged; their gates are masked to zero below.
    nG = S // g
    valid = (jnp.arange(S) < S_real).reshape(1, nG, g)
    cap = max(int(g * k * cfg.moe_capacity_factor / E), 1)
    dt = x.dtype

    xg = x.reshape(B, nG, g, d)
    logits = jnp.einsum(
        "bngd,de->bnge", xg.astype(jnp.float32), p["w_router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)  # (B, nG, g, k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (B, nG, g, k, E)
    onehot = onehot * valid[..., None, None].astype(jnp.float32)
    # Position of each (token, slot) inside its expert queue: slots are
    # priority-ordered (slot 0 first), tokens in sequence order within slot.
    flat = jnp.moveaxis(onehot, 3, 2).reshape(B, nG, k * g, E)
    pos_flat = jnp.cumsum(flat, axis=2) - flat  # exclusive prefix count
    pos = jnp.moveaxis(pos_flat.reshape(B, nG, k, g, E), 2, 3)  # (B,nG,g,k,E)
    within = (pos < cap).astype(jnp.float32)
    kept = onehot * within

    combine_w = gate[..., None] * kept  # (B, nG, g, k, E)
    pos_idx = jnp.sum(pos * onehot, axis=-1)  # (B, nG, g, k)
    onehot_c = jax.nn.one_hot(pos_idx, cap, dtype=jnp.float32)  # (B,nG,g,k,C)
    combine = jnp.einsum("bngke,bngkc->bngec", combine_w, onehot_c)
    dispatch = (combine > 0).astype(dt)  # (B, nG, g, E, C)

    x_e = jnp.einsum("bngec,bngd->bnecd", dispatch, xg.astype(dt))
    x_e = _pin_experts(x_e, rules, axis=2)  # (B, nG, E, C, d)
    h_g = jnp.einsum("bnecd,edf->bnecf", x_e, p["w_gate"].astype(dt))
    h_u = jnp.einsum("bnecd,edf->bnecf", x_e, p["w_up"].astype(dt))
    h = jax.nn.silu(h_g) * h_u
    h = _pin_experts(h, rules, axis=2)
    y_e = jnp.einsum("bnecf,efd->bnecd", h, p["w_down"].astype(dt))
    y_e = _pin_experts(y_e, rules, axis=2)
    y = jnp.einsum("bngec,bnecd->bngd", combine.astype(dt), y_e)

    # --- aux losses (GShard load-balance + router z-loss) -----------------
    me = jnp.mean(probs, axis=(0, 1, 2))  # (E,) mean router prob
    ce = jnp.mean(
        jnp.sum(onehot[..., 0, :] if k == 1 else onehot.sum(3), axis=-2)
        / g,
        axis=(0, 1),
    )  # (E,) fraction of tokens routed
    aux = {
        "moe_load_balance": E * jnp.sum(me * ce),
        "moe_router_z": jnp.mean(
            jax.nn.logsumexp(logits, axis=-1) ** 2
        ),
        "moe_drop_fraction": 1.0
        - jnp.sum(kept) / jnp.maximum(jnp.sum(onehot), 1.0),
    }
    y = y.reshape(B, S, d)[:, :S_real]
    return y.reshape(B0, S0, d), aux
