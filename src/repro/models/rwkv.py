"""RWKV-6 "Finch" block: data-dependent decay linear recurrence.

Faithful pieces: per-channel data-dependent decay ``w_t = exp(-exp(ŵ_t))``
with a LoRA on the shifted input, the bonus-``u`` current-token term, the
matrix-valued per-head state ``S ∈ (K × V)``, token-shift mixing on every
projection, squared-ReLU channel-mix.  Simplification (noted in DESIGN.md):
token-shift uses static learned mix coefficients instead of RWKV-6's
data-dependent ddlerp — the recurrence (the part that matters for systems
behaviour: O(1) state, attention-free) is exact.

Train path scans sequence chunks; within a chunk the recurrence runs
step-by-step (the chunked-GLA matmul formulation is the documented perf
upgrade — see EXPERIMENTS.md §Perf).  Decode is a single O(1) state update,
which is why rwkv6 runs the ``long_500k`` shape that full-attention archs
skip.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Init, layer_norm


def init_rwkv_time_mix(cfg, rng: Init):
    d = cfg.d_model
    H, hd = cfg.rwkv_heads, cfg.d_model // cfg.rwkv_heads
    lora = cfg.rwkv_decay_lora
    params = {
        "mix_r": rng.normal((d,), 0.2),
        "mix_k": rng.normal((d,), 0.2),
        "mix_v": rng.normal((d,), 0.2),
        "mix_g": rng.normal((d,), 0.2),
        "mix_w": rng.normal((d,), 0.2),
        "w0": rng.normal((d,), 0.5),
        "wA": rng.dense((d, lora)),
        "wB": rng.dense((lora, d), fan_in=lora),
        "u": rng.normal((H, hd), 0.5),
        "wr": rng.dense((d, d)),
        "wk": rng.dense((d, d)),
        "wv": rng.dense((d, d)),
        "wg": rng.dense((d, d)),
        "wo": rng.dense((d, d)),
        "ln_g": rng.ones((d,)),
        "ln_b": rng.zeros((d,)),
    }
    specs = {
        "mix_r": (None,), "mix_k": (None,), "mix_v": (None,),
        "mix_g": (None,), "mix_w": (None,),
        "w0": (None,), "wA": ("embed", None), "wB": (None, "embed"),
        "u": ("rwkv_heads", None),
        "wr": ("embed", "rwkv_proj"),
        "wk": ("embed", "rwkv_proj"),
        "wv": ("embed", "rwkv_proj"),
        "wg": ("embed", "rwkv_proj"),
        "wo": ("rwkv_proj", "embed"),
        "ln_g": (None,), "ln_b": (None,),
    }
    return params, specs


def init_rwkv_channel_mix(cfg, rng: Init):
    d, f = cfg.d_model, cfg.d_ff
    params = {
        "mix_k": rng.normal((d,), 0.2),
        "mix_r": rng.normal((d,), 0.2),
        "wk": rng.dense((d, f)),
        "wr": rng.dense((d, d)),
        "wv": rng.dense((f, d), fan_in=f),
    }
    specs = {
        "mix_k": (None,), "mix_r": (None,),
        "wk": ("embed", "mlp"),
        "wr": ("embed", None),
        "wv": ("mlp", "embed"),
    }
    return params, specs


def _shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """Token shift: x_{t-1} (zeros / carried state at t=0)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _mix(x, x_prev, mu):
    return x + (x_prev - x) * jax.nn.sigmoid(mu).astype(x.dtype)


def _time_mix_projections(cfg, p, x, x_prev):
    dt = x.dtype
    H, hd = cfg.rwkv_heads, cfg.d_model // cfg.rwkv_heads
    r = jnp.einsum("bsd,de->bse", _mix(x, x_prev, p["mix_r"]), p["wr"].astype(dt))
    k = jnp.einsum("bsd,de->bse", _mix(x, x_prev, p["mix_k"]), p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", _mix(x, x_prev, p["mix_v"]), p["wv"].astype(dt))
    g = jnp.einsum("bsd,de->bse", _mix(x, x_prev, p["mix_g"]), p["wg"].astype(dt))
    xw = _mix(x, x_prev, p["mix_w"])
    w_hat = p["w0"].astype(jnp.float32) + jnp.einsum(
        "bsd,dl,le->bse",
        jnp.tanh(xw.astype(jnp.float32)),
        p["wA"].astype(jnp.float32),
        p["wB"].astype(jnp.float32),
    )
    w = jnp.exp(-jnp.exp(w_hat))  # (B,S,d) data-dependent per-channel decay
    B, S, d = x.shape
    shp = (B, S, H, hd)
    return (r.reshape(shp), k.reshape(shp), v.reshape(shp), g, w.reshape(shp))


def apply_rwkv_time_mix(
    cfg, p, x: jax.Array, state: jax.Array | None = None,
    x_carry: jax.Array | None = None,
):
    """x: (B, S, d) → (y, (final_state, last_x))."""
    B, S, d = x.shape
    H, hd = cfg.rwkv_heads, d // cfg.rwkv_heads
    dt = x.dtype
    x_prev = _shift(x, x_carry)
    r, k, v, g, w = _time_mix_projections(cfg, p, x, x_prev)
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)
    u = p["u"].astype(jnp.float32)

    c = min(cfg.rwkv_chunk, S)
    n_full = S // c
    rem = S - n_full * c

    def chunk(S0, inp):
        rc_, kc_, vc_, wc_ = inp

        def step(S_, t):
            r_t, k_t, v_t, w_t = (
                rc_[:, t].astype(jnp.float32),
                kc_[:, t].astype(jnp.float32),
                vc_[:, t].astype(jnp.float32),
                wc_[:, t].astype(jnp.float32),
            )
            kv = k_t[..., :, None] * v_t[..., None, :]  # (B,H,K,V)
            y_t = jnp.einsum(
                "bhk,bhkv->bhv", r_t, S_ + u[None, :, :, None] * kv
            )
            S_next = w_t[..., :, None] * S_ + kv
            return S_next, y_t

        S1, ys = jax.lax.scan(step, S0, jnp.arange(inp[0].shape[1]))
        return S1, jnp.moveaxis(ys, 0, 1)  # (B,c,H,V)

    if cfg.remat_policy != "none":
        chunk = jax.checkpoint(chunk)  # bound live set to one chunk (§Perf)

    def to_chunks(a):  # head of the sequence as (nC, B, c, H, hd)
        return jnp.moveaxis(
            a[:, : n_full * c].reshape(B, n_full, c, H, hd), 1, 0
        )

    ys = []
    S_final = state
    if n_full:
        S_final, yc = jax.lax.scan(
            chunk, state, (to_chunks(r), to_chunks(k), to_chunks(v), to_chunks(w))
        )
        ys.append(jnp.moveaxis(yc, 0, 1).reshape(B, n_full * c, H, hd))
    if rem:  # non-divisible tail (e.g. prefill of S+1 tokens)
        S_final, y_tail = chunk(
            S_final,
            (r[:, -rem:], k[:, -rem:], v[:, -rem:], w[:, -rem:]),
        )
        ys.append(y_tail.reshape(B, rem, H, hd))
    y = jnp.concatenate(ys, axis=1).reshape(B, S, d).astype(dt)
    y = layer_norm(y, p["ln_g"], p["ln_b"])  # per-token group norm (H groups folded)
    y = y * jax.nn.silu(g)
    out = jnp.einsum("bsd,de->bse", y, p["wo"].astype(dt))
    return out, (S_final, x[:, -1:])


def apply_rwkv_channel_mix(
    cfg, p, x: jax.Array, x_carry: jax.Array | None = None
):
    dt = x.dtype
    x_prev = _shift(x, x_carry)
    k = jnp.einsum("bsd,df->bsf", _mix(x, x_prev, p["mix_k"]), p["wk"].astype(dt))
    r = jnp.einsum("bsd,de->bse", _mix(x, x_prev, p["mix_r"]), p["wr"].astype(dt))
    h = jnp.square(jax.nn.relu(k))
    out = jax.nn.sigmoid(r) * jnp.einsum("bsf,fd->bsd", h, p["wv"].astype(dt))
    return out, x[:, -1:]


def init_rwkv_cache(cfg, batch: int, dtype=jnp.bfloat16):
    d = cfg.d_model
    H, hd = cfg.rwkv_heads, d // cfg.rwkv_heads
    cache = {
        "S": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "x_tm": jnp.zeros((batch, 1, d), dtype),
        "x_cm": jnp.zeros((batch, 1, d), dtype),
    }
    specs = {
        "S": ("batch_kv", "rwkv_heads", None, None),
        "x_tm": ("batch_kv", None, None),
        "x_cm": ("batch_kv", None, None),
    }
    return cache, specs
