"""Feed-forward blocks: SwiGLU (llama-family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Init


def init_mlp(cfg, rng: Init, *, gated: bool = True):
    d, f = cfg.d_model, cfg.d_ff
    if gated:
        params = {
            "w_gate": rng.dense((d, f)),
            "w_up": rng.dense((d, f)),
            "w_down": rng.dense((f, d), fan_in=f),
        }
        specs = {
            "w_gate": ("embed", "mlp"),
            "w_up": ("embed", "mlp"),
            "w_down": ("mlp", "embed"),
        }
    else:
        params = {
            "w_up": rng.dense((d, f)),
            "b_up": rng.zeros((f,)),
            "w_down": rng.dense((f, d), fan_in=f),
            "b_down": rng.zeros((d,)),
        }
        specs = {
            "w_up": ("embed", "mlp"),
            "b_up": ("mlp",),
            "w_down": ("mlp", "embed"),
            "b_down": (None,),
        }
    return params, specs


def apply_mlp(cfg, p, x: jax.Array, *, gated: bool = True) -> jax.Array:
    dt = x.dtype
    if gated:
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
        h = jax.nn.silu(g) * u
        return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt)) + p["b_up"].astype(dt)
    h = jax.nn.gelu(h)
    return (
        jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))
        + p["b_down"].astype(dt)
    )
