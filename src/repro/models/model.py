"""Model assembly: pattern-based layer stacks, scanned over repeats.

A model is a repeating ``pattern`` of layer kinds (configs/base.py) whose
parameters are stacked over ``repeats`` and executed with ``jax.lax.scan``
— HLO size is depth-independent, which keeps 80 dry-run compiles tractable
and is how production JAX frameworks (MaxText et al.) structure deep stacks.

Three entry points:
  * ``loss_fn``      — training forward + chunked CE (+ MoE aux losses)
  * ``prefill``      — forward that fills the decode caches, returns last logits
  * ``decode_step``  — one-token step against the caches (KV / SSM state)

``rules`` is a callable mapping logical-axis tuples to ``PartitionSpec``
(or ``None`` off-mesh); activation sharding constraints are applied at the
residual-stream boundaries only — XLA SPMD propagates the rest.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import mamba as mamba_mod
from repro.models import mlp as mlp_mod
from repro.models import moe as moe_mod
from repro.models import rwkv as rwkv_mod
from repro.models.common import (
    Init,
    chunked_softmax_xent,
    layer_norm,
    rms_norm,
    sinusoidal_positions,
    softcap,
)

Rules = Callable[[tuple], Any] | None


def _wsc(x, rules: Rules, logical: tuple):
    if rules is None:
        return x
    spec = rules(logical)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def _compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Norm helpers (rms vs layer-norm per config)
# ---------------------------------------------------------------------------


def _init_norm(cfg, rng: Init):
    if cfg.norm_type == "layernorm":
        return {"g": rng.ones((cfg.d_model,)), "b": rng.zeros((cfg.d_model,))}, {
            "g": (None,), "b": (None,)
        }
    return {"g": rng.zeros((cfg.d_model,))}, {"g": (None,)}


def _apply_norm(cfg, p, x):
    if cfg.norm_type == "layernorm":
        return layer_norm(x, p["g"], p["b"], cfg.norm_eps)
    return rms_norm(x, p["g"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Per-layer init / apply
# ---------------------------------------------------------------------------


def _parse(kind: str) -> list[str]:
    return kind.split("+")


def init_layer(
    cfg: ModelConfig, kind: str, key, abstract: bool = False
) -> tuple[Any, Any]:
    rng = Init(key, abstract=abstract)
    parts = _parse(kind)
    params: dict = {}
    specs: dict = {}
    if kind == "rwkv":
        params["ln1"], specs["ln1"] = _init_norm(cfg, rng)
        params["tm"], specs["tm"] = rwkv_mod.init_rwkv_time_mix(cfg, rng)
        params["ln2"], specs["ln2"] = _init_norm(cfg, rng)
        params["cm"], specs["cm"] = rwkv_mod.init_rwkv_channel_mix(cfg, rng)
        return params, specs
    mixer = parts[0]
    params["ln1"], specs["ln1"] = _init_norm(cfg, rng)
    if mixer in ("attn", "local", "global"):
        params["mixer"], specs["mixer"] = attn_mod.init_attention(cfg, rng)
    elif mixer == "mamba":
        params["mixer"], specs["mixer"] = mamba_mod.init_mamba(cfg, rng)
    else:
        raise ValueError(mixer)
    if "cross" in parts:
        params["ln_x"], specs["ln_x"] = _init_norm(cfg, rng)
        params["cross"], specs["cross"] = attn_mod.init_attention(cfg, rng)
    params["ln2"], specs["ln2"] = _init_norm(cfg, rng)
    ffn = parts[-1]
    if ffn == "moe":
        params["ffn"], specs["ffn"] = moe_mod.init_moe(cfg, rng)
    else:
        params["ffn"], specs["ffn"] = mlp_mod.init_mlp(
            cfg, rng, gated=cfg.norm_type != "layernorm"
        )
    return params, specs


def apply_layer_train(
    cfg, kind, p, x, positions, enc_states=None, *, causal=True, rules=None
):
    """Pre-norm residual block. Returns (x, aux_losses)."""
    aux = {"moe_load_balance": 0.0, "moe_router_z": 0.0}
    if kind == "rwkv":
        h, _ = rwkv_mod.apply_rwkv_time_mix(cfg, p["tm"], _apply_norm(cfg, p["ln1"], x))
        x = x + h
        h, _ = rwkv_mod.apply_rwkv_channel_mix(cfg, p["cm"], _apply_norm(cfg, p["ln2"], x))
        return x + h, aux
    parts = _parse(kind)
    mixer = parts[0]
    h = _apply_norm(cfg, p["ln1"], x)
    if mixer == "mamba":
        h, _ = mamba_mod.apply_mamba(cfg, p["mixer"], h)
    else:
        h = attn_mod.apply_attention(
            cfg, p["mixer"], h, positions,
            kind="local" if mixer == "local" else "global",
            causal=causal, rope=cfg.use_rope,
        )
    x = x + h
    if "cross" in parts:
        h = attn_mod.apply_cross_attention(
            cfg, p["cross"], _apply_norm(cfg, p["ln_x"], x),
            enc_kv=None, enc_states=enc_states,
        )
        x = x + h
    h = _apply_norm(cfg, p["ln2"], x)
    if parts[-1] == "moe":
        h, moe_aux = moe_mod.apply_moe(cfg, p["ffn"], h, rules)
        aux["moe_load_balance"] = moe_aux["moe_load_balance"]
        aux["moe_router_z"] = moe_aux["moe_router_z"]
    else:
        h = mlp_mod.apply_mlp(cfg, p["ffn"], h, gated=cfg.norm_type != "layernorm")
    return x + h, aux


# ---------------------------------------------------------------------------
# Model init
# ---------------------------------------------------------------------------


def _stacked_blocks(cfg, key, pattern, repeats, abstract=False):
    blocks_p, blocks_s = [], []
    for i, kind in enumerate(pattern):
        pos_key = jax.random.fold_in(key, i)
        if abstract:
            single, spec = init_layer(cfg, kind, pos_key, abstract=True)
            stacked = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((repeats,) + s.shape, s.dtype),
                single,
            )
        else:
            keys = jax.random.split(pos_key, repeats)
            stacked = jax.vmap(
                lambda k, kind=kind: init_layer(cfg, kind, k)[0]
            )(keys)
            _, spec = init_layer(cfg, kind, pos_key, abstract=True)
        spec = jax.tree.map(
            lambda s: ("layers",) + tuple(s),
            spec,
            is_leaf=lambda s: isinstance(s, tuple),
        )
        blocks_p.append(stacked)
        blocks_s.append(spec)
    return blocks_p, blocks_s


def init_model(cfg: ModelConfig, key, abstract: bool = False) -> tuple[Any, Any]:
    rng = Init(key, abstract=abstract)
    params: dict = {}
    specs: dict = {}
    params["embed"] = rng.normal((cfg.vocab_size, cfg.d_model), 0.02)
    specs["embed"] = ("vocab", "embed")
    params["blocks"], specs["blocks"] = _stacked_blocks(
        cfg, rng.take(), cfg.pattern, cfg.repeats, abstract=abstract
    )
    params["final_norm"], specs["final_norm"] = _init_norm(cfg, rng)
    if not cfg.tie_embeddings:
        params["unembed"] = rng.normal((cfg.vocab_size, cfg.d_model), 0.02)
        specs["unembed"] = ("vocab", "embed")
    if cfg.is_encoder_decoder:
        enc_p, enc_s = _stacked_blocks(
            cfg, rng.take(), ("attn+mlp",), cfg.encoder_layers,
            abstract=abstract,
        )
        norm_p, norm_s = _init_norm(cfg, rng)
        params["encoder"] = {"blocks": enc_p, "final_norm": norm_p}
        specs["encoder"] = {"blocks": enc_s, "final_norm": norm_s}
    return params, specs


# ---------------------------------------------------------------------------
# Forward (train)
# ---------------------------------------------------------------------------


def _remat(cfg, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)


def _embed_tokens(cfg, params, tokens):
    dt = _compute_dtype(cfg)
    x = params["embed"].astype(dt)[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, dt)
    return x


def _run_encoder(cfg, params, frames, rules: Rules):
    """Whisper encoder over stub frame embeddings (B, S_enc, d)."""
    dt = _compute_dtype(cfg)
    S = frames.shape[1]
    x = frames.astype(dt) + sinusoidal_positions(S, cfg.d_model).astype(dt)
    x = _wsc(x, rules, ("act_batch", "enc_seq", None))
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, blk):
        h = carry
        step = _remat(cfg, functools.partial(
            apply_layer_train, cfg, "attn+mlp",
            positions=positions, causal=False,
        ))
        h, _ = step(blk[0], h)
        h = _wsc(h, rules, ("act_batch", "enc_seq", None))
        return h, None

    x, _ = jax.lax.scan(
        body, x, tuple(params["encoder"]["blocks"]),
        unroll=cfg.scan_unroll,
    )
    return _apply_norm(cfg, params["encoder"]["final_norm"], x)


def forward_hidden(cfg, params, batch, rules: Rules = None):
    """Shared train/eval forward → (final hidden (B,S,d), aux dict)."""
    dt = _compute_dtype(cfg)
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens)
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(dt), x], axis=1)
    if not cfg.use_rope:
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_states = None
    if cfg.is_encoder_decoder:
        enc_states = _run_encoder(cfg, params, batch["frames"], rules)
    x = _wsc(x, rules, ("act_batch", "act_seq", None))

    aux0 = {"moe_load_balance": jnp.float32(0), "moe_router_z": jnp.float32(0)}

    def body(carry, blk):
        h, aux = carry
        for i, kind in enumerate(cfg.pattern):
            step = _remat(cfg, functools.partial(
                apply_layer_train, cfg, kind,
                positions=positions, enc_states=enc_states, rules=rules,
            ))
            h, a = step(blk[i], h)
            aux = jax.tree.map(lambda t, u: t + u, aux, a)
        h = _wsc(h, rules, ("act_batch", "act_seq", None))
        return (h, aux), None

    (x, aux), _ = jax.lax.scan(
        body, (x, aux0), tuple(params["blocks"]), unroll=cfg.scan_unroll
    )
    x = _apply_norm(cfg, params["final_norm"], x)
    return x, aux


def loss_fn(cfg, params, batch, rules: Rules = None):
    """Mean CE + MoE aux losses. batch: tokens/targets/mask [+frontend]."""
    hidden, aux = forward_hidden(cfg, params, batch, rules)
    unemb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    ce = chunked_softmax_xent(
        hidden, unemb, batch["targets"], batch["mask"],
        s_chunk=cfg.loss_chunk, final_cap=cfg.final_softcap,
    )
    n_layers = cfg.repeats * max(sum(1 for k in cfg.pattern if "moe" in k), 1)
    lb = aux["moe_load_balance"] / n_layers
    zl = aux["moe_router_z"] / n_layers
    loss = ce + cfg.moe_aux_weight * lb + cfg.moe_z_weight * zl
    metrics = {"ce": ce, "moe_load_balance": lb, "moe_router_z": zl}
    return loss, metrics


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------


def init_layer_cache(cfg, kind, batch, max_seq, dtype=jnp.bfloat16):
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_cache(cfg, batch, dtype)
    parts = _parse(kind)
    cache, specs = {}, {}
    if parts[0] == "mamba":
        cache["ssm"], specs["ssm"] = mamba_mod.init_mamba_cache(cfg, batch, dtype)
    else:
        cache["kv"], specs["kv"] = attn_mod.init_kv_cache(cfg, batch, max_seq, dtype)
    if "cross" in parts:
        shape = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
        cache["cross"] = {
            "k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)
        }
        specs["cross"] = {
            "k": ("batch_kv", None, "kv_heads_cache", None),
            "v": ("batch_kv", None, "kv_heads_cache", None),
        }
    return cache, specs


def init_cache(
    cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
    abstract: bool = False,
):
    """Stacked (repeats, ...) caches per pattern position (+ carries)."""
    caches, specs = [], []
    for kind in cfg.pattern:
        if abstract:
            c, s = jax.eval_shape(
                lambda: init_layer_cache(cfg, kind, batch, max_seq, dtype)[0]
            ), init_layer_cache_specs(cfg, kind)
            c = jax.tree.map(
                lambda a: jax.ShapeDtypeStruct(
                    (cfg.repeats,) + a.shape, a.dtype
                ),
                c,
            )
        else:
            c, s = init_layer_cache(cfg, kind, batch, max_seq, dtype)
            c = jax.tree.map(
                lambda a: jnp.zeros((cfg.repeats,) + a.shape, a.dtype), c
            )
        s = jax.tree.map(
            lambda t: ("layers",) + tuple(t),
            s,
            is_leaf=lambda t: isinstance(t, tuple),
        )
        caches.append(c)
        specs.append(s)
    return tuple(caches), tuple(specs)


def init_layer_cache_specs(cfg, kind):
    """Cache spec tree without allocating (mirrors init_layer_cache)."""
    if kind == "rwkv":
        return {
            "S": ("batch_kv", "rwkv_heads", None, None),
            "x_tm": ("batch_kv", None, None),
            "x_cm": ("batch_kv", None, None),
        }
    parts = _parse(kind)
    specs = {}
    if parts[0] == "mamba":
        specs["ssm"] = {
            "h": ("batch_kv", "mamba_inner", None),
            "conv": ("batch_kv", None, "mamba_inner"),
        }
    else:
        specs["kv"] = {
            "k": ("batch_kv", "kv_seq", "kv_heads_cache", None),
            "v": ("batch_kv", "kv_seq", "kv_heads_cache", None),
        }
    if "cross" in parts:
        specs["cross"] = {
            "k": ("batch_kv", None, "kv_heads_cache", None),
            "v": ("batch_kv", None, "kv_heads_cache", None),
        }
    return specs


# ---------------------------------------------------------------------------
# Prefill: forward + cache fill, returns last-position logits
# ---------------------------------------------------------------------------


def _layer_prefill(cfg, kind, p, cache, x, positions, enc_states, rules=None):
    if kind == "rwkv":
        h, (S_f, x_tm) = rwkv_mod.apply_rwkv_time_mix(
            cfg, p["tm"], _apply_norm(cfg, p["ln1"], x)
        )
        x = x + h
        h_in = _apply_norm(cfg, p["ln2"], x)
        h, x_cm = rwkv_mod.apply_rwkv_channel_mix(cfg, p["cm"], h_in)
        x = x + h
        new = {"S": S_f, "x_tm": x_tm.astype(cache["x_tm"].dtype),
               "x_cm": x_cm.astype(cache["x_cm"].dtype)}
        return x, new
    parts = _parse(kind)
    new = dict(cache)
    h = _apply_norm(cfg, p["ln1"], x)
    if parts[0] == "mamba":
        d_in = cfg.mamba_expand * cfg.d_model
        xp = jnp.einsum("bsd,di->bsi", h, p["mixer"]["wx"].astype(h.dtype))
        conv_tail = xp[:, -(cfg.mamba_d_conv - 1):]
        h, h_final = mamba_mod.apply_mamba(cfg, p["mixer"], h)
        new["ssm"] = {
            "h": h_final,
            "conv": conv_tail.astype(cache["ssm"]["conv"].dtype),
        }
    else:
        h, kv = attn_mod.prefill_attention(
            cfg, p["mixer"], h, positions, cache["kv"],
            kind="local" if parts[0] == "local" else "global",
        )
        new["kv"] = kv
    x = x + h
    if "cross" in parts:
        ck, cv = attn_mod.encode_cross_kv(cfg, p["cross"], enc_states)
        new["cross"] = {
            "k": ck.astype(cache["cross"]["k"].dtype),
            "v": cv.astype(cache["cross"]["v"].dtype),
        }
        h = attn_mod.apply_cross_attention(
            cfg, p["cross"], _apply_norm(cfg, p["ln_x"], x),
            enc_kv=(ck, cv),
        )
        x = x + h
    h = _apply_norm(cfg, p["ln2"], x)
    if parts[-1] == "moe":
        h, _ = moe_mod.apply_moe(cfg, p["ffn"], h, rules)
    else:
        h = mlp_mod.apply_mlp(cfg, p["ffn"], h, gated=cfg.norm_type != "layernorm")
    return x + h, new


def prefill(cfg, params, batch, cache, rules: Rules = None):
    """Process the full prompt, fill caches, return last-token logits."""
    dt = _compute_dtype(cfg)
    x = _embed_tokens(cfg, params, batch["tokens"])
    if cfg.frontend == "vision" and "patch_embeds" in batch:
        x = jnp.concatenate([batch["patch_embeds"].astype(dt), x], axis=1)
    if not cfg.use_rope:
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model).astype(dt)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)
    enc_states = None
    if cfg.is_encoder_decoder:
        enc_states = _run_encoder(cfg, params, batch["frames"], rules)
    x = _wsc(x, rules, ("act_batch", "act_seq", None))

    def body(carry, xs):
        h = carry
        blk, cache_blk = xs
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            h, nc = _layer_prefill(
                cfg, kind, blk[i], cache_blk[i], h, positions, enc_states,
                rules,
            )
            new_caches.append(nc)
        h = _wsc(h, rules, ("act_batch", "act_seq", None))
        return h, tuple(new_caches)

    x, new_cache = jax.lax.scan(
        body, x, (tuple(params["blocks"]), tuple(cache)),
        unroll=cfg.scan_unroll,
    )
    x = _apply_norm(cfg, params["final_norm"], x[:, -1:])
    unemb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), unemb.astype(jnp.float32)
    )
    return softcap(logits, cfg.final_softcap), new_cache


# ---------------------------------------------------------------------------
# Decode: one token
# ---------------------------------------------------------------------------


def _layer_decode(cfg, kind, p, cache, x, pos, rules=None):
    if kind == "rwkv":
        h_in = _apply_norm(cfg, p["ln1"], x)
        h, (S_f, x_tm) = rwkv_mod.apply_rwkv_time_mix(
            cfg, p["tm"], h_in, state=cache["S"],
            x_carry=cache["x_tm"].astype(h_in.dtype),
        )
        x = x + h
        h_in = _apply_norm(cfg, p["ln2"], x)
        h, x_cm = rwkv_mod.apply_rwkv_channel_mix(
            cfg, p["cm"], h_in, x_carry=cache["x_cm"].astype(h_in.dtype)
        )
        x = x + h
        new = {"S": S_f, "x_tm": x_tm.astype(cache["x_tm"].dtype),
               "x_cm": x_cm.astype(cache["x_cm"].dtype)}
        return x, new
    parts = _parse(kind)
    new = dict(cache)
    h = _apply_norm(cfg, p["ln1"], x)
    if parts[0] == "mamba":
        h, new["ssm"] = mamba_mod.decode_mamba_step(cfg, p["mixer"], h, cache["ssm"])
    else:
        h, new["kv"] = attn_mod.decode_attention_step(
            cfg, p["mixer"], h, pos, cache["kv"],
            kind="local" if parts[0] == "local" else "global",
        )
    x = x + h
    if "cross" in parts:
        h = attn_mod.apply_cross_attention(
            cfg, p["cross"], _apply_norm(cfg, p["ln_x"], x),
            enc_kv=(cache["cross"]["k"].astype(h.dtype),
                    cache["cross"]["v"].astype(h.dtype)),
        )
        x = x + h
    h = _apply_norm(cfg, p["ln2"], x)
    if parts[-1] == "moe":
        h, _ = moe_mod.apply_moe(cfg, p["ffn"], h, rules)
    else:
        h = mlp_mod.apply_mlp(cfg, p["ffn"], h, gated=cfg.norm_type != "layernorm")
    return x + h, new


def decode_step(cfg, params, cache, token, pos, rules: Rules = None):
    """token: (B, 1) int32; pos: scalar int32 → (logits (B,1,V), cache)."""
    dt = _compute_dtype(cfg)
    x = _embed_tokens(cfg, params, token)
    if not cfg.use_rope:
        half = cfg.d_model // 2
        i = jnp.arange(half, dtype=jnp.float32)
        angle = pos.astype(jnp.float32) / jnp.power(10000.0, 2 * i / cfg.d_model)
        pe = jnp.concatenate([jnp.sin(angle), jnp.cos(angle)])
        x = x + pe.astype(dt)

    def body(carry, xs):
        h = carry
        blk, cache_blk = xs
        new_caches = []
        for i, kind in enumerate(cfg.pattern):
            h, nc = _layer_decode(cfg, kind, blk[i], cache_blk[i], h, pos, rules)
            new_caches.append(nc)
        return h, tuple(new_caches)

    x, new_cache = jax.lax.scan(
        body, x, (tuple(params["blocks"]), tuple(cache)),
        unroll=cfg.scan_unroll,
    )
    x = _apply_norm(cfg, params["final_norm"], x)
    unemb = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum(
        "bsd,vd->bsv", x.astype(jnp.float32), unemb.astype(jnp.float32)
    )
    return softcap(logits, cfg.final_softcap), new_cache
