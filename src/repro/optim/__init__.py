from repro.optim.adamw import (
    OptimizerConfig,
    adamw_update,
    clip_grads,
    init_opt_state,
    lr_schedule,
    opt_state_specs,
)
from repro.optim.compression import (
    CompressionConfig,
    compress_grads,
    init_residual,
)

__all__ = [
    "OptimizerConfig", "adamw_update", "clip_grads", "init_opt_state",
    "lr_schedule", "opt_state_specs",
    "CompressionConfig", "compress_grads", "init_residual",
]
