"""Histogram-threshold gradient sparsification with error feedback.

Top-ρ gradient compression needs the (1-ρ) quantile of |g| over billions of
elements.  A global sort is a non-starter; sampling gives no guarantee.
The paper's merge gives the threshold with *bounded rank error* (Theorem 1:
``2/T`` of the element count) from per-leaf (and on a mesh, per-device)
summaries, at ``O(k·T)`` communication.

On a real deployment this sits *before* the gradient reduce-scatter (each
replica sparsifies its local gradient, exchanging only survivors); under
``jit`` + GSPMD we apply it to the reduced gradient, which preserves the
convergence-relevant semantics (error feedback keeps the residual) and the
structural cost model — the placement note lives in DESIGN.md §7.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.telemetry import grad_quantile


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    enabled: bool = False
    rho: float = 0.01  # fraction of entries kept
    hist_T: int = 1024


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(
    grads: Any,
    residual: Any,
    ccfg: CompressionConfig,
    *,
    mesh=None,
    axis_names: tuple[str, ...] = (),
) -> tuple[Any, Any, dict]:
    """Returns (sparse_grads, new_residual, metrics)."""
    acc = jax.tree.map(
        lambda g, r: g.astype(jnp.float32) + r, grads, residual
    )
    thr = grad_quantile(
        acc, 1.0 - ccfg.rho, ccfg.hist_T, mesh=mesh, axis_names=axis_names
    )

    def split(a):
        keep = jnp.abs(a) >= thr
        return jnp.where(keep, a, 0.0), jnp.where(keep, 0.0, a)

    out = jax.tree.map(split, acc)
    sparse = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_resid = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    total = sum(g.size for g in jax.tree.leaves(grads))
    kept = sum(
        jnp.sum((jnp.abs(a) >= thr).astype(jnp.float32))
        for a in jax.tree.leaves(acc)
    )
    return sparse, new_resid, {
        "compress_threshold": thr,
        "compress_kept_fraction": kept / total,
    }
