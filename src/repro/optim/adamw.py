"""AdamW with histogram-quantile clipping — functional, shard-friendly.

Moments mirror parameter sharding (their logical specs are the parameter
specs), so optimizer state is ZeRO-sharded for free.  ``clip_mode``:

  * ``none``         — raw gradients
  * ``global_norm``  — classic clip-by-global-norm
  * ``quantile``     — **the paper integration**: clip each |g| at the
    approximate ``clip_q`` quantile of the *whole gradient tree's*
    magnitude distribution, computed by merging per-leaf equi-depth
    summaries (Theorem 1 bounds the rank error of the threshold by
    ``2/T`` of the element count — a principled, scale-free clip that
    costs one tiny merge instead of a global sort).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.telemetry import grad_quantile


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_mode: str = "global_norm"  # none | global_norm | quantile
    clip_value: float = 1.0  # max norm for global_norm
    clip_q: float = 0.999  # quantile for quantile mode
    clip_hist_T: int = 512
    moment_dtype: str = "float32"
    grad_accum: int = 1


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    warm = cfg.peak_lr * jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    decayed = cfg.peak_lr * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)
    return jnp.where(step < cfg.warmup_steps, warm, decayed)


def init_opt_state(params: Any, cfg: OptimizerConfig) -> dict:
    dt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs: Any) -> dict:
    """Moment sharding == parameter sharding (ZeRO-sharded for free)."""
    return {
        "m": param_specs,
        "v": param_specs,
        "step": (),
    }


def clip_grads(
    grads: Any,
    cfg: OptimizerConfig,
    *,
    mesh=None,
    axis_names: tuple[str, ...] = (),
) -> tuple[Any, dict]:
    if cfg.clip_mode == "none":
        return grads, {"grad_norm": _global_norm(grads)}
    if cfg.clip_mode == "global_norm":
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.clip_value / (gnorm + 1e-9))
        return jax.tree.map(lambda g: g * scale, grads), {"grad_norm": gnorm}
    if cfg.clip_mode == "quantile":
        thr = grad_quantile(
            grads, cfg.clip_q, cfg.clip_hist_T, mesh=mesh, axis_names=axis_names
        )
        clipped = jax.tree.map(lambda g: jnp.clip(g, -thr, thr), grads)
        return clipped, {
            "grad_norm": _global_norm(grads),
            "clip_threshold": thr,
        }
    raise ValueError(cfg.clip_mode)


def _global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    )


def adamw_update(
    grads: Any, opt_state: dict, params: Any, cfg: OptimizerConfig
) -> tuple[Any, dict, dict]:
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step.astype(jnp.float32))
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m32, v32 = m.astype(jnp.float32), v.astype(jnp.float32)
        m_n = b1 * m32 + (1 - b1) * g
        v_n = b2 * v32 + (1 - b2) * g * g
        mhat = m_n / bc1
        vhat = v_n / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m_n.astype(m.dtype),
            v_n.astype(v.dtype),
        )

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    # out is a tree of 3-tuples; unzip
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"lr": lr}
