"""Mesh construction for single-pod and multi-pod deployments.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS *before* any jax
initialization).
"""
from __future__ import annotations

import jax

try:  # jax ≥ 0.5 — explicit axis types
    from jax.sharding import AxisType

    def _axis_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}

except ImportError:  # older jax: every mesh axis is implicitly Auto

    def _axis_kwargs(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> jax.sharding.Mesh:
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_host_mesh() -> jax.sharding.Mesh:
    """Whatever devices exist, as a (data, model) mesh for tests/examples."""
    n = len(jax.devices())
    return make_mesh((n, 1), ("data", "model"))
