import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks device count at first init.

"""Dry-run of the paper's technique itself on the production mesh.

Three ways to answer "β-bucket equi-depth histogram of N values sharded
over the pod" — lowered, compiled, and cost-analyzed like the LM cells:

  exact_global   — jnp.sort over the whole sharded array then cut
                   (the pre-paper baseline: a distributed sort ⇒ the
                   MapReduce shuffle, reborn as all-to-all traffic)
  merge          — the paper: per-device exact T-bucket summary,
                   all-gather of k·(2T+1) scalars, replicated merge
  hierarchical   — tile → device → pod with composed bounds (DESIGN.md §5)

Writes results/dryrun/core__<variant>__<mesh>.json in the same record
format so the roofline report picks them up.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import (
    distributed_histogram,
    distributed_histogram_hierarchical,
)
from repro.core.histogram import build_exact
from repro.launch.dryrun import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    COLLECTIVES,
    parse_collective_bytes,
)
from repro.launch.mesh import make_production_mesh


def make_fn(variant: str, mesh, N: int, T: int, beta: int):
    axes = tuple(mesh.axis_names)
    data_axes = tuple(a for a in axes if a != "pod")

    if variant == "exact_global":
        def fn(x):
            return build_exact(x, beta)
    elif variant == "merge":
        def fn(x):
            return distributed_histogram(x, T, beta, mesh, axis_names=axes)
    elif variant == "hierarchical":
        def fn(x):
            return distributed_histogram_hierarchical(
                x, mesh,
                tile_size=8192, T_tile=2048, T_device=T, T_pod=T, beta=beta,
                data_axes=data_axes,
                pod_axis="pod" if "pod" in axes else None,
            )
    else:
        raise ValueError(variant)
    return fn, NamedSharding(mesh, P(axes))


def run(variant: str, multi_pod: bool, N: int, T: int, beta: int) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    fn, in_sh = make_fn(variant, mesh, N, T, beta)
    x = jax.ShapeDtypeStruct((N,), jnp.float32)
    t0 = time.time()
    with mesh:
        compiled = jax.jit(fn, in_shardings=(in_sh,)).lower(x).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = parse_collective_bytes(compiled.as_text())
    coll_bytes = sum(coll.get(c, 0.0) for c in COLLECTIVES)
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": byts / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }
    mem = compiled.memory_analysis()
    rec = {
        "arch": f"core-{variant}", "shape": f"N{N>>20}M_T{T}_b{beta}",
        "mesh": mesh_name, "kind": "core", "status": "ok",
        "compile_s": round(time.time() - t0, 1),
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": byts,
        "collectives": coll,
        "terms": terms,
        "dominant": max(terms, key=terms.get),
        "roofline_step_s": max(terms.values()),
        "memory": {
            "peak_bytes_per_device": int(
                mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes - mem.alias_size_in_bytes
            )
        },
        "useful_compute_ratio": float("nan"),
        "model_flops_per_device": 0.0,
        "mfu_upper_bound": 0.0,
    }
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1 << 30)  # 1 Gi values
    ap.add_argument("--t", type=int, default=40 * 254)  # paper's T ≥ 40β
    ap.add_argument("--beta", type=int, default=254)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    for variant in ("exact_global", "merge", "hierarchical"):
        for mp in meshes:
            mesh_name = "2x16x16" if mp else "16x16"
            try:
                rec = run(variant, mp, args.n, args.t, args.beta)
            except Exception as e:
                rec = {"arch": f"core-{variant}", "shape": "core",
                       "mesh": mesh_name, "kind": "core",
                       "status": "error", "error": str(e)[:2000]}
            path = os.path.join(args.out, f"core__{variant}__{mesh_name}.json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                t = rec["terms"]
                print(f"{variant:14s} {mesh_name}: compile={rec['compile_s']}s "
                      f"flops/dev={rec['hlo_flops_per_device']:.3e} "
                      f"c/m/x={t['compute_s']:.4f}/{t['memory_s']:.4f}/"
                      f"{t['collective_s']:.4f}s dominant={rec['dominant']}",
                      flush=True)
            else:
                print(f"{variant:14s} {mesh_name}: ERROR {rec['error'][:300]}")


if __name__ == "__main__":
    main()
