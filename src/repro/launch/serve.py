"""Serving launcher: batched generation with a reduced config on CPU.

``--metrics-dir DIR`` attaches a :class:`HistogramService` sidecar: each
request's generation latency is recorded as a durable histogram window,
and a standing subscription on the latency metric demonstrates the push
plane — the pushed update's p-quantile answer and eps are printed after
the batch, then the sidecar checkpoints and closes.

``--replicate-to DIR`` additionally ships the sidecar's WAL to a
hot-standby directory (core/replication.py): after the batch, a
replica-role service is opened over the shipped log and its
bounded-staleness answer (eps widened by the lag-drift bound) is printed
next to the primary's, demonstrating zero-loss WAL shipping end to end.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, smoke as smoke_cfg
from repro.models.model import init_model
from repro.serve import Engine, HistogramService, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument(
        "--metrics-dir", default=None,
        help="attach a HistogramService sidecar recording per-request "
        "generation latency, with a standing push subscription",
    )
    ap.add_argument(
        "--replicate-to", default=None,
        help="hot-standby directory: ship the sidecar's WAL there and "
        "print a replica-role bounded-staleness answer after the batch "
        "(requires --metrics-dir)",
    )
    args = ap.parse_args()
    if args.replicate_to is not None and args.metrics_dir is None:
        ap.error("--replicate-to requires --metrics-dir")

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(
        cfg, params,
        ServeConfig(
            max_seq=args.prompt_len + args.max_new_tokens + 1,
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature,
        ),
    )
    svc = sub = None
    if args.metrics_dir is not None:
        replicate_to = (args.replicate_to,) if args.replicate_to else ()
        svc = HistogramService(
            args.metrics_dir, num_buckets=64, replicate_to=replicate_to
        )
        # standing dashboard panel: p-latency over the whole run so far
        sub = svc.subscribe("gen_latency_ms", 0, 1 << 20, beta=64)

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(2, cfg.vocab_size, size=rng.integers(4, args.prompt_len + 1)).astype(np.int32)
        for _ in range(args.batch)
    ]
    latencies = []
    outs = []
    for i, p in enumerate(prompts):
        t0 = time.perf_counter()
        outs.append(eng.generate([p])[0])
        latencies.append((time.perf_counter() - t0) * 1e3)
        if svc is not None:
            svc.record("gen_latency_ms", i, np.float32([latencies[-1]]))
    for i, o in enumerate(outs):
        print(f"req{i}: prompt_len={len(prompts[i])} output={o.tolist()}")

    if svc is not None:
        svc.subscriptions.flush()  # push barrier: deliver the update
        update = sub.get(timeout=5.0)
        if update is not None:
            print(
                f"pushed update: metric=gen_latency_ms windows=0..{1 << 20} "
                f"eps={update.eps:g} degraded={update.degraded} "
                f"lag={update.lag_seconds * 1e3:.1f}ms"
            )
        stats = svc.subscriptions.stats()
        print(
            "subscription plane: "
            f"delivered={stats['updates_delivered']} "
            f"dispatches={stats['eval_batches']}"
        )
        if args.replicate_to is not None:
            replica = HistogramService(
                args.replicate_to, role="replica", num_buckets=64
            )
            replica.sync()
            ans = replica.query_many(
                [("gen_latency_ms", 0, 1 << 20)], beta=64
            )[0]
            repl = svc.health()["replication"]
            print(
                f"replica answer: eps={ans.eps:g} degraded={ans.degraded} "
                f"lag_s={ans.lag_seconds} "
                f"(primary shipped_lsn={repl['shipped_lsn']} "
                f"ships={repl['ships']})"
            )
            replica.close()
        svc.checkpoint()
        svc.close()


if __name__ == "__main__":
    main()
