"""Serving launcher: batched generation with a reduced config on CPU."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, smoke as smoke_cfg
from repro.models.model import init_model
from repro.serve import Engine, ServeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(
        cfg, params,
        ServeConfig(
            max_seq=args.prompt_len + args.max_new_tokens + 1,
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature,
        ),
    )
    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(2, cfg.vocab_size, size=rng.integers(4, args.prompt_len + 1)).astype(np.int32)
        for _ in range(args.batch)
    ]
    outs = eng.generate(prompts)
    for i, o in enumerate(outs):
        print(f"req{i}: prompt_len={len(prompts[i])} output={o.tolist()}")


if __name__ == "__main__":
    main()
