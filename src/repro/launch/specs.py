"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, zero allocation — the dry-run contract.
Modality frontends are stubs per the assignment: whisper receives
precomputed frame embeddings, pixtral precomputed patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    s_text = S - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    specs = {
        "tokens": SDS((B, s_text), jnp.int32),
        "targets": SDS((B, S), jnp.int32),
        "mask": SDS((B, S), jnp.float32),
    }
    if cfg.frontend == "vision":
        specs["patch_embeds"] = SDS(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.is_encoder_decoder:
        specs["frames"] = SDS((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return specs


def batch_logical_specs(cfg: ModelConfig) -> dict:
    """Logical sharding for each batch entry (train/prefill)."""
    specs = {
        "tokens": ("act_batch", None),
        "targets": ("act_batch", None),
        "mask": ("act_batch", None),
    }
    if cfg.frontend == "vision":
        specs["patch_embeds"] = ("act_batch", None, None)
    if cfg.is_encoder_decoder:
        specs["frames"] = ("act_batch", None, None)
    return specs


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Prompt batch for the prefill step (no targets)."""
    B, S = shape.global_batch, shape.seq_len
    s_text = S - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    specs = {"tokens": SDS((B, s_text), jnp.int32)}
    if cfg.frontend == "vision":
        specs["patch_embeds"] = SDS(
            (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16
        )
    if cfg.is_encoder_decoder:
        specs["frames"] = SDS((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B = shape.global_batch
    return {
        "token": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }
