import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("REPRO_EXTRA_XLA_FLAGS", "")
)
# ^ MUST precede every other import: jax locks device count at first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with zero real allocation:
  * proof the sharding config is coherent (compile succeeds),
  * ``memory_analysis()``  — fits-in-HBM evidence,
  * ``cost_analysis()``    — per-device HLO FLOPs / bytes,
  * collective-bytes breakdown parsed from the post-SPMD optimized HLO,
  * the three roofline terms (197 TFLOP/s bf16, 819 GB/s HBM,
    50 GB/s/link ICI — TPU v5e) + dominant-term classification,
  * MODEL_FLOPS = 6·N(_active)·D and the useful-compute ratio.

Results go to ``results/dryrun/<arch>__<shape>__<mesh>.json`` (incremental:
existing cells are skipped unless --force).
"""
import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    batch_logical_specs,
    decode_input_specs,
    prefill_input_specs,
    train_input_specs,
)
from repro.models.model import decode_step, init_cache, init_model, prefill
from repro.optim import OptimizerConfig
from repro.sharding import Rules
from repro.train.train_step import make_train_step

# ---- hardware constants (TPU v5e) -----------------------------------------
PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per-chip effective collective bw)

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "u1": 1, "s1": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?P<out>\(?[^=]*?)\s*"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum output bytes of every collective in the optimized HLO (per device).

    `-done` ops are skipped so async start/done pairs count once.
    """
    out: dict[str, float] = {c: 0.0 for c in COLLECTIVES}
    counts: dict[str, int] = {c: 0 for c in COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        op = m.group("op")
        out[op] += _shape_bytes(m.group("out"))
        counts[op] += 1
    out_cnt = {f"n_{k}": v for k, v in counts.items()}
    return {**out, **out_cnt}


def _model_flops(cfg, shape) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode: per emitted token."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens  # forward only
    tokens = shape.global_batch  # one token per request
    return 2.0 * n_active * tokens


def build_cell(cfg, shape, mesh, opt_cfg) -> tuple:
    """Returns (lowered_fn_args..., ) ready to lower: (fn, args, in_sh, out_sh, donate)."""
    kind = shape.kind
    rules = Rules(cfg, mesh, kind, seq_len=shape.seq_len)
    params_abs, pspecs = init_model(cfg, jax.random.PRNGKey(0), abstract=True)
    p_sh = rules.tree_shardings(pspecs)

    if kind == "train":
        batch_abs = train_input_specs(cfg, shape)
        b_sh = rules.tree_shardings(
            {k: batch_logical_specs(cfg)[k] for k in batch_abs}
        )
        from repro.optim.adamw import OptimizerConfig as _OC

        opt_abs = {
            "m": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(
                    p.shape,
                    jnp.bfloat16
                    if cfg.optimizer_dtype == "bfloat16"
                    else jnp.float32,
                ),
                params_abs,
            ),
            "v": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(
                    p.shape,
                    jnp.bfloat16
                    if cfg.optimizer_dtype == "bfloat16"
                    else jnp.float32,
                ),
                params_abs,
            ),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
        o_sh = {
            "m": p_sh,
            "v": p_sh,
            "step": jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec()
            ),
        }
        ocfg = dataclasses.replace(
            opt_cfg,
            moment_dtype=cfg.optimizer_dtype,
            clip_mode="global_norm",
        )
        step = make_train_step(cfg, ocfg, rules)
        return (
            step,
            (params_abs, opt_abs, batch_abs),
            (p_sh, o_sh, b_sh),
            (p_sh, o_sh, None),
            (0, 1),
        )

    if kind == "prefill":
        batch_abs = prefill_input_specs(cfg, shape)
        b_sh = rules.tree_shardings(
            {k: batch_logical_specs(cfg)[k] for k in batch_abs}
        )
        cache_abs, cspecs = init_cache(
            cfg, shape.global_batch, shape.seq_len, abstract=True
        )
        c_sh = rules.tree_shardings(cspecs)

        def fn(params, batch, cache):
            return prefill(cfg, params, batch, cache, rules)

        return (
            fn,
            (params_abs, batch_abs, cache_abs),
            (p_sh, b_sh, c_sh),
            (None, c_sh),
            (2,),
        )

    # decode / decode_long
    batch_abs = decode_input_specs(cfg, shape)
    cache_abs, cspecs = init_cache(
        cfg, shape.global_batch, shape.seq_len, abstract=True
    )
    c_sh = rules.tree_shardings(cspecs)
    tok_sh = rules.sharding(("act_batch", None))
    pos_sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    def fn(params, cache, token, pos):
        return decode_step(cfg, params, cache, token, pos, rules)

    return (
        fn,
        (params_abs, cache_abs, batch_abs["token"], batch_abs["pos"]),
        (p_sh, c_sh, tok_sh, pos_sh),
        (None, c_sh),
        (1,),
    )


def costing_config(cfg, shape, r: int):
    """Variant for exact HLO cost accounting.

    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count (verified experimentally), so the scanned production config would
    undercount depth by ``repeats``× and every chunked seq loop by its chunk
    count.  The costing variant (a) fully unrolls the layer scan and (b)
    collapses chunk loops to a single chunk.  Two compiles (r=1, r=2) give
    the exact per-superblock marginal cost — scanned layers are identical —
    and linear extrapolation to the real depth is exact.  Residual
    undercount: the RWKV per-step recurrence einsums inside its inner scan
    (~2-4 % of layer FLOPs; noted in EXPERIMENTS.md §Roofline).
    """
    seq = shape.seq_len
    repl = dict(
        repeats=r,
        scan_unroll=max(r, 1),
        attn_q_chunk=seq,
        loss_chunk=seq,
        mamba_chunk=seq,
        rwkv_chunk=seq,
    )
    if cfg.encoder_layers:
        repl["encoder_layers"] = r
    return dataclasses.replace(cfg, **repl)


def _compile_cell(cfg, shape, mesh, opt_cfg):
    fn, args, in_sh, out_sh, donate = build_cell(cfg, shape, mesh, opt_cfg)
    with mesh:
        jitted = jax.jit(
            fn, in_shardings=in_sh, out_shardings=out_sh,
            donate_argnums=donate,
        )
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    return compiled


def _cost_of(compiled) -> dict:
    out = {"flops": 0.0, "bytes": 0.0}
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    out["flops"] = float(cost.get("flops", 0.0))
    out["bytes"] = float(cost.get("bytes accessed", 0.0))
    out["coll"] = parse_collective_bytes(compiled.as_text())
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, opt_cfg=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind,
    }
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        record["status"] = "skip"
        record["reason"] = reason
        return record
    mesh = make_production_mesh(multi_pod=multi_pod)
    opt_cfg = opt_cfg or OptimizerConfig()
    chips = mesh.devices.size

    # ---- production compile: sharding coherence + memory proof ------------
    t0 = time.time()
    compiled = _compile_cell(cfg, shape, mesh, opt_cfg)
    record["status"] = "ok"
    record["compile_s"] = round(time.time() - t0, 1)
    record["degradations"] = Rules(
        cfg, mesh, shape.kind, seq_len=shape.seq_len
    ).degradations()

    try:
        mem = compiled.memory_analysis()
        record["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
        alias = int(getattr(mem, "alias_size_in_bytes", 0))
        record["memory"]["alias_size_in_bytes"] = alias
        record["memory"]["peak_bytes_per_device"] = int(
            record["memory"].get("argument_size_in_bytes", 0)
            + record["memory"].get("output_size_in_bytes", 0)
            + record["memory"].get("temp_size_in_bytes", 0)
            - alias
        )
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": str(e)}
    del compiled

    # ---- costing compiles: r=1, r=2 unrolled → exact linear extrapolation -
    R = cfg.repeats
    t0 = time.time()
    c1 = _cost_of(_compile_cell(costing_config(cfg, shape, 1), shape, mesh, opt_cfg))
    c2 = _cost_of(_compile_cell(costing_config(cfg, shape, 2), shape, mesh, opt_cfg))
    record["costing_compile_s"] = round(time.time() - t0, 1)

    def extrap(v1, v2):
        return v1 + (R - 1) * max(v2 - v1, 0.0)

    record["hlo_flops_per_device"] = extrap(c1["flops"], c2["flops"])
    record["hlo_bytes_per_device"] = extrap(c1["bytes"], c2["bytes"])
    coll = {
        k: extrap(c1["coll"].get(k, 0.0), c2["coll"].get(k, 0.0))
        for k in set(c1["coll"]) | set(c2["coll"])
    }
    record["collectives"] = coll
    record["costing_raw"] = {"r1": c1, "r2": c2}
    coll_bytes = sum(coll.get(c, 0.0) for c in COLLECTIVES)

    model_flops = _model_flops(cfg, shape)
    record["model_flops_total"] = model_flops
    record["model_flops_per_device"] = model_flops / chips

    t_compute = record["hlo_flops_per_device"] / PEAK_FLOPS
    t_memory = record["hlo_bytes_per_device"] / HBM_BW
    t_coll = coll_bytes / ICI_BW
    record["terms"] = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
    }
    dominant = max(record["terms"], key=record["terms"].get)
    record["dominant"] = dominant
    bound = max(t_compute, t_memory, t_coll)
    record["roofline_step_s"] = bound
    record["useful_compute_ratio"] = (
        record["model_flops_per_device"] / record["hlo_flops_per_device"]
        if record["hlo_flops_per_device"]
        else 0.0
    )
    # model-FLOPs utilization *if* the dominant term were the runtime
    record["mfu_upper_bound"] = (
        record["model_flops_per_device"] / (bound * PEAK_FLOPS)
        if bound
        else 0.0
    )
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[
        args.mesh
    ]
    os.makedirs(args.out, exist_ok=True)

    for arch in archs:
        for shape_name in shapes:
            for multi_pod in meshes:
                mesh_name = "2x16x16" if multi_pod else "16x16"
                path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json"
                )
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {path}")
                    continue
                print(f"[dryrun] {arch} × {shape_name} × {mesh_name} ...", flush=True)
                try:
                    rec = run_cell(arch, shape_name, multi_pod)
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "error", "error": str(e),
                        "traceback": traceback.format_exc()[-4000:],
                    }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                if status == "ok":
                    t = rec["terms"]
                    print(
                        f"  ok compile={rec['compile_s']}s "
                        f"flops/dev={rec['hlo_flops_per_device']:.3e} "
                        f"terms(c/m/x)={t['compute_s']:.4f}/{t['memory_s']:.4f}/"
                        f"{t['collective_s']:.4f}s dominant={rec['dominant']} "
                        f"mfu_ub={rec['mfu_upper_bound']:.3f}",
                        flush=True,
                    )
                else:
                    print(f"  {status}: {rec.get('reason') or rec.get('error','')[:500]}", flush=True)


if __name__ == "__main__":
    main()
