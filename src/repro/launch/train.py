"""Training launcher: ``python -m repro.launch.train --arch smollm-135m ...``

On this CPU container it runs reduced configs end-to-end (the full configs
are exercised by dryrun.py); on a real TPU slice the same entry point runs
the production mesh — the only difference is --mesh/--smoke flags.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, smoke as smoke_cfg
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.optim import CompressionConfig, OptimizerConfig
from repro.sharding import Rules
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--clip-mode", default="global_norm",
                    choices=["none", "global_norm", "quantile"])
    ap.add_argument("--compress-rho", type=float, default=0.0,
                    help=">0 enables histogram-threshold grad compression")
    ap.add_argument("--checkpoint-dir", default="checkpoints")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--no-resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_cfg(cfg)
    mesh = {
        "host": make_host_mesh,
        "single": lambda: make_production_mesh(multi_pod=False),
        "multi": lambda: make_production_mesh(multi_pod=True),
    }[args.mesh]()
    rules = Rules(cfg, mesh, "train", seq_len=args.seq_len)
    opt_cfg = OptimizerConfig(
        peak_lr=args.lr, clip_mode=args.clip_mode,
        decay_steps=max(args.steps, 10),
        warmup_steps=min(20, args.steps // 5 + 1),
    )
    comp = (
        CompressionConfig(enabled=True, rho=args.compress_rho)
        if args.compress_rho > 0
        else None
    )
    tcfg = TrainerConfig(
        total_steps=args.steps,
        log_every=args.log_every,
        checkpoint_every=args.checkpoint_every,
        checkpoint_dir=args.checkpoint_dir,
        seed=args.seed,
        resume=not args.no_resume,
    )
    with mesh:
        trainer = Trainer(
            cfg, opt_cfg, tcfg,
            seq_len=args.seq_len, global_batch=args.global_batch,
            mesh=mesh, rules=rules, comp_cfg=comp,
        )
        trainer.install_signal_handler()
        trainer.run()


if __name__ == "__main__":
    main()
