"""deepseek-7b — llama-arch dense, full MHA (kv=32).

[arXiv:2401.02954; hf]  30L, d_model=4096, 32H (kv=32, hd=128),
d_ff=11008, vocab=102400.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b",
        family="dense",
        pattern=("attn+mlp",),
        repeats=30,
        d_model=4096,
        num_heads=32,
        num_kv_heads=32,
        head_dim=128,
        d_ff=11008,
        vocab_size=102400,
    )
