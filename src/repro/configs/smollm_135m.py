"""smollm-135m — llama-arch small dense model.

[hf:HuggingFaceTB/SmolLM-135M; hf]  30L, d_model=576, 9H (GQA kv=3, hd=64),
d_ff=1536, vocab=49152, tied embeddings.  9 heads do not divide a 16-way TP
axis: attention weights replicate over "model" (DESIGN.md §7) while the MLP
and vocab dims still shard.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-135m",
        family="dense",
        pattern=("attn+mlp",),
        repeats=30,
        d_model=576,
        num_heads=9,
        num_kv_heads=3,
        head_dim=64,
        d_ff=1536,
        vocab_size=49152,
        tie_embeddings=True,
    )
