"""The paper's own workload config: log-analytics histogram framework.

Not an LM — this configures the Summarizer/Merger deployment of the paper
(partition count, T, beta per the paper's experiments: B=254 Oracle-default
query buckets, T = B*254*2^n summary sizes, 31 daily partitions of the
January-2015 Wikipedia pageview workload, Gumbel-skewed synthetic).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LogStatsConfig:
    name: str = "paper-logstats"
    beta: int = 254                 # final histogram buckets (Oracle default)
    T_factor: int = 8               # T = beta * T_factor
    num_partitions: int = 31        # one month of daily logs
    tuples_per_partition: int = 200_000
    distribution: str = "gumbel"    # gumbel | wiki_pagesize
    seed: int = 0

    @property
    def T(self) -> int:
        return self.beta * self.T_factor


def config() -> LogStatsConfig:
    return LogStatsConfig()
