"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]  32L, d_model=4096, 32H (GQA kv=8, hd=128),
d_ff=14336, vocab=65536.  Super-block of 8 layers: one attention layer per
block (ratio 1:7), MoE replacing the MLP on odd layer slots (16 MoE layers
total), per the Jamba paper's layout.
"""
from repro.configs.base import ModelConfig

BLOCK = (
    "mamba+mlp", "mamba+moe", "mamba+mlp", "mamba+moe",
    "attn+mlp", "mamba+moe", "mamba+mlp", "mamba+moe",
)


def config() -> ModelConfig:
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        pattern=BLOCK,
        repeats=4,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=65536,
        num_experts=16,
        num_experts_per_token=2,
        moe_group_size=128,  # §Perf P5: C 80→20, dispatch flops 4× down
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_expand=2,
        # §Perf P6: bf16-stored scan tensors (fp32 carries) — halves the
        # dominant memory-traffic term; <0.1% output deviation measured.
        mamba_scan_dtype="bfloat16",
    )
