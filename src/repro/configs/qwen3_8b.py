"""qwen3-8b — dense GQA with per-head qk RMS-norm.

[hf:Qwen/Qwen3-8B; hf]  36L, d_model=4096, 32H (GQA kv=8, hd=128),
d_ff=12288, vocab=151936.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family="dense",
        pattern=("attn+mlp",),
        repeats=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1000000.0,
    )
