"""whisper-medium — encoder-decoder, conv frontend stubbed.

[arXiv:2212.04356; unverified]  24 encoder + 24 decoder layers,
d_model=1024, 16H (kv=16, hd=64), d_ff=4096, vocab=51865 (padded to 51872
for clean 16-way vocab sharding — Megatron-style padding, noted).  The
conv1d audio frontend is a STUB per the assignment: input_specs() provides
precomputed frame embeddings (B, 1500, d).  Sinusoidal positions, LayerNorm,
ungated GELU FFN; decode shapes exercise the decoder self-attn KV cache +
cross-attention to the stub encoder states.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-medium",
        family="audio",
        pattern=("attn+cross+mlp",),
        repeats=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=51872,
        use_rope=False,
        norm_type="layernorm",
        norm_eps=1e-5,
        encoder_layers=24,
        encoder_seq=1500,
        frontend="audio",
        tie_embeddings=True,
    )
