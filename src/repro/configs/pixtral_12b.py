"""pixtral-12b — VLM: pixtral-ViT frontend STUB + mistral-nemo decoder.

[hf:mistralai/Pixtral-12B-2409; unverified]  40L, d_model=5120, 32H
(GQA kv=8, hd=128), d_ff=14336, vocab=131072.  The ViT frontend is a STUB
per the assignment: input_specs() provides precomputed patch embeddings
(B, 1024, d) that are prepended to the token stream (1D RoPE over the fused
sequence — the 2D image RoPE is a frontend concern, noted in DESIGN.md).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="pixtral-12b",
        family="vlm",
        pattern=("attn+mlp",),
        repeats=40,
        d_model=5120,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1000000.0,
        frontend="vision",
        frontend_tokens=1024,
    )
