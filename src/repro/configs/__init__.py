"""Architecture registry: --arch <id> resolves here."""
from repro.configs.base import (
    ModelConfig,
    SHAPES,
    ShapeConfig,
    shape_applicable,
    smoke,
)
from repro.configs import (
    jamba_v0_1_52b,
    smollm_135m,
    deepseek_7b,
    gemma2_9b,
    qwen3_8b,
    dbrx_132b,
    llama4_maverick_400b,
    rwkv6_7b,
    whisper_medium,
    pixtral_12b,
)

REGISTRY = {
    "jamba-v0.1-52b": jamba_v0_1_52b.config,
    "smollm-135m": smollm_135m.config,
    "deepseek-7b": deepseek_7b.config,
    "gemma2-9b": gemma2_9b.config,
    "qwen3-8b": qwen3_8b.config,
    "dbrx-132b": dbrx_132b.config,
    "llama4-maverick-400b-a17b": llama4_maverick_400b.config,
    "rwkv6-7b": rwkv6_7b.config,
    "whisper-medium": whisper_medium.config,
    "pixtral-12b": pixtral_12b.config,
}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]()


def list_archs() -> list[str]:
    return sorted(REGISTRY)

__all__ = [
    "ModelConfig", "SHAPES", "ShapeConfig", "shape_applicable", "smoke",
    "REGISTRY", "get_config", "list_archs",
]
