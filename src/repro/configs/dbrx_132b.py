"""dbrx-132b — fine-grained MoE, 16 experts top-4.

[hf:databricks/dbrx-base; unverified]  40L, d_model=6144, 48H (GQA kv=8,
hd=128), d_ff=10752 per expert, vocab=100352.  Every layer is MoE.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        pattern=("attn+moe",),
        repeats=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100352,
        num_experts=16,
        num_experts_per_token=4,
        # §Perf P5: C = g·k·cf/E; g=512 gave C=160 and a one-hot dispatch
        # einsum 16× the expert FFN flops. g=128 → C=40 (4× less dispatch
        # compute) with 25% capacity headroom at k=4.
        moe_group_size=128,
        rope_theta=500000.0,
    )
