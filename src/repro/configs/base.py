"""Config system: architectures, input shapes, smoke reductions.

Every assigned architecture is a ``ModelConfig`` built from a repeating
layer ``pattern`` (the scanned super-block — DESIGN.md §7) so HLO size is
depth-independent.  ``smoke()`` derives a reduced same-family config for
CPU tests; full configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

LayerKind = Literal[
    "attn+mlp", "attn+moe", "local+mlp", "global+mlp",
    "mamba+mlp", "mamba+moe", "rwkv", "attn+cross+mlp",
]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    pattern: tuple[str, ...]
    repeats: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # attention variants
    qk_norm: bool = False
    attn_softcap: float | None = None
    final_softcap: float | None = None
    sliding_window: int | None = None
    rope_theta: float = 10000.0
    use_rope: bool = True
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-6
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d)
    tie_embeddings: bool = False
    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_group_size: int = 512
    moe_capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    moe_z_weight: float = 1e-3
    # Mamba
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_expand: int = 2
    mamba_chunk: int = 256
    mamba_scan_dtype: str = "float32"  # bf16 halves scan traffic (§Perf P6)
    # RWKV
    rwkv_heads: int = 0
    rwkv_decay_lora: int = 64
    rwkv_chunk: int = 256
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 0  # stub frame-embedding count
    # modality frontend stubs
    frontend: str | None = None  # None | "audio" | "vision"
    frontend_tokens: int = 0  # vision: patch embeddings prepended to stream
    # execution
    attn_q_chunk: int = 512
    scan_unroll: int = 1  # dry-run costing: full unroll for exact HLO counts
    loss_chunk: int = 512
    remat_policy: str = "full"  # none | full | dots
    compute_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"  # bf16 moments for the 400B config

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return not any(
            k.split("+")[0] in ("attn", "local", "global")
            for k in self.pattern
        )

    @property
    def has_subquadratic_path(self) -> bool:
        """Eligible for long_500k: SSM/hybrid/linear-attn or local+global."""
        kinds = {k.split("+")[0] for k in self.pattern}
        if kinds & {"mamba", "rwkv"}:
            return True
        return "local" in kinds  # gemma-2 alternation: half the layers local

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + unembed)."""
        d, f, V = self.d_model, self.d_ff, self.vocab_size
        total = V * d * (1 if self.tie_embeddings else 2)
        attn = d * self.num_heads * self.head_dim + 2 * (
            d * self.num_kv_heads * self.head_dim
        ) + self.num_heads * self.head_dim * d
        mlp = (2 if self.norm_type == "layernorm" else 3) * d * f
        moe = self.num_experts * 3 * d * f + d * self.num_experts
        d_in = self.mamba_expand * d
        mamba = (
            2 * d * d_in + d_in * self.mamba_d_conv
            + d_in * (max(d // 16, 1) + 2 * self.mamba_d_state)
            + max(d // 16, 1) * d_in + d_in * self.mamba_d_state
            + d_in * d
        )
        rwkv_tm = 5 * d * d + 2 * d * self.rwkv_decay_lora
        rwkv_cm = 2 * d * f + d * d
        for kind in self.pattern:
            for part in kind.split("+"):
                total += {
                    "attn": attn, "local": attn, "global": attn,
                    "cross": attn, "mlp": mlp, "moe": moe,
                    "mamba": mamba, "rwkv": rwkv_tm + rwkv_cm,
                }[part] * self.repeats
        if self.encoder_layers:
            total += self.encoder_layers * (attn + mlp)
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts instead of all E)."""
        if self.num_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        full_moe = self.num_experts * 3 * d * f
        active_moe = self.num_experts_per_token * 3 * d * f
        n_moe_layers = sum(
            1 for k in self.pattern if "moe" in k
        ) * self.repeats
        return self.param_count() - n_moe_layers * (full_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode | decode_long


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode_long"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason) — the DESIGN.md §6 skip rules."""
    if shape.kind == "decode_long" and not cfg.has_subquadratic_path:
        return False, "pure full-attention arch: 500k decode KV excluded by assignment"
    return True, ""


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    heads = 4
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        repeats=1,
        d_model=128,
        num_heads=heads,
        num_kv_heads=2,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        sliding_window=32 if cfg.sliding_window else None,
        num_experts=4 if cfg.num_experts else 0,
        num_experts_per_token=min(cfg.num_experts_per_token, 2)
        if cfg.num_experts
        else 0,
        moe_group_size=16,
        mamba_d_state=8,
        mamba_chunk=8,
        mamba_scan_dtype="float32",  # smoke = full precision everywhere
        rwkv_heads=4 if cfg.rwkv_heads else 0,
        rwkv_decay_lora=8,
        rwkv_chunk=8,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=16 if cfg.encoder_layers else 0,
        frontend_tokens=8 if cfg.frontend_tokens else 0,
        attn_q_chunk=16,
        loss_chunk=16,
        remat_policy="none",
        compute_dtype="float32",
    )
