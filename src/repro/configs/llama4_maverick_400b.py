"""llama4-maverick-400b-a17b — MoE 128e top-1, interleaved dense/MoE.

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]  48L, d_model=5120,
40H (GQA kv=8, hd=128), d_ff=8192, vocab=202048, 128 experts top-1.
Dense/MoE layers alternate (as in the released Maverick checkpoints) —
this is what lands total params at ~400B with ~17B active; all-MoE at this
d_ff would exceed the published 400B.  "Early fusion" refers to the
multimodal token path; the assigned spec is the LM backbone, so inputs are
token ids (the frontend stub applies to pixtral/whisper only).  bf16
optimizer moments (optimizer_dtype) keep the single-pod (256-chip)
footprint under HBM — DESIGN.md §7.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        pattern=("attn+mlp", "attn+moe"),
        repeats=24,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        num_experts=128,
        num_experts_per_token=1,
        rope_theta=500000.0,
        optimizer_dtype="bfloat16",
    )
