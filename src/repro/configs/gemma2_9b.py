"""gemma2-9b — dense, local(4k window)/global alternating, logit softcaps.

[arXiv:2408.00118; hf]  42L, d_model=3584, 16H (GQA kv=8, hd=256),
d_ff=14336, vocab=256000.  Attention logit softcap 50, final logit softcap
30, embeddings scaled by sqrt(d), tied unembedding.  The local/global pair
is the scanned super-block (21 repeats).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-9b",
        family="dense",
        pattern=("local+mlp", "global+mlp"),
        repeats=21,
        d_model=3584,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        attn_softcap=50.0,
        final_softcap=30.0,
        sliding_window=4096,
        embed_scale=True,
        tie_embeddings=True,
    )
