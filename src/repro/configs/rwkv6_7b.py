"""rwkv6-7b "Finch" — attention-free, data-dependent decay.

[arXiv:2404.05892; hf]  32L, d_model=4096 (64 heads x 64), channel-mix
d_ff=14336, vocab=65536.  O(1) decode state => runs long_500k.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        pattern=("rwkv",),
        repeats=32,
        d_model=4096,
        num_heads=64,       # informational; attention is never instantiated
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        rwkv_heads=64,
        rwkv_decay_lora=64,
    )
