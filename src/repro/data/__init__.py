from repro.data.pipeline import SyntheticLM, LengthBucketer, shard_batch
