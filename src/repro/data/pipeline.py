"""Deterministic synthetic data pipeline with histogram length-bucketing.

Determinism contract: ``batch_at(step)`` is a pure function of
``(seed, step)`` — restart-resume needs no data-state checkpoint beyond the
step counter (fault-tolerance requirement, DESIGN.md §7).

Histogram integration (paper → data plane): documents have a skewed length
distribution (log-normal, like real web corpora).  Packing sequences from
unbucketed docs wastes pad tokens; equal-*count* buckets mis-balance token
mass.  We build an **equi-depth histogram of document lengths** — per input
shard, merged with the paper's algorithm — and use its boundaries as length
buckets: every bucket then holds the same number of documents whose lengths
are maximally homogeneous, so pack efficiency is uniform across hosts and
no input-bound straggler emerges.  ``bucket_report()`` quantifies it.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.histogram import build_exact, merge_list, quantile
import jax.numpy as jnp


@dataclasses.dataclass
class SyntheticLM:
    """Zipf-ish token stream packed into fixed-length training rows."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: float = 350.0
    sigma: float = 1.0
    eos_id: int = 1

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step])
        )

    def doc_lengths(self, rng, n: int) -> np.ndarray:
        ln = rng.lognormal(np.log(self.mean_doc_len), self.sigma, size=n)
        return np.clip(ln.astype(np.int64), 8, 4 * self.seq_len)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """(tokens, targets, mask) each (global_batch, seq_len)."""
        rng = self._rng(step)
        B, S = self.global_batch, self.seq_len
        total = B * (S + 1)
        # zipf-ish unigram stream; ids folded into vocab
        raw = rng.zipf(1.3, size=total).astype(np.int64)
        tokens = (raw % (self.vocab_size - 2)) + 2
        # sprinkle EOS at packed-document boundaries
        lens = self.doc_lengths(rng, 4 * total // int(self.mean_doc_len))
        pos = np.cumsum(lens)
        pos = pos[pos < total]
        tokens[pos] = self.eos_id
        grid = tokens.reshape(B, S + 1)
        return {
            "tokens": grid[:, :-1].astype(np.int32),
            "targets": grid[:, 1:].astype(np.int32),
            "mask": np.ones((B, S), np.float32),
        }


@dataclasses.dataclass
class LengthBucketer:
    """Equi-depth document-length buckets from merged shard summaries."""

    num_buckets: int = 8
    summary_T: int = 256

    def fit(self, shard_lengths: list[np.ndarray]):
        """shard_lengths: one array of doc lengths per input shard (host)."""
        summaries = [
            build_exact(
                jnp.asarray(s.astype(np.float32)),
                min(self.summary_T, len(s)),
            )
            for s in shard_lengths
        ]
        merged = merge_list(summaries, self.num_buckets)
        self.boundaries_ = np.asarray(merged.boundaries)
        self.merged_ = merged
        return self

    def assign(self, lengths: np.ndarray) -> np.ndarray:
        return np.clip(
            np.searchsorted(self.boundaries_[1:-1], lengths, side="right"),
            0,
            self.num_buckets - 1,
        )

    def bucket_report(self, lengths: np.ndarray) -> dict:
        """Pack-efficiency: pad waste with vs. without bucketing."""
        b = self.assign(lengths)
        waste_bucketed, waste_flat = 0.0, 0.0
        for i in range(self.num_buckets):
            sel = lengths[b == i]
            if len(sel) == 0:
                continue
            waste_bucketed += float(np.sum(sel.max() - sel))
        waste_flat = float(np.sum(lengths.max() - lengths))
        tot = float(lengths.sum())
        return {
            "pad_waste_bucketed": waste_bucketed / (tot + waste_bucketed),
            "pad_waste_unbucketed": waste_flat / (tot + waste_flat),
            "counts": np.bincount(b, minlength=self.num_buckets).tolist(),
        }


def shard_batch(batch: dict, rules, mesh) -> dict:
    """device_put a global batch with the Rules' activation sharding."""
    import jax
    from jax.sharding import NamedSharding

    out = {}
    for k, v in batch.items():
        logical = ("act_batch", None) if v.ndim == 2 else ("act_batch", None, None)
        out[k] = jax.device_put(v, NamedSharding(mesh, rules(logical)))
    return out
