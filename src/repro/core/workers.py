"""Shared async-ingest worker pool for the store and the tenant registry.

``HistogramStore``'s single background thread and ``TenantRegistry``'s
worker pool used to be near-duplicate lock-sensitive code: the greedy
queue drain, the poison-row isolation retry, the enqueue-vs-close mutex
(a producer landing an item behind the shutdown sentinel would strand it,
leaking ``pending`` and wedging every later flush), and the
pending-count/condition bookkeeping that makes ``flush()`` deterministic.
This module is that logic, once — both planes now build an
:class:`IngestPool` with plane-specific callbacks, so fixes to the drain
loop land in one place.

Contract (the async-ingest consistency model of core/stream.py):

* ``submit(item, route)`` enqueues; items with the same route key stay
  FIFO (per-tenant prefix visibility in the registry; a single store uses
  one route).  Threads are started lazily and restarted transparently
  after ``close()``.
* Each worker drains whatever is already queued into one batch and calls
  ``apply_batch(batch)``.  If the batch raises, every item is retried
  alone — a poison item cannot take down its co-batched neighbours — and
  each individual failure is recorded as ``wrap_error(item, exc)`` under
  the pool condition (pairs with ``drain()``'s swap-read: a failure
  concurrent with a flush can neither vanish nor double-report).  The
  batch is the registry's cross-tenant unit of work: with a shared node
  arena its ``apply_batch`` pulls up every tenant touched by the drained
  batch with one merge dispatch per tree level (core/tenant.py
  ``_apply_groups_batched``), which is why workers drain greedily instead
  of applying item by item.
* ``on_batch_end(batch)``, when given, runs on the worker after every
  applied batch and *before* the pending count drops — the retention
  sweeper's slot: ``flush()`` returning implies the sweep ran on
  everything visible.  Its failures are recorded as
  ``wrap_error(None, exc)``.
* ``drain()`` blocks until everything submitted so far is processed and
  returns (swapping out) the accumulated error records; ``close()`` stops
  the workers after a final drain of each queue.  Nothing is
  timing-dependent: synchronization is by lock/condition only.

Write-ahead log: the durable-ingest contract
--------------------------------------------
The queue above is in-memory: a crash between ``submit`` and the next
flush silently loses partitions the persisted npz never saw.  With a
:class:`WriteAheadLog` attached (``IngestPool(wal=..., wal_record=...)``,
built by ``HistogramStore(wal_dir=...)`` / ``TenantRegistry(wal_dir=...)``)
every submitted partition is appended to a segmented on-disk log and
**fsynced before the submit call returns** — an acked partition can
always be replayed, so ``save``/``load`` become real checkpoint/restore
(``HistogramStore.recover`` / ``TenantRegistry.recover``).

**Record layout** (little-endian, one record per submitted partition)::

    magic  b"WAL1"                      4 bytes
    lsn    u64   log sequence number    8 bytes (monotonic, dense)
    crc32  u32   over header+payload    4 bytes
    hlen   u32   header length          4 bytes
    header utf-8 json                   hlen bytes
           {"tenant": str|null, "pid": int, "dtype": str,
            "shape": [...], "nbytes": int}
    payload raw little-endian array bytes   nbytes bytes

Records live in segment files ``wal-<first_lsn>.log``; a segment is
rotated once it exceeds ``segment_bytes`` (the outgoing segment is
fsynced at rotation, so a later group commit never needs to revisit it).
A new process always appends to a **fresh** segment — a torn tail from a
crash is never appended over.

**Epoch fencing (replication).**  Every fresh segment starts with a
12-byte header ``b"WEP1" + epoch u64`` stamping the writer's epoch
(segments without the header — the pre-replication format — read as
epoch 0).  The epoch is persisted in ``epoch.json`` next to the
segments, together with an optional ``fenced_at`` mark: ``fence(e)``
persists the mark and every later ``append`` on a log whose epoch is
below it raises :class:`~repro.core.resilience.PrimaryFenced` — a
follower promoted at epoch ``e`` (core/replication.py) permanently
rejects the deposed primary's late writes, even across a restart of the
deposed process.  ``segment_view()`` / ``read_segment()`` are the
shipping surface: the view reports each segment's safe-to-read byte
length (for the active segment, the flushed record-boundary position),
and a reader holding a path that ``truncate()`` deleted underneath it
gets a clean ``None`` ("segment rotated away") instead of a
FileNotFoundError masquerading as a torn tail.

**Fsync batching (group commit).** ``append`` buffers the record and
assigns its LSN; ``commit(lsn)`` returns once every append up to ``lsn``
is durable.  Concurrent committers share one ``os.fsync``: whoever takes
the commit lock first syncs *everything appended so far* and later
committers find their LSN already covered — acks are never issued before
durability, but N concurrent submits cost ~1 fsync, and batch ingest
(``ingest_many``) appends the whole batch then commits once.

**Truncation-on-save invariant.** The log tracks the contiguous
*applied* prefix (``stable_lsn``): a record is marked applied when its
batch leaves the worker (or when the synchronous ingest path applied
it).  ``save`` captures ``stable_lsn`` **before** reading the store
state — every record ≤ that LSN was applied before the snapshot was
taken, hence is covered by it — persists it as ``meta["wal_stable_lsn"]``
and, after the atomic rename succeeds, deletes every closed segment
whose records are all ≤ the captured LSN.  Log lifecycle is therefore
tied to checkpoints: the log holds exactly the suffix not yet covered by
a snapshot (plus the tail of the active segment).

**Idempotent-replay contract.** Recovery scans the segments in LSN
order, stopping at the first torn/corrupt record *of each segment* (a
torn tail is a record whose ack never returned — dropping it is
correct), then re-ingests records above the snapshot's
``wal_stable_lsn`` with **pid dedup reconciled against the persisted
watermark**: a pid already present is skipped (it was applied after the
stable capture but still made the snapshot), and a pid ≤ the tenant's
watermark is skipped (it was applied and later evicted by retention —
replay must not resurrect expired partitions).  Replay is idempotent:
recovering twice, or recovering a log whose records were all applied,
changes nothing.  Partition ids are assumed monotone per tenant
(they are the time axis), which is what makes the watermark rule sound.
A *poisoned* record (one whose apply permanently fails) is still marked
applied once its retry completes — the WAL guards against crashes, not
bad data: poison failures surface on ``flush()`` exactly once and are
not replayed forever.  ``ingest_summary`` bypasses the WAL (there are no
raw values to log); durability there remains snapshot-only.
"""
from __future__ import annotations

import binascii
import json
import os
import queue
import struct
import threading
import time
from typing import Callable, NamedTuple

import numpy as np

from repro.analysis.witness import OrderedLock, OrderedRLock
from repro.core import faults
from repro.core.resilience import (
    IngestBackpressure,
    PrimaryFenced,
    RetryPolicy,
    retry_call,
)

__all__ = [
    "IngestPool",
    "PartialBatchFailure",
    "PoolStateView",
    "WalRecord",
    "WriteAheadLog",
    "atomic_write_json",
    "read_segment_epoch",
    "scan_wal_bytes",
]

_SENTINEL = object()  # shuts down one pool worker

_WAL_MAGIC = b"WAL1"
_WAL_PREFIX = struct.Struct("<4sQII")  # magic, lsn, crc32, header_len

_SEG_MAGIC = b"WEP1"
_SEG_HEADER = struct.Struct("<4sQ")  # magic, writer epoch


def read_segment_epoch(data: bytes) -> tuple[int, int]:
    """``(epoch, header_bytes)`` of a segment's byte prefix.  Segments
    written before the epoch header existed start directly with a record
    and read as epoch 0 with a 0-byte header."""
    if len(data) >= _SEG_HEADER.size:
        magic, epoch = _SEG_HEADER.unpack_from(data, 0)
        if magic == _SEG_MAGIC:
            return int(epoch), _SEG_HEADER.size
    return 0, 0


def scan_wal_bytes(data: bytes, at: int = 0) -> tuple[list["WalRecord"], int]:
    """Parse complete records from ``data[at:]``; returns ``(records,
    next_at)`` where ``next_at`` sits just past the last complete record.
    A short/torn/corrupt suffix is left unconsumed — incremental tailers
    (the replication follower) re-try from ``next_at`` once more bytes
    arrive, and recovery counts it as the segment's torn tail."""
    records: list[WalRecord] = []
    while at < len(data):
        if at + _WAL_PREFIX.size > len(data):
            break  # torn/short prefix
        magic, lsn, crc, hlen = _WAL_PREFIX.unpack_from(data, at)
        if magic != _WAL_MAGIC:
            break
        body_at = at + _WAL_PREFIX.size
        if body_at + hlen > len(data):
            break  # torn/short header
        try:
            header = json.loads(data[body_at : body_at + hlen])
            nbytes = int(header["nbytes"])
        except (ValueError, KeyError, UnicodeDecodeError):
            break
        pay_at = body_at + hlen
        if pay_at + nbytes > len(data):
            break  # torn/short payload
        blob = data[body_at : pay_at + nbytes]
        if binascii.crc32(blob) != crc:
            break  # corrupt record
        values = np.frombuffer(
            data[pay_at : pay_at + nbytes], dtype=header["dtype"]
        ).reshape(header["shape"])
        records.append(
            WalRecord(
                lsn=int(lsn),
                tenant=header["tenant"],
                pid=int(header["pid"]),
                values=np.array(values),  # writable copy
            )
        )
        at = pay_at + nbytes
    return records, at


def mass_meta_path(dir: str) -> str:
    """The WAL directory's durable cumulative-mass ledger (mass.json)."""
    return os.path.join(str(dir), "mass.json")


def atomic_write_json(path: str, obj, *, fsync: bool = True) -> None:
    """Write small JSON state durably: tmp + fsync + rename (+ dir
    fsync), so a crash leaves either the old file or the new one."""
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(obj, f)
        f.flush()
        if fsync:
            os.fsync(f.fileno())
    os.replace(tmp, path)
    if fsync:
        dfd = os.open(os.path.dirname(path) or ".", os.O_RDONLY)
        try:
            os.fsync(dfd)
        finally:
            os.close(dfd)


class WalRecord(NamedTuple):
    """One durably-logged partition: ``lsn`` orders it, ``tenant`` routes
    it (``None`` for a standalone store), ``pid``/``values`` replay it."""

    lsn: int
    tenant: str | None
    pid: int
    values: np.ndarray


class WriteAheadLog:
    """Segmented on-disk write-ahead log (format: module docstring).

    Thread-safe: ``append`` serializes under the log lock, ``commit`` is
    a group-commit fsync, ``mark_applied`` advances the contiguous
    applied prefix that drives truncation.  Opening a directory with
    existing segments scans them once (recovered records are kept for
    :meth:`recovered_records`) and positions the next LSN after the last
    valid record; new appends go to a fresh segment.
    """

    def __init__(
        self,
        dir: str,
        *,
        segment_bytes: int = 4 << 20,
        fsync: bool = True,
        retry: RetryPolicy | None = None,
        epoch: int | None = None,
    ):
        self.dir = str(dir)
        self.segment_bytes = int(segment_bytes)
        self.fsync_enabled = bool(fsync)
        # transient-fault policy for the group-commit fsync: a flaky disk
        # (EIO that clears, momentary ENOSPC) heals inside commit() itself;
        # a persistently sick one exhausts the budget and the failure
        # propagates to the submitter as backpressure (IngestPool.submit)
        self.retry = retry if retry is not None else RetryPolicy(
            attempts=3, base=0.005, cap=0.1
        )
        os.makedirs(self.dir, exist_ok=True)
        # rank note (ANALYSIS.md): commit() nests _commit_lock OUTER and
        # _lock inner (grab the fd/lsn snapshot under _lock, fsync outside
        # it) — so _commit_lock ranks BELOW _lock in the hierarchy
        self._lock = OrderedLock("wal._lock")  # append/rotate/bookkeeping
        self._commit_lock = OrderedLock("wal._commit_lock")  # group-commit fsync
        self._fd = None  # active segment file object (lazy)
        self._fd_broken = False  # rollback failed → rotate before next write
        self._active_path: str | None = None
        # set by close(): cuts any in-flight backoff wait short
        self._interrupt = threading.Event()
        # telemetry counters (core/telemetry.py surfaces these)
        self.appends = 0
        self.fsyncs = 0
        self.fsync_retries = 0
        self.append_rollbacks = 0
        self.fsync_seconds = 0.0
        self.last_fsync_seconds = 0.0
        self.bytes_written = 0
        self.torn_records_dropped = 0
        # epoch fencing (module docstring): the writer's epoch is stamped
        # into every fresh segment header; fence() persists a fenced_at
        # mark that permanently rejects appends from lower-epoch writers
        disk_epoch, fenced_at = self._load_epoch_state()
        self.epoch = max(disk_epoch, 0 if epoch is None else int(epoch))
        self._fence_epoch: int | None = fenced_at
        if self.epoch != disk_epoch:
            self._store_epoch_state()
        # per-tenant cumulative appended mass (value counts) — the ship
        # manifest's drift currency (core/replication.py): a follower
        # bounds its staleness by manifest mass − mass it has scanned.
        # Truncation removes record *bytes* but their mass must survive
        # a reopen, or a follower attached after a checkpoint would
        # bound its drift at 0 and silently miss the snapshot-covered
        # prefix: ``_shed_mass`` (mass.json) is the durable ledger of
        # mass truncated out of the log, and ``_mass`` = shed + in-log.
        self._shed_mass, pending = self._load_mass_state()
        self._mass: dict = {k: v for k, v in self._shed_mass.items() if v}
        self._seg_mass: dict[str, dict] = {}  # path -> per-tenant mass
        # tracked segments found missing on disk by segment_view() —
        # out-of-band deletion, always an anomaly worth surfacing
        self.vanished_segments = 0
        # closed segments: path -> (first_lsn, last_valid_lsn)
        self._segments: dict[str, tuple[int, int]] = {}
        self._recovered: list[WalRecord] = []
        first = None
        last = 0
        had_pending = bool(pending)
        for path, first_lsn, records, torn, seg_epoch in self._scan():
            self._recovered.extend(records)
            self.torn_records_dropped += torn
            last_valid = records[-1].lsn if records else first_lsn - 1
            self._segments[path] = (first_lsn, last_valid)
            charged = pending.pop(os.path.basename(path), None)
            if charged is not None:
                # a truncate() crashed between charging this segment to
                # the shed ledger and unlinking it: the bytes are still
                # here (about to be counted by the scan) — un-charge
                for k, m in charged.items():
                    self._shed_mass[k] = self._shed_mass.get(k, 0) - int(m)
                    self._mass[k] = self._mass.get(k, 0) - int(m)
            seg_m = self._seg_mass.setdefault(path, {})
            for rec in records:
                key = rec.tenant
                self._mass[key] = self._mass.get(key, 0) + int(
                    rec.values.size
                )
                seg_m[key] = seg_m.get(key, 0) + int(rec.values.size)
            if first is None:
                first = first_lsn
            last = max(last, last_valid)
        if had_pending:
            # pending entries whose files are gone really were unlinked
            # (their mass stays shed); settle the ledger either way
            self._store_mass_state()
        self._next_lsn = last + 1
        self._written_lsn = last  # highest appended (durable: on disk)
        self._synced_lsn = last
        # contiguous applied prefix: everything ≤ _stable was applied
        # in-memory (→ covered by the next snapshot).  Records found on
        # disk start *unapplied*; replay marks them.
        self._stable = (first - 1) if first is not None else 0
        self._applied: set[int] = set()

    # ------------------------------------------------------------- append
    def append(self, tenant: str | None, pid: int, values) -> int:
        """Buffer one record into the active segment; returns its LSN.
        Durability requires a subsequent :meth:`commit`.

        **All-or-nothing on failure.**  A write that raises mid-record
        (ENOSPC, EIO, an injected torn write) must not leave a partial
        record in the segment: the torn-tail scan stops a segment at its
        first bad record, so stray bytes here would silently drop every
        *later* record in the segment at recovery.  On any write failure
        the segment is truncated back to the pre-append offset and the
        LSN is un-assigned (nothing else can have taken one — the lock is
        held); if even the rollback fails, the fd is marked broken and
        the next append rotates to a fresh segment, leaving the partial
        record as a scannable torn tail instead of a mid-segment hole.
        """
        v = np.ascontiguousarray(values)
        header = json.dumps(
            {
                "tenant": tenant,
                "pid": int(pid),
                "dtype": str(v.dtype),
                "shape": list(v.shape),
                "nbytes": int(v.nbytes),
            }
        ).encode()
        payload = v.tobytes()
        crc = binascii.crc32(payload, binascii.crc32(header))
        faults.hit("wal.append", tenant=tenant, pid=pid)
        with self._lock:
            if self._fence_epoch is not None and self.epoch < self._fence_epoch:
                # a follower was promoted past us: this log's writer is a
                # deposed primary and must never extend the history
                raise PrimaryFenced(self.epoch, self._fence_epoch)
            lsn = self._next_lsn
            if (
                self._fd is None
                or self._fd_broken
                or self._fd.tell() >= self.segment_bytes
            ):
                self._roll(lsn)
            buf = _WAL_PREFIX.pack(_WAL_MAGIC, lsn, crc, len(header))
            data = buf + header + payload
            pos = self._fd.tell()
            try:
                torn = faults.hit("wal.append.torn", lsn=lsn, size=len(data))
                if torn is not None:  # injected: write a prefix, then fail
                    self._fd.write(data[: int(torn)])
                    self._fd.flush()
                    raise OSError("injected torn write")
                self._fd.write(data)
                self._fd.flush()  # into the OS — commit() makes it durable
            except BaseException:
                self.append_rollbacks += 1
                try:  # roll the partial record back out of the segment
                    self._fd.seek(pos)
                    self._fd.truncate()
                except OSError:
                    self._fd_broken = True  # next append rotates
                raise
            self._next_lsn = lsn + 1
            self.appends += 1
            self.bytes_written += len(data)
            self._written_lsn = lsn
            self._mass[tenant] = self._mass.get(tenant, 0) + int(v.size)
            sm = self._seg_mass.setdefault(self._active_path, {})
            sm[tenant] = sm.get(tenant, 0) + int(v.size)
        return lsn

    def commit(self, upto: int | None = None) -> None:
        """Group commit: return once every append ≤ ``upto`` (default: all
        appends so far) is fsynced.  Concurrent committers share one
        fsync — the first through the lock syncs for everyone."""
        with self._lock:
            if upto is None:
                upto = self._written_lsn
        if not self.fsync_enabled:
            with self._lock:
                self._synced_lsn = max(self._synced_lsn, upto)
            return
        with self._commit_lock:
            if self._synced_lsn >= upto:
                return  # a concurrent committer's fsync covered us
            with self._lock:
                fd, latest = self._fd, self._written_lsn
            if fd is None:
                return

            def _sync() -> None:
                faults.hit("wal.fsync")
                os.fsync(fd.fileno())

            def _count(attempt: int, exc: BaseException) -> None:
                self.fsync_retries += 1

            t0 = time.perf_counter()
            # transient failures heal here (bounded backoff, jittered);
            # close() interrupts the wait, and the remaining attempts
            # still run — a persistent failure propagates to the
            # submitter, which surfaces it as backpressure
            retry_call(
                _sync,
                self.retry,
                wait=self._interrupt.wait,
                on_retry=_count,
            )
            dt = time.perf_counter() - t0
            self.fsyncs += 1
            self.fsync_seconds += dt
            self.last_fsync_seconds = dt
            # rotation fsyncs the outgoing segment, so syncing the active
            # fd covers every append ≤ latest
            self._synced_lsn = latest

    def log(self, tenant: str | None, pid: int, values) -> int:
        """:meth:`append` + :meth:`commit` — durable before return."""
        lsn = self.append(tenant, pid, values)
        self.commit(lsn)
        return lsn

    def _roll(self, first_lsn: int) -> None:
        """Rotate to a fresh segment (callers hold ``_lock``)."""
        if self._fd is not None:
            try:
                self._fd.flush()
                if self.fsync_enabled:
                    os.fsync(self._fd.fileno())
                synced = True
            except OSError:
                # a broken outgoing fd (failed append rollback): records
                # already committed were fsynced at their own commit; an
                # un-fsynced tail was never acked, and its loss is the
                # torn-tail scan's job — rotating away is the recovery
                synced = False
            try:
                self._fd.close()
            except OSError:
                pass
            self._fd_broken = False
            # every record in the outgoing segment is ≤ written_lsn and
            # now durable; it becomes a closed, truncatable segment
            self._segments[self._active_path] = (
                self._segments[self._active_path][0],
                self._written_lsn,
            )
            if synced:
                self._synced_lsn = max(self._synced_lsn, self._written_lsn)
        self._active_path = os.path.join(self.dir, f"wal-{first_lsn:020d}.log")
        self._fd = open(self._active_path, "wb")
        # stamp the writer's epoch (fencing: a promoted follower's scan
        # and the dir transport reject lower-epoch history)
        self._fd.write(_SEG_HEADER.pack(_SEG_MAGIC, self.epoch))
        self._fd.flush()
        self._segments[self._active_path] = (first_lsn, first_lsn - 1)

    # ------------------------------------------------------ epoch fencing
    def _epoch_path(self) -> str:
        return os.path.join(self.dir, "epoch.json")

    def _load_epoch_state(self) -> tuple[int, int | None]:
        try:
            with open(self._epoch_path()) as f:
                st = json.load(f)
            fenced = st.get("fenced_at")
            return int(st.get("epoch", 0)), (
                None if fenced is None else int(fenced)
            )
        except (FileNotFoundError, ValueError, OSError):
            return 0, None

    def _store_epoch_state(self) -> None:
        atomic_write_json(
            self._epoch_path(),
            {"epoch": self.epoch, "fenced_at": self._fence_epoch},
            fsync=self.fsync_enabled,
        )

    # -------------------------------------------------- mass ledger
    @staticmethod
    def _decode_mass(d: dict) -> dict:
        return {(None if k == "" else k): int(v) for k, v in d.items()}

    @staticmethod
    def _encode_mass(d: dict) -> dict:
        return {("" if k is None else str(k)): int(v) for k, v in d.items() if v}

    def _load_mass_state(self) -> tuple[dict, dict]:
        """``(shed, pending)`` from mass.json: per-tenant mass truncated
        out of the log forever, plus per-segment charges written just
        before an unlink (reconciled at open if the unlink never ran)."""
        try:
            with open(mass_meta_path(self.dir)) as f:
                st = json.load(f)
            return (
                self._decode_mass(st.get("shed") or {}),
                {
                    name: self._decode_mass(mm)
                    for name, mm in (st.get("pending") or {}).items()
                },
            )
        except (FileNotFoundError, ValueError, OSError):
            return {}, {}

    def _store_mass_state(self, pending: dict | None = None) -> None:
        atomic_write_json(
            mass_meta_path(self.dir),
            {
                "shed": self._encode_mass(self._shed_mass),
                "pending": {
                    name: self._encode_mass(mm)
                    for name, mm in (pending or {}).items()
                },
            },
            fsync=self.fsync_enabled,
        )

    def shed_mass_by_tenant(self) -> dict:
        """Per-tenant mass of records truncated out of this log — state
        a follower can only obtain through a snapshot bootstrap
        (core/replication.py ``Replicator.bootstrap``)."""
        with self._lock:
            return {k: v for k, v in self._shed_mass.items() if v}

    def fence(self, min_epoch: int) -> None:
        """Reject every future append unless this log's epoch is ≥
        ``min_epoch`` (:class:`PrimaryFenced`).  Persisted: a deposed
        primary that restarts and reopens its log stays fenced."""
        min_epoch = int(min_epoch)
        with self._lock:
            if self._fence_epoch is None or min_epoch > self._fence_epoch:
                self._fence_epoch = min_epoch
                self._store_epoch_state()

    # ------------------------------------------------------- ship surface
    def segment_view(self) -> list[dict]:
        """Snapshot of the live segments for a tail reader (the
        replication shipper), LSN order.  ``size`` is the byte length
        that is safe to read now: for the active segment the flushed
        position — between appends that is always a record boundary, so
        a bounded read never sees a half-written record (a failed
        rollback leaves a torn tail, which the follower's incremental
        scan simply refuses to consume until it is overwritten)."""
        with self._lock:
            out = []
            for path, (first, _last) in sorted(
                self._segments.items(), key=lambda kv: kv[1][0]
            ):
                active = path == self._active_path
                if active and self._fd is not None:
                    size = self._fd.tell()
                else:
                    try:
                        size = os.path.getsize(path)
                    except FileNotFoundError:
                        # vanished out-of-band (operator rm, not our
                        # truncate — that untracks first): count it so
                        # stats() surfaces the anomaly, and skip
                        self.vanished_segments += 1
                        continue
                out.append(
                    {
                        "path": path,
                        "first_lsn": first,
                        "size": int(size),
                        "active": active,
                    }
                )
            return out

    def read_segment(
        self, path: str, offset: int = 0, length: int | None = None
    ) -> bytes | None:
        """Read ``length`` bytes of a segment from ``offset`` for a tail
        reader.  Returns ``None`` — the clean "segment rotated away"
        signal — when the file vanished because :meth:`truncate` deleted
        it between the reader's :meth:`segment_view` listing and this
        read.  (Before this contract existed the race surfaced as a
        FileNotFoundError indistinguishable from a torn-tail
        misdiagnosis.)  A missing file the log still *tracks* is a real
        I/O fault and raises."""
        try:
            with open(path, "rb") as f:
                if offset:
                    f.seek(int(offset))
                return f.read(-1 if length is None else int(length))
        except FileNotFoundError:
            with self._lock:
                if path in self._segments:
                    raise  # tracked but unreadable: not a rotation
            return None

    def read_active(self, offset: int) -> tuple[str, bytes, int] | None:
        """``(path, data, size)`` of the active segment from ``offset``
        to its current flushed boundary — measured and read atomically
        under the log lock, so a concurrent append *rollback* (which
        shrinks the file back to the pre-append boundary) can never
        interleave between the measure and the read and hand the shipper
        bytes the primary just disowned.  ``size < offset`` tells the
        shipper to truncate its copy back to ``size``.  ``None`` when no
        segment is active yet."""
        offset = int(offset)
        with self._lock:
            if self._fd is None or self._active_path is None:
                return None
            path = self._active_path
            size = self._fd.tell()
            if size <= offset:
                return path, b"", size
            with open(path, "rb") as f:
                f.seek(offset)
                data = f.read(size - offset)
            return path, data, size

    def mass_by_tenant(self) -> dict:
        """Cumulative appended mass (value counts) per tenant route for
        the ship manifest (includes records recovered at open)."""
        with self._lock:
            return dict(self._mass)

    # ----------------------------------------------------- applied prefix
    def mark_applied(self, lsns) -> None:
        """Record that these LSNs were applied in-memory; advances the
        contiguous ``stable_lsn`` prefix that save-truncation uses."""
        with self._lock:
            for lsn in lsns:
                if lsn is not None:
                    self._applied.add(int(lsn))
            while self._stable + 1 in self._applied:
                self._applied.discard(self._stable + 1)
                self._stable += 1

    def ensure_position(self, last_lsn: int | None) -> None:
        """Advance the LSN horizon to at least ``last_lsn`` (idempotent).

        Recovery calls this with the snapshot's ``wal_stable_lsn``: if
        the log directory was emptied out-of-band (truncation itself
        always keeps the highest segment as an anchor) the next append
        must not reuse an LSN the snapshot already claims to cover —
        replay would silently skip it."""
        if last_lsn is None:
            return
        last_lsn = int(last_lsn)
        with self._lock:
            if self._next_lsn <= last_lsn:
                self._next_lsn = last_lsn + 1
                self._written_lsn = max(self._written_lsn, last_lsn)
                self._synced_lsn = max(self._synced_lsn, last_lsn)
                self._stable = max(self._stable, last_lsn)

    @property
    def stable_lsn(self) -> int:
        """Highest LSN of the contiguous applied prefix: every record ≤
        this was applied before *now*, so a snapshot whose state is read
        after this property returns covers all of them."""
        with self._lock:
            return self._stable

    # ------------------------------------------------------------ replay
    def recovered_records(self) -> list[WalRecord]:
        """The records found on disk when this log was opened, LSN order."""
        return list(self._recovered)

    def _scan(self):
        """Yield ``(path, first_lsn, [WalRecord], torn_count, epoch)``
        per segment in LSN order, stopping each segment at its first
        invalid record (torn tail ⇒ the ack for that record never
        returned).  A segment deleted by a concurrent :meth:`truncate`
        between the listing and the read is skipped — it rotated away
        with all of its records applied, which is not a torn tail."""
        try:
            names = sorted(
                n
                for n in os.listdir(self.dir)
                if n.startswith("wal-") and n.endswith(".log")
            )
        except FileNotFoundError:
            return
        for name in names:
            path = os.path.join(self.dir, name)
            try:
                first_lsn = int(name[len("wal-") : -len(".log")])
            except ValueError:
                continue  # not a segment file
            scanned = self._scan_segment(path)
            if scanned is None:
                continue  # rotated away under us
            records, torn, epoch = scanned
            yield path, first_lsn, records, torn, epoch

    @staticmethod
    def _scan_segment(path: str) -> tuple[list[WalRecord], int, int] | None:
        """``(records, torn_count, epoch)`` of one segment file, or
        ``None`` when the file vanished (truncated away concurrently)."""
        try:
            with open(path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            return None
        epoch, at = read_segment_epoch(data)
        records, end = scan_wal_bytes(data, at)
        return records, (0 if end >= len(data) else 1), epoch

    # -------------------------------------------------------- truncation
    def truncate(self, stable: int | None = None) -> list[str]:
        """Delete every *closed* segment whose records are all ≤ ``stable``
        (default: the current applied prefix) — the save-side half of the
        truncation-on-save invariant.  Returns the deleted paths.

        The segment with the highest first-LSN always survives (as does
        the active one): it anchors the LSN horizon, so a process that
        reopens a fully-truncated log can never hand out LSNs the last
        snapshot's ``wal_stable_lsn`` already claims to cover.
        """
        stable = self.stable_lsn if stable is None else int(stable)
        removed = []
        with self._lock:
            horizon = max(
                (first for first, _last in self._segments.values()),
                default=None,
            )
            victims = [
                path
                for path, (first, last_valid) in self._segments.items()
                if not (
                    path == self._active_path
                    or first == horizon
                    or last_valid > stable
                )
            ]
            if not victims:
                return removed
            # charge the victims' mass to the durable shed ledger BEFORE
            # unlinking (listed as "pending" so a crash in between is
            # reconciled at the next open): the ship manifest's
            # cumulative mass must never silently lose the truncated
            # prefix, or a follower's drift bound would read 0 while it
            # is missing snapshot-covered history
            pending = {
                os.path.basename(p): dict(self._seg_mass.get(p, {}))
                for p in victims
            }
            for mm in pending.values():
                for k, m in mm.items():
                    self._shed_mass[k] = self._shed_mass.get(k, 0) + int(m)
            self._store_mass_state(pending)
            for path in victims:
                try:
                    os.unlink(path)
                except FileNotFoundError:
                    pass  # already gone — its bytes left the log anyway
                except OSError:
                    # cannot remove (e.g. EACCES): the segment stays in
                    # the log — give its charged mass back
                    for k, m in pending.pop(os.path.basename(path)).items():
                        self._shed_mass[k] = (
                            self._shed_mass.get(k, 0) - int(m)
                        )
                    continue
                del self._segments[path]
                self._seg_mass.pop(path, None)
                removed.append(path)
            self._store_mass_state()  # settle: pending cleared
        return removed

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Telemetry snapshot: depth (appended-but-not-yet-applied
        records), fsync latency/counts, segment/byte footprint."""
        with self._lock:
            return {
                "appends": self.appends,
                "append_rollbacks": self.append_rollbacks,
                "fsyncs": self.fsyncs,
                "fsync_retries": self.fsync_retries,
                "fsync_seconds_total": self.fsync_seconds,
                "last_fsync_seconds": self.last_fsync_seconds,
                "bytes_written": self.bytes_written,
                "segments": len(self._segments),
                "depth": self._written_lsn - self._stable,
                "written_lsn": self._written_lsn,
                "synced_lsn": self._synced_lsn,
                "stable_lsn": self._stable,
                "records_recovered": len(self._recovered),
                "torn_records_dropped": self.torn_records_dropped,
                "epoch": self.epoch,
                "fence_epoch": self._fence_epoch,
                "vanished_segments": self.vanished_segments,
            }

    def close(self) -> None:
        self._interrupt.set()  # cut any in-flight commit backoff short
        with self._lock:
            if self._fd is not None:
                try:
                    self._fd.flush()
                    if self.fsync_enabled:
                        os.fsync(self._fd.fileno())
                finally:
                    self._fd.close()
                    self._fd = None


class PartialBatchFailure(Exception):
    """Raised by ``apply_batch`` to narrow the poison retry.

    When the callback knows which items of the batch are suspect (the
    registry applies per-tenant groups independently, so a failing group
    doesn't taint the groups that already applied), it raises this with
    just those items — the pool then retries *only them* one by one,
    instead of re-applying the whole batch.  Any other exception keeps
    the conservative whole-batch retry.
    """

    def __init__(self, items: list):
        super().__init__(f"{len(items)} item(s) failed")
        self.items = items


class PoolStateView:
    """Forwarding properties onto the owner's ``_pool`` (an IngestPool).

    Mixed into the store and the registry so their historical attribute
    surface keeps working — tests pin the error/flush synchronization by
    replacing ``_cv`` (and the per-owner errors alias) directly, and the
    pool reads these dynamically.  Each owner adds its own errors alias
    (``_async_errors`` / ``_errors``) since the record shapes differ.
    """

    @property
    def _cv(self) -> threading.Condition:
        return self._pool.cv

    @_cv.setter
    def _cv(self, value: threading.Condition) -> None:
        self._pool.cv = value

    @property
    def _pending(self) -> int:
        return self._pool.pending

    @property
    def _ingest_mutex(self) -> threading.Lock:
        return self._pool.ingest_mutex


class IngestPool:
    """Bounded-queue worker pool with batch drain + poison isolation."""

    def __init__(
        self,
        *,
        apply_batch: Callable[[list], None],
        wrap_error: Callable[[object, BaseException], object],
        workers: int = 1,
        queue_size: int = 1024,
        name: str = "ingest",
        on_batch_end: Callable[[list], None] | None = None,
        wal: "WriteAheadLog | None" = None,
        wal_record: Callable[[object], tuple] | None = None,
        retry: RetryPolicy | None = None,
    ):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        if wal is not None and wal_record is None:
            raise ValueError("wal requires a wal_record extractor")
        self.apply_batch = apply_batch
        self.wrap_error = wrap_error
        self.on_batch_end = on_batch_end
        # transient-fault policy: suspect items get this many attempts
        # (with interruptible backoff) before their error surfaces on
        # flush, and WAL appends retry under it before the submit is
        # rejected with backpressure
        self.retry = retry if retry is not None else RetryPolicy(
            attempts=3, base=0.005, cap=0.1
        )
        # durable-ingest plane (module docstring): every submit is
        # appended + group-commit-fsynced before it acks; wal_record maps
        # a queue item to its (tenant_route, pid, raw_values) log fields
        self.wal = wal
        self.wal_record = wal_record
        self.workers = int(workers)
        self.queue_size = int(queue_size)
        self.name = name
        # pending-count + error-record synchronization; owners may expose
        # (or tests may replace) this condition — always read via self.cv
        self.cv = threading.Condition(OrderedRLock("pool.cv"))
        self.pending = 0  # submitted-but-not-yet-processed items
        self.errors: list = []  # wrap_error records since the last drain
        # serializes submit against close(): without it a producer could
        # land an item behind the shutdown sentinel (or hit the torn-down
        # queue list) and strand it.  Workers never take this mutex, so
        # close() may hold it across join().
        self.ingest_mutex = OrderedLock("pool.ingest_mutex")
        self._state_lock = OrderedLock("pool._state_lock")  # queue/thread setup
        self._queues: list[queue.Queue] | None = None
        self._threads: list[threading.Thread] = []
        # set by close() BEFORE the sentinels go in: any worker sleeping
        # in a retry backoff wakes immediately, runs its remaining
        # attempts without sleeping, and reaches the sentinel — close()
        # never out-waits a backoff and never drops a retried batch
        self._closing = threading.Event()
        # self-healing observability (surfaced through health()/stats())
        self.batches = 0
        self.apply_retries = 0
        self.wal_append_retries = 0
        self.backpressure_rejects = 0
        # most recent backpressure rejection (reason/retry_after/at) —
        # health()["backpressure"] mirrors this so dashboards see pacing
        self.last_backpressure: dict | None = None
        # replication hook: called as on_durable() after a submit's WAL
        # commit lands (no pool locks held) — the Replicator ships here so
        # an ack implies the record reached every follower directory
        self.on_durable: Callable[[], None] | None = None

    # --------------------------------------------------------------- submit
    def submit(self, item, route: int = 0) -> None:
        """Enqueue one item (blocking only when the bounded queue is full).
        Items sharing ``route % workers`` are processed FIFO.

        With a WAL attached, the item is appended to the log before it is
        enqueued and fsynced (group commit) before this call returns — an
        acked submit is always replayable after a crash.  The fsync runs
        *outside* ``ingest_mutex`` so concurrent submitters batch into
        one fsync; a worker may apply the item before the fsync lands,
        which is harmless (if the process dies first, the ack never
        happened and the in-memory apply died with it).

        **Backpressure when the disk is sick.**  A WAL append that keeps
        failing after bounded retries rejects the submit with
        :class:`~repro.core.resilience.IngestBackpressure` — nothing is
        enqueued, the caller owns the partition and may resubmit.  If the
        append landed but the group-commit fsync failed after retries,
        the item is already queued (it will be applied in-memory) but the
        call still raises backpressure: the durability ack would be a
        lie, and the caller must know it.
        """
        lsn = None
        with self.ingest_mutex:
            self._ensure_workers()
            if self.wal is not None:
                try:
                    lsn = retry_call(
                        lambda: self.wal.append(*self.wal_record(item)),
                        self.retry,
                        wait=self._closing.wait,
                        # epoch fencing is permanent, not a sick disk:
                        # never retried, never wrapped in backpressure
                        retryable=lambda e: not isinstance(e, PrimaryFenced),
                        on_retry=self._count_append_retry,
                    )
                except PrimaryFenced:
                    raise
                except BaseException as e:
                    raise self._backpressure(
                        "append",
                        f"WAL append failed after "
                        f"{self.retry.attempts} attempt(s): {e!r}",
                    ) from e
            with self.cv:
                self.pending += 1
            self._queues[route % self.workers].put((item, lsn))
        if self.wal is not None:
            try:
                self.wal.commit(lsn)  # durable before the ack
            except BaseException as e:
                raise self._backpressure(
                    "fsync",
                    "WAL fsync failed after retries — the partition was "
                    f"accepted in-memory but is NOT durable: {e!r}",
                ) from e
            if self.on_durable is not None:
                # ship-before-ack: a raising shipper fails the submit, so
                # the producer never sees an ack the followers don't hold
                self.on_durable()

    def _backpressure(self, reason: str, message: str) -> IngestBackpressure:
        """Count + remember a backpressure rejection and build the
        exception with its pacing hint (satellite: retry-after)."""
        retry_after = self.retry.retry_after()
        self.backpressure_rejects += 1
        self.last_backpressure = {
            "reason": reason,
            "retry_after": retry_after,
            "at": time.time(),
        }
        return IngestBackpressure(message, retry_after=retry_after)

    def _count_append_retry(self, attempt: int, exc: BaseException) -> None:
        self.wal_append_retries += 1

    def _count_apply_retry(self, attempt: int, exc: BaseException) -> None:
        self.apply_retries += 1

    def _retry_wait(self, delay: float) -> None:
        """Interruptible backoff sleep of the worker's per-item retry.
        The ``pool.retry`` failpoint fires first, so tests can sequence a
        close() against a worker provably parked in this wait."""
        faults.hit("pool.retry", delay=delay)
        self._closing.wait(delay)

    def _ensure_workers(self) -> None:
        with self._state_lock:
            if self._queues is not None and all(
                t.is_alive() for t in self._threads
            ):
                return
            self._closing.clear()
            self._queues = [
                queue.Queue(maxsize=self.queue_size)
                for _ in range(self.workers)
            ]
            self._threads = [
                threading.Thread(
                    target=self._drain_loop,
                    args=(q,),
                    name=f"{self.name}-{i}",
                    daemon=True,
                )
                for i, q in enumerate(self._queues)
            ]
            for t in self._threads:
                t.start()

    # ---------------------------------------------------------------- drain
    def _drain_loop(self, q: queue.Queue) -> None:
        while True:
            entry = q.get()
            if entry is _SENTINEL:
                return
            batch = [entry]  # [(item, lsn)] — lsn None without a WAL
            stop = False
            while True:  # drain whatever else is already queued — one flush
                try:
                    nxt = q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    stop = True
                    break
                batch.append(nxt)
            self._run_batch(batch)
            if stop:
                return

    def _run_batch(self, batch: list) -> None:
        items = [item for item, _lsn in batch]
        try:
            try:
                # chaos site: a worker "crash" mid-batch — the whole
                # batch becomes suspect and rides the per-item retry
                faults.hit("pool.batch", size=len(items))
                self.apply_batch(items)
            except PartialBatchFailure as pf:
                suspects = pf.items
            except BaseException:
                suspects = items
            else:
                suspects = ()
            # isolate the poison rows: retry the suspect items one at a
            # time — each under the bounded backoff policy, so transient
            # faults heal on the worker — so a single bad item cannot
            # drop the valid items drained into the same batch (errors
            # surface on the owner's flush()).  The retries run HERE,
            # inside the batch, before the pending count drops — close()'s
            # shutdown sentinel (and drain()'s pending wait) therefore
            # cannot overtake an in-flight retry and drop the
            # still-pending non-poisoned items; the backoff sleeps wait
            # on the closing event, so close() bounds them without
            # skipping the remaining attempts (pinned by the
            # deterministic close-vs-retry interleavings in
            # tests/test_durability.py and tests/test_faults.py).
            for item in suspects:
                try:
                    retry_call(
                        lambda item=item: self.apply_batch([item]),
                        self.retry,
                        wait=self._retry_wait,
                        on_retry=self._count_apply_retry,
                    )
                except BaseException as e:
                    # build the record BEFORE taking cv: wrap_error may be
                    # a registry callback that trips the tenant's circuit
                    # breaker under registry._lock — taking that under cv
                    # inverts the lock hierarchy (witness-pinned in
                    # tests/test_lock_witness.py)
                    rec = self.wrap_error(item, e)
                    with self.cv:  # pairs with drain()'s swap-read
                        self.errors.append(rec)
            if self.on_batch_end is not None:
                try:
                    self.on_batch_end(items)
                except BaseException as e:
                    rec = self.wrap_error(None, e)  # outside cv, as above
                    with self.cv:
                        self.errors.append(rec)
        finally:
            if self.wal is not None:
                # the whole batch — poison included — is done with the
                # worker: advance the applied prefix so truncation-on-save
                # can reclaim its segments (the WAL guards against
                # crashes, not bad data; poison errors surfaced above)
                self.wal.mark_applied(lsn for _item, lsn in batch)
            with self.cv:
                self.batches += 1
                self.pending -= len(batch)
                self.cv.notify_all()

    # ----------------------------------------------------------- lifecycle
    def drain(self) -> list:
        """Block until every submitted item is processed; swap out and
        return the accumulated error records (the owner formats/raises)."""
        with self.cv:
            while self.pending > 0:
                self.cv.wait()
            # swap-read under cv: workers append under the same lock, so a
            # batch failing concurrently with this drain can neither vanish
            # into the swapped-out list nor be reported twice
            errs, self.errors = self.errors, []
        return errs

    def close(self) -> None:
        """Drain each queue, stop the workers.  Safe to call repeatedly;
        the next submit() restarts the pool transparently.

        Bounded even against an in-flight retry backoff: the closing
        event is set *before* the sentinels go in, so a worker parked in
        a backoff sleep wakes immediately, finishes its remaining retry
        attempts without sleeping, and reaches the sentinel — the
        retried batch is never dropped and the join never out-waits a
        backoff schedule."""
        self._closing.set()
        with self.ingest_mutex:
            with self._state_lock:
                threads, queues = self._threads, self._queues
                self._threads, self._queues = [], None
            if queues is not None:
                for q in queues:
                    q.put(_SENTINEL)
                for t in threads:
                    t.join()

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Self-healing counters for health()/telemetry surfaces."""
        with self.cv:
            pending = self.pending
            errors_pending = len(self.errors)
            batches = self.batches
        return {
            "pending": pending,
            "errors_pending": errors_pending,
            "batches": batches,
            "apply_retries": self.apply_retries,
            "wal_append_retries": self.wal_append_retries,
            "backpressure_rejects": self.backpressure_rejects,
            "backpressure": self.last_backpressure,
        }
