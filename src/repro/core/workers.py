"""Shared async-ingest worker pool for the store and the tenant registry.

``HistogramStore``'s single background thread and ``TenantRegistry``'s
worker pool used to be near-duplicate lock-sensitive code: the greedy
queue drain, the poison-row isolation retry, the enqueue-vs-close mutex
(a producer landing an item behind the shutdown sentinel would strand it,
leaking ``pending`` and wedging every later flush), and the
pending-count/condition bookkeeping that makes ``flush()`` deterministic.
This module is that logic, once — both planes now build an
:class:`IngestPool` with plane-specific callbacks, so fixes to the drain
loop land in one place.

Contract (the async-ingest consistency model of core/stream.py):

* ``submit(item, route)`` enqueues; items with the same route key stay
  FIFO (per-tenant prefix visibility in the registry; a single store uses
  one route).  Threads are started lazily and restarted transparently
  after ``close()``.
* Each worker drains whatever is already queued into one batch and calls
  ``apply_batch(batch)``.  If the batch raises, every item is retried
  alone — a poison item cannot take down its co-batched neighbours — and
  each individual failure is recorded as ``wrap_error(item, exc)`` under
  the pool condition (pairs with ``drain()``'s swap-read: a failure
  concurrent with a flush can neither vanish nor double-report).  The
  batch is the registry's cross-tenant unit of work: with a shared node
  arena its ``apply_batch`` pulls up every tenant touched by the drained
  batch with one merge dispatch per tree level (core/tenant.py
  ``_apply_groups_batched``), which is why workers drain greedily instead
  of applying item by item.
* ``on_batch_end(batch)``, when given, runs on the worker after every
  applied batch and *before* the pending count drops — the retention
  sweeper's slot: ``flush()`` returning implies the sweep ran on
  everything visible.  Its failures are recorded as
  ``wrap_error(None, exc)``.
* ``drain()`` blocks until everything submitted so far is processed and
  returns (swapping out) the accumulated error records; ``close()`` stops
  the workers after a final drain of each queue.  Nothing is
  timing-dependent: synchronization is by lock/condition only.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

__all__ = ["IngestPool", "PartialBatchFailure", "PoolStateView"]

_SENTINEL = object()  # shuts down one pool worker


class PartialBatchFailure(Exception):
    """Raised by ``apply_batch`` to narrow the poison retry.

    When the callback knows which items of the batch are suspect (the
    registry applies per-tenant groups independently, so a failing group
    doesn't taint the groups that already applied), it raises this with
    just those items — the pool then retries *only them* one by one,
    instead of re-applying the whole batch.  Any other exception keeps
    the conservative whole-batch retry.
    """

    def __init__(self, items: list):
        super().__init__(f"{len(items)} item(s) failed")
        self.items = items


class PoolStateView:
    """Forwarding properties onto the owner's ``_pool`` (an IngestPool).

    Mixed into the store and the registry so their historical attribute
    surface keeps working — tests pin the error/flush synchronization by
    replacing ``_cv`` (and the per-owner errors alias) directly, and the
    pool reads these dynamically.  Each owner adds its own errors alias
    (``_async_errors`` / ``_errors``) since the record shapes differ.
    """

    @property
    def _cv(self) -> threading.Condition:
        return self._pool.cv

    @_cv.setter
    def _cv(self, value: threading.Condition) -> None:
        self._pool.cv = value

    @property
    def _pending(self) -> int:
        return self._pool.pending

    @property
    def _ingest_mutex(self) -> threading.Lock:
        return self._pool.ingest_mutex


class IngestPool:
    """Bounded-queue worker pool with batch drain + poison isolation."""

    def __init__(
        self,
        *,
        apply_batch: Callable[[list], None],
        wrap_error: Callable[[object, BaseException], object],
        workers: int = 1,
        queue_size: int = 1024,
        name: str = "ingest",
        on_batch_end: Callable[[list], None] | None = None,
    ):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.apply_batch = apply_batch
        self.wrap_error = wrap_error
        self.on_batch_end = on_batch_end
        self.workers = int(workers)
        self.queue_size = int(queue_size)
        self.name = name
        # pending-count + error-record synchronization; owners may expose
        # (or tests may replace) this condition — always read via self.cv
        self.cv = threading.Condition()
        self.pending = 0  # submitted-but-not-yet-processed items
        self.errors: list = []  # wrap_error records since the last drain
        # serializes submit against close(): without it a producer could
        # land an item behind the shutdown sentinel (or hit the torn-down
        # queue list) and strand it.  Workers never take this mutex, so
        # close() may hold it across join().
        self.ingest_mutex = threading.Lock()
        self._state_lock = threading.Lock()  # guards queue/thread setup
        self._queues: list[queue.Queue] | None = None
        self._threads: list[threading.Thread] = []

    # --------------------------------------------------------------- submit
    def submit(self, item, route: int = 0) -> None:
        """Enqueue one item (blocking only when the bounded queue is full).
        Items sharing ``route % workers`` are processed FIFO."""
        with self.ingest_mutex:
            self._ensure_workers()
            with self.cv:
                self.pending += 1
            self._queues[route % self.workers].put(item)

    def _ensure_workers(self) -> None:
        with self._state_lock:
            if self._queues is not None and all(
                t.is_alive() for t in self._threads
            ):
                return
            self._queues = [
                queue.Queue(maxsize=self.queue_size)
                for _ in range(self.workers)
            ]
            self._threads = [
                threading.Thread(
                    target=self._drain_loop,
                    args=(q,),
                    name=f"{self.name}-{i}",
                    daemon=True,
                )
                for i, q in enumerate(self._queues)
            ]
            for t in self._threads:
                t.start()

    # ---------------------------------------------------------------- drain
    def _drain_loop(self, q: queue.Queue) -> None:
        while True:
            item = q.get()
            if item is _SENTINEL:
                return
            batch = [item]
            stop = False
            while True:  # drain whatever else is already queued — one flush
                try:
                    nxt = q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    stop = True
                    break
                batch.append(nxt)
            self._run_batch(batch)
            if stop:
                return

    def _run_batch(self, batch: list) -> None:
        try:
            try:
                self.apply_batch(batch)
            except PartialBatchFailure as pf:
                suspects = pf.items
            except BaseException:
                suspects = batch
            else:
                suspects = ()
            # isolate the poison rows: retry the suspect items one at a
            # time so a single bad item cannot drop the valid items
            # drained into the same batch (errors surface on the owner's
            # flush())
            for item in suspects:
                try:
                    self.apply_batch([item])
                except BaseException as e:
                    with self.cv:  # pairs with drain()'s swap-read
                        self.errors.append(self.wrap_error(item, e))
            if self.on_batch_end is not None:
                try:
                    self.on_batch_end(batch)
                except BaseException as e:
                    with self.cv:
                        self.errors.append(self.wrap_error(None, e))
        finally:
            with self.cv:
                self.pending -= len(batch)
                self.cv.notify_all()

    # ----------------------------------------------------------- lifecycle
    def drain(self) -> list:
        """Block until every submitted item is processed; swap out and
        return the accumulated error records (the owner formats/raises)."""
        with self.cv:
            while self.pending > 0:
                self.cv.wait()
            # swap-read under cv: workers append under the same lock, so a
            # batch failing concurrently with this drain can neither vanish
            # into the swapped-out list nor be reported twice
            errs, self.errors = self.errors, []
        return errs

    def close(self) -> None:
        """Drain each queue, stop the workers.  Safe to call repeatedly;
        the next submit() restarts the pool transparently."""
        with self.ingest_mutex:
            with self._state_lock:
                threads, queues = self._threads, self._queues
                self._threads, self._queues = [], None
            if queues is not None:
                for q in queues:
                    q.put(_SENTINEL)
                for t in threads:
                    t.join()
