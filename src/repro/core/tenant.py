"""Multi-tenant registry: many named HistogramStores, one serving plane.

A production deployment of the paper's Summarizer/Merger framework tracks
not one metric but thousands — per-service latency, per-table scan sizes,
per-gradient-leaf magnitudes.  One ``HistogramStore`` + ``IntervalTree``
per metric answers each tenant correctly, but N tenants then cost N query
dispatches per dashboard refresh and N independent ingest threads.  The
``TenantRegistry`` keeps the stores (shared configuration, one per named
tenant) and collapses the two hot cross-tenant paths:

Cross-tenant batched queries (one XLA dispatch)
-----------------------------------------------
``query_many([(tenant, lo, hi), ...], beta)`` resolves each query's
canonical segment-tree node set inside its own tenant's tree, then packs
*all* miss selections — across tenants — into one static-shape
``(Q, k_pad, T_pad)`` block and answers the whole batch with a single
jitted ``merge_stacks`` call (the same free function the per-tree engine
uses; stacking node sets from different trees is sound because only the
summary arrays matter and the shared registry configuration keeps ``T``
uniform).  Per-tenant LRU answer caches are consulted first and populated
after, exactly like the single-tree ``query_many``, so a repeated
dashboard batch costs zero dispatches.

Consistency: each answer is a consistent snapshot of *its* tenant (node
selection happens under that store's lock); there is no cross-tenant
barrier — two tenants' answers in one batch may reflect different ingest
frontiers, which is the right contract for independent metrics.

Shared async ingest (one worker pool)
-------------------------------------
``ingest_async(tenant, pid, values)`` fans every tenant's partitions into
a single bounded-queue worker pool instead of one thread per store.  Each
drained batch is grouped by tenant and summarized with the store's grouped
one-dispatch summarizer; per-partition failures are isolated (the batch is
retried row by row) and surface on :meth:`flush`, which blocks until
everything enqueued so far is visible.  With ``workers > 1`` partitions
are routed to a worker by a stable hash of the tenant name, so per-tenant
FIFO prefix visibility is preserved (global cross-tenant ordering is not —
again the right contract for independent metrics).

Shared persistence (one npz, atomic)
------------------------------------
``save``/``load`` hold every tenant in a single npz written with the same
mkstemp + rename discipline as ``HistogramStore.save`` — a crash leaves
either the complete old registry or the complete new one.  Array keys are
namespaced ``t{i}_`` per tenant via ``HistogramStore._state``.
"""
from __future__ import annotations

import json
import queue
import threading
from typing import Sequence

import numpy as np

from repro.core.histogram import Histogram
from repro.core.interval_tree import (
    merge_stacks,
    pack_node_rows,
    selection_eps,
)
from repro.core.stream import HistogramStore, _validated, atomic_savez

__all__ = ["TenantRegistry"]

_SENTINEL = object()  # shuts down one pool worker

_SCHEMA = "tenant_registry/v1"


class TenantRegistry:
    """Many named stores, shared config, one-dispatch cross-tenant serving."""

    def __init__(
        self,
        num_buckets: int,
        *,
        engine: str = "tree",
        T_node: int | str | None = None,
        cache_size: int = 128,
        queue_size: int = 4096,
        workers: int = 1,
    ):
        if workers < 1:
            raise ValueError("workers must be >= 1")
        self.num_buckets = int(num_buckets)
        self.engine = engine
        self.T_node = T_node
        self.cache_size = int(cache_size)
        self.queue_size = int(queue_size)
        self.workers = int(workers)
        self._stores: dict[str, HistogramStore] = {}
        self._lock = threading.RLock()  # guards the tenant dict + pool setup
        # shared ingest pool state (mirrors HistogramStore's single worker)
        # serializes enqueue against close(): without it a producer could
        # land an item behind a shutdown sentinel (or hit the torn-down
        # queue list) and strand it, leaking _pending and wedging flush.
        # Workers never take this mutex, so close() holds it across join().
        self._ingest_mutex = threading.Lock()
        self._cv = threading.Condition()
        self._pending = 0
        self._queues: list[queue.Queue] | None = None
        self._threads: list[threading.Thread] = []
        # every failed partition since the last flush: [(tenant, pid, exc)]
        self._errors: list[tuple[str, int, BaseException]] = []
        # cross-tenant merge dispatch observability (summarize_shapes-style)
        self.merge_dispatches = 0
        self.merge_shapes: set[tuple[int, int, int, int]] = set()

    # -------------------------------------------------------------- tenants
    def tenant(self, name: str) -> HistogramStore:
        """Get-or-create the named store (shared registry configuration).

        Names are str()-normalized everywhere (lookup and storage alike),
        so ``reg.tenant(5)`` and ``reg.tenant("5")`` are the same tenant.
        Stores are created synchronous (``async_ingest=False``) — the
        registry's own worker pool is the async plane.
        """
        name = str(name)
        with self._lock:
            store = self._stores.get(name)
            if store is None:
                store = HistogramStore(
                    num_buckets=self.num_buckets,
                    engine=self.engine,
                    T_node=self.T_node,
                    cache_size=self.cache_size,
                )
                self._stores[name] = store
            return store

    def __getitem__(self, name: str) -> HistogramStore:
        with self._lock:
            try:
                return self._stores[str(name)]
            except KeyError:
                raise KeyError(f"unknown tenant: {name!r}") from None

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return str(name) in self._stores

    def __len__(self) -> int:
        with self._lock:
            return len(self._stores)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._stores)

    # ----------------------------------------------------------- Summarizer
    def ingest(self, tenant: str, partition_id: int, values):
        """Synchronous single-partition ingest into the named tenant."""
        return self.tenant(tenant).ingest(partition_id, values)

    def ingest_many(self, tenant: str, partitions: dict[int, np.ndarray]) -> None:
        """Grouped one-dispatch bulk ingest into the named tenant."""
        self.tenant(tenant).ingest_many(partitions)

    def ingest_async(self, tenant: str, partition_id: int, values) -> None:
        """Enqueue one partition for the shared background worker pool.

        Validation is synchronous (a bad partition fails the caller, not
        the pool); visibility comes with the worker's next flush of the
        batch — call :meth:`flush` to wait for everything enqueued so far.
        """
        values = _validated(values)
        name = str(tenant)
        self.tenant(name)  # create eagerly: queries can see the tenant
        with self._ingest_mutex:
            self._ensure_pool()
            with self._cv:
                self._pending += 1
            # stable per-tenant routing keeps each tenant's partitions FIFO
            q = self._queues[self._route(name)]
            q.put((name, int(partition_id), values))

    def flush(self) -> None:
        """Block until every enqueued partition is visible; surface errors.

        Re-raises (wrapped) every per-partition failure the pool hit since
        the last flush; valid partitions co-batched with a poison one are
        retried and applied individually, so the pool never wedges.
        """
        with self._cv:
            while self._pending > 0:
                self._cv.wait()
            errs, self._errors = self._errors, []
        if errs:
            detail = "; ".join(
                f"tenant {t!r} partition {pid}: {e!r}" for t, pid, e in errs
            )
            raise RuntimeError(
                f"async ingest failed for {len(errs)} partition(s): {detail}"
            ) from errs[0][2]

    def close(self) -> None:
        """Drain the pool, stop its workers, surface pending errors."""
        with self._ingest_mutex:
            with self._lock:
                threads, queues = self._threads, self._queues
                self._threads, self._queues = [], None
            if queues is not None:
                for q in queues:
                    q.put(_SENTINEL)
                for t in threads:
                    t.join()
        self.flush()

    def _route(self, name: str) -> int:
        # hash() is salted per process but stable within one — all that
        # per-tenant FIFO needs
        return hash(name) % self.workers

    def _ensure_pool(self) -> None:
        with self._lock:
            if self._queues is not None and all(
                t.is_alive() for t in self._threads
            ):
                return
            self._queues = [
                queue.Queue(maxsize=self.queue_size)
                for _ in range(self.workers)
            ]
            self._threads = [
                threading.Thread(
                    target=self._drain_loop,
                    args=(q,),
                    name=f"tenant-ingest-{i}",
                    daemon=True,
                )
                for i, q in enumerate(self._queues)
            ]
            for t in self._threads:
                t.start()

    def _drain_loop(self, q: queue.Queue) -> None:
        while True:
            item = q.get()
            if item is _SENTINEL:
                return
            batch = [item]
            stop = False
            while True:  # drain whatever else is already queued — one flush
                try:
                    nxt = q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _SENTINEL:
                    stop = True
                    break
                batch.append(nxt)
            self._flush_batch(batch)
            if stop:
                return

    def _flush_batch(
        self, batch: list[tuple[str, int, np.ndarray]]
    ) -> None:
        try:
            groups: dict[str, dict[int, np.ndarray]] = {}
            for name, pid, values in batch:
                groups.setdefault(name, {})[pid] = values
            for name, parts in groups.items():
                store = self.tenant(name)
                try:
                    store._apply(store._summarize_batch(parts))
                except BaseException:
                    # isolate poison rows: retry one partition at a time so
                    # a single bad partition cannot drop its co-batched
                    # valid neighbours (errors surface on flush())
                    for pid, values in parts.items():
                        try:
                            store._apply(store._summarize_batch({pid: values}))
                        except BaseException as e:
                            with self._cv:  # pairs with flush's swap-read
                                self._errors.append((name, pid, e))
        finally:
            with self._cv:
                self._pending -= len(batch)
                self._cv.notify_all()

    # --------------------------------------------------------------- Merger
    def query(
        self, tenant: str, lo: int, hi: int, beta: int, **kwargs
    ) -> tuple[Histogram, float]:
        """Single-tenant query — delegates to the named store."""
        return self[tenant].query(lo, hi, beta, **kwargs)

    def query_many(
        self,
        queries: Sequence[tuple[str, int, int]],
        beta: int,
        *,
        strict: bool = True,
    ) -> list[tuple[Histogram | None, float]]:
        """Answer ``[(tenant, lo, hi), ...]`` with ≤ one merge dispatch.

        Each query's canonical node set is collected under its own store's
        lock (per-tenant snapshot consistency), per-tenant LRU caches are
        consulted first, and all misses — deduplicated, across tenants —
        are packed into one static-shape block and merged by a single
        jitted ``merge_stacks`` call.  Answers are returned in query order
        (stable indexing) and populated back into each tenant's cache.

        ``strict=False`` applies the store-level summary-loss contract per
        query: an unknown tenant or an interval with zero present summaries
        yields the placeholder ``(None, float("inf"))`` instead of killing
        the batch; with ``strict=True`` both raise ``KeyError``.
        """
        results: list[tuple[Histogram | None, float] | None] = [None] * len(
            queries
        )
        # mkey (store id + cache key) → (miss row, result slots)
        miss_map: dict[tuple, tuple[int, list[int]]] = {}
        miss_sels: list[list] = []
        miss_meta: list[tuple[HistogramStore, tuple]] = []
        for qi, (name, lo, hi) in enumerate(queries):
            if not strict and name not in self:
                results[qi] = (None, float("inf"))
                continue
            store = self[name]
            tree = store._tree
            with store._lock:
                ids = [
                    i for i in range(lo, hi + 1) if i in store.summaries
                ]
                if strict and len(ids) != hi - lo + 1:
                    missing = sorted(set(range(lo, hi + 1)) - set(ids))
                    raise KeyError(
                        f"tenant {name!r}: missing partition summaries: "
                        f"{missing}"
                    )
                keys = store._sync_tree(ids, lo, hi)
                if not ids:
                    if strict:
                        raise KeyError(
                            f"tenant {name!r}: no partition summaries in "
                            f"requested interval"
                        )
                    results[qi] = (None, float("inf"))
                    continue
                key = (int(lo), int(hi), int(beta), tree.version)
                mkey = (id(store), key)
                prior = miss_map.get(mkey)
                if prior is not None:  # duplicate within this batch
                    prior[1].append(qi)
                    continue
                hit = tree._cache_get(key)
                if hit is not None:
                    results[qi] = hit
                    continue
                tree.cache_misses += 1
                sel = [tree.nodes[k] for k in keys]
                miss_map[mkey] = (len(miss_sels), [qi])
                miss_sels.append(sel)
                miss_meta.append((store, key))
        if miss_sels:
            # ONE cross-tenant merge dispatch for the whole batch; TreeNode
            # summaries are immutable, so packing outside the store locks
            # is safe
            bounds, sizes = pack_node_rows(miss_sels)
            with self._lock:  # counters are read by concurrent servers
                self.merge_dispatches += 1
                self.merge_shapes.add(bounds.shape + (int(beta),))
            bo, so = merge_stacks(bounds, sizes, int(beta))
            # one device→host transfer; per-row unpacking is then free views
            bo, so = np.asarray(bo), np.asarray(so)
            for row, slots in miss_map.values():
                store, key = miss_meta[row]
                out = (
                    Histogram(bo[row], so[row]),
                    selection_eps(miss_sels[row]),
                )
                with store._lock:
                    store._tree._cache_put(key, out)
                for qi in slots:
                    results[qi] = out
        return results

    # ---------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Atomic one-npz write of every tenant (summaries + tree nodes)."""
        with self._lock:
            names = sorted(self._stores)
            payload: dict[str, np.ndarray] = {}
            stores_meta: dict[str, dict] = {}
            for i, name in enumerate(names):
                store = self._stores[name]
                with store._lock:
                    meta_i, payload_i = store._state(prefix=f"t{i}_")
                stores_meta[name] = meta_i
                payload.update(payload_i)
            meta = {
                "schema": _SCHEMA,
                "num_buckets": self.num_buckets,
                "engine": self.engine,
                "T_node": self.T_node,
                "cache_size": self.cache_size,
                "tenants": names,
                "stores": stores_meta,
            }
        atomic_savez(path, meta, payload)

    @classmethod
    def load(cls, path: str) -> "TenantRegistry":
        with np.load(path, allow_pickle=False) as data:
            meta = json.loads(str(data["meta"]))
            if meta.get("schema") != _SCHEMA:
                raise ValueError(
                    f"not a tenant registry file: schema="
                    f"{meta.get('schema')!r}"
                )
            T_node = meta.get("T_node")
            reg = cls(
                num_buckets=int(meta["num_buckets"]),
                engine=str(meta.get("engine", "tree")),
                T_node=(
                    T_node if T_node in (None, "geometric") else int(T_node)
                ),
                cache_size=int(meta.get("cache_size", 128)),
            )
            for i, name in enumerate(meta["tenants"]):
                store = reg.tenant(name)
                store._restore(meta["stores"][name], data, prefix=f"t{i}_")
        return reg

    # ------------------------------------------------------------- utility
    def cache_stats(self) -> dict[str, int]:
        """Aggregated per-tenant cache counters + registry dispatch count."""
        with self._lock:
            stores = list(self._stores.values())
        hits = sum(s._tree.cache_hits for s in stores)
        misses = sum(s._tree.cache_misses for s in stores)
        return {
            "hits": hits,
            "misses": misses,
            "merge_dispatches": self.merge_dispatches,
            "merge_shapes": len(self.merge_shapes),
        }
