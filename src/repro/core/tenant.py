"""Multi-tenant registry: many named HistogramStores, one serving plane.

A production deployment of the paper's Summarizer/Merger framework tracks
not one metric but thousands — per-service latency, per-table scan sizes,
per-gradient-leaf magnitudes.  One ``HistogramStore`` + ``IntervalTree``
per metric answers each tenant correctly, but N tenants then cost N query
dispatches per dashboard refresh and N independent ingest threads.  The
``TenantRegistry`` keeps the stores (shared configuration, one per named
tenant) and collapses the two hot cross-tenant paths:

Cross-tenant batched queries (one XLA dispatch)
-----------------------------------------------
``query_many([(tenant, lo, hi), ...], beta)`` resolves each query's
canonical segment-tree node set inside its own tenant's tree, then packs
*all* miss selections — across tenants — into one static-shape
``(Q, k_pad, T_pad)`` block and answers the whole batch with a single
jitted ``merge_stacks`` call (the same free function the per-tree engine
uses; stacking node sets from different trees is sound because only the
summary arrays matter and the shared registry configuration keeps ``T``
uniform).  Per-tenant LRU answer caches are consulted first and populated
after, exactly like the single-tree ``query_many``, so a repeated
dashboard batch costs zero dispatches.

Consistency: each answer is a consistent snapshot of *its* tenant (node
selection happens under that store's lock); there is no cross-tenant
barrier — two tenants' answers in one batch may reflect different ingest
frontiers, which is the right contract for independent metrics.

Shared async ingest (one worker pool)
-------------------------------------
``ingest_async(tenant, pid, values)`` fans every tenant's partitions into
a single bounded-queue worker pool instead of one thread per store.  Each
drained batch is grouped by tenant and summarized with the store's grouped
one-dispatch summarizer; per-partition failures are isolated (the batch is
retried row by row) and surface on :meth:`flush`, which blocks until
everything enqueued so far is visible.  With ``workers > 1`` partitions
are routed to a worker by a stable hash of the tenant name, so per-tenant
FIFO prefix visibility is preserved (global cross-tenant ordering is not —
again the right contract for independent metrics).

Shared persistence (one npz, atomic)
------------------------------------
``save``/``load`` hold every tenant in a single npz written with the same
mkstemp + fsync + rename discipline as ``HistogramStore.save`` — a crash
leaves either the complete old registry or the complete new one.  Array
keys are namespaced ``t{i}_`` per tenant via ``HistogramStore._state``
(which also carries each tenant's retention watermark).

Durable ingest (``wal_dir=...``)
--------------------------------
One registry-owned write-ahead log covers every tenant: each submitted
partition (sync or async) is appended with its tenant route and fsynced
before the ingest call acks, ``save`` becomes a checkpoint that
truncates covered log segments, and ``recover(path, wal_dir)`` restores
snapshot + uncovered log suffix — so a crash between enqueue and flush
loses nothing that was acked.  Contract details (record layout, group
commit, truncation-on-save, idempotent replay) live in core/workers.py.

Retention and registry-wide memory budgets
------------------------------------------
Two bounded-memory layers compose (core/retention.py):

* ``retention=`` — a per-tenant :class:`RetentionPolicy` shared by every
  store the registry creates (TTL / sliding window / per-store budget);
  the pool worker sweeps the tenants touched by each drained batch
  between flushes, and synchronous ingest sweeps inline.
* ``budget=`` — a **global node-float budget across tenants**.  When the
  summed footprint exceeds it, :meth:`enforce_budget` evicts oldest
  partitions from the **largest-over-quota tenant first** (fair quota =
  budget / #tenants), never below a tenant's newest partition, until the
  registry fits — so thousands of tenants share one bounded memory
  envelope and a single noisy tenant cannot squeeze out the rest.
  Per-tenant footprints are cached per store version, so the steady-state
  check costs O(#tenants) dict lookups, not O(#nodes) scans.

Both planes ride the shared :class:`~repro.core.workers.IngestPool`
(drain/poison-isolation/flush/close live in one place — this used to be
near-duplicate lock-sensitive code in the store and the registry).

Shared node-storage arena (``shared_arena=True``)
-------------------------------------------------
Every same-config tenant's tree nodes can pool into ONE registry-owned
:class:`~repro.core.arena.NodeArena` (one device-resident ``(n_slots, T)``
pool pair per row width).  Three hot paths change shape:

* ``query_many`` assembles its cross-tenant merge stack with a **single
  device gather** over the shared pool (zero host-side row copies — the
  ``host_row_copies`` counter machine-checks it) instead of re-packing
  canonical rows host-side per tenant;
* a drained async-ingest batch pulls up **all** touched trees together —
  one merge dispatch per level for the whole batch, not per tenant
  (:func:`~repro.core.interval_tree.pull_up_trees`);
* ``save``/``load`` persist the arena **once per registry** (compacted
  pools + per-tenant slot records) instead of one array dict per tenant.

Answers are bit-identical to the per-tenant-array layout (property-tested
in tests/test_arena.py); benchmarks/arena.py → BENCH_arena.json is the
A/B.  Eviction under concurrent queries stays snapshot-safe because arena
rows are write-once and freed only when their last handle dies — an
in-flight pack holding node handles pins its rows (core/arena.py).
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import ExitStack
from typing import Sequence

import numpy as np

from repro.analysis.witness import OrderedRLock
from repro.core import faults
from repro.core.arena import NodeArena
from repro.core.histogram import Histogram
from repro.core.resilience import (
    Answer,
    BreakerPolicy,
    CircuitBreaker,
    TenantQuarantined,
)
from repro.core.scrub import scrub_registry, verify_snapshot
from repro.core.interval_tree import (
    merge_stacks,
    pack_device_rows,
    pack_node_rows,
    pull_up_trees,
    selection_eps,
)
from repro.core.retention import (
    MemoryBudget,
    RetentionPolicy,
    policy_from_spec,
)
from repro.core.stream import (
    HistogramStore,
    _PrefixedArrays,
    _validated,
    atomic_savez,
)
from repro.core.workers import (
    IngestPool,
    PartialBatchFailure,
    PoolStateView,
    WriteAheadLog,
)

__all__ = ["TenantRegistry"]

_SCHEMA = "tenant_registry/v1"


class TenantRegistry(PoolStateView):
    """Many named stores, shared config, one-dispatch cross-tenant serving."""

    def __init__(
        self,
        num_buckets: int,
        *,
        engine: str = "tree",
        T_node: int | str | None = None,
        cache_size: int = 128,
        queue_size: int = 4096,
        workers: int = 1,
        retention: RetentionPolicy | None = None,
        budget: int | None = None,
        shared_arena: bool = False,
        collapse: str = "canonical",
        wal_dir: str | None = None,
        breaker: BreakerPolicy | None = None,
    ):
        if budget is not None and budget < 1:
            raise ValueError("budget must be >= 1 node floats")
        self.num_buckets = int(num_buckets)
        self.engine = engine
        self.T_node = T_node
        self.cache_size = int(cache_size)
        self.queue_size = int(queue_size)
        self.workers = int(workers)
        self.retention = retention  # per-tenant policy (shared config)
        self.budget = None if budget is None else int(budget)  # node floats
        self.collapse = str(collapse)  # eviction collapse mode (shared)
        # durable ingest: ONE registry-owned write-ahead log for every
        # tenant (records carry the tenant route) — submits ack only
        # after the record is fsynced, save truncates covered segments,
        # load/recover replay the rest (core/workers.py design note).
        # Tenant stores are created with wal=None: the registry logs.
        self.wal_dir = wal_dir
        self._wal: WriteAheadLog | None = (
            WriteAheadLog(wal_dir) if wal_dir is not None else None
        )
        # stats of the last WAL replay (recover/load), None until then
        self.last_recovery: dict | None = None
        # one registry-owned NodeArena for every tenant's tree nodes: the
        # cross-tenant query_many pack becomes a single device gather over
        # the shared pool, and a drained ingest batch pulls up ALL touched
        # trees with one merge dispatch per level (core/arena.py)
        self.arena: NodeArena | None = NodeArena() if shared_arena else None
        self._stores: dict[str, HistogramStore] = {}
        self._lock = OrderedRLock("registry._lock")  # tenant dict + caches
        # per-tenant node-float footprints, cached per store version so the
        # budget check is O(#tenants) when nothing changed
        self._floats_cache: dict[str, tuple[int, int]] = {}
        # the shared ingest plane (core/workers.py): drain, poison
        # isolation, enqueue-vs-close serialization, and the retention/
        # budget sweep between flushes all live on the pool
        self._pool = IngestPool(
            apply_batch=self._apply_worker_batch,
            wrap_error=self._wrap_async_error,
            workers=int(workers),
            queue_size=self.queue_size,
            name="tenant-ingest",
            on_batch_end=self._sweep_after_batch,
            wal=self._wal,
            wal_record=lambda item: (item[0], item[1], item[2]),
        )
        # cross-tenant merge dispatch observability (summarize_shapes-style)
        self.merge_dispatches = 0
        self.merge_shapes: set[tuple[int, int, int, int]] = set()
        # ----- self-healing plane (core/resilience.py) -----
        # per-tenant circuit breakers: None → quarantine disabled (the
        # historical contract); a BreakerPolicy (assignable post-load too)
        # trips a tenant whose ingests keep failing, rejecting further
        # submits at the door (TenantQuarantined) until a cooldown probe
        # succeeds — a poisoned tenant cannot keep riding into shared
        # batches.  Breakers are runtime config and are NOT persisted.
        self.breaker_policy = breaker
        self._breakers: dict[str, CircuitBreaker] = {}
        # last-known-good answers for degraded serving, keyed
        # (tenant, lo, hi, beta) → (hist, eps, {pid: n}, store version);
        # recorded only by degraded_ok=True query_many calls (the serving
        # plane), so direct strict callers pay nothing
        self._last_good: dict[tuple, tuple] = {}
        self._last_good_cap = 4096
        self._clock = time.monotonic  # injectable for deadline tests
        self.degraded_served = 0  # Answer(degraded=True) responses handed out
        self.pack_fallbacks = 0  # shared-arena gathers that fell to host pack
        # standing-query planes (serve/subscriptions.py) attached to this
        # registry: every ingest/sweep/eviction tick notifies them which
        # tenants' versions moved, so pushed answers re-evaluate
        # incrementally.  Runtime state — never persisted.
        self._stale_listeners: list = []
        self.last_scrub: dict | None = None  # scrub() report (core/scrub.py)
        self.last_salvage: dict | None = None  # recover(salvage=True) report
        # hot-standby shipper (core/replication.py) — attached via
        # Replicator.attach(): the async ack path ships through the
        # pool's on_durable hook, the synchronous ingest path ships in
        # _wal_log_sync, and health() surfaces its stats.  Runtime
        # wiring — never persisted.
        self._replication = None

    @property
    def host_row_copies(self) -> int:
        """Host-side node-row materializations across this registry's
        arena(s) — the machine-checked zero-copy counter of the shared-
        arena gather path (mirrors ``merge_dispatches``)."""
        if self.arena is not None:
            return self.arena.host_row_copies
        with self._lock:
            stores = list(self._stores.values())
        return sum(s._tree.arena.host_row_copies for s in stores)

    def reset_host_row_copies(self) -> None:
        if self.arena is not None:
            self.arena.host_row_copies = 0
            return
        with self._lock:
            stores = list(self._stores.values())
        for s in stores:
            s._tree.arena.host_row_copies = 0

    # (PoolStateView provides _cv/_pending/_ingest_mutex onto the pool)
    @property
    def _errors(self) -> list:
        """Every failed partition since the last flush: [(tenant, pid,
        exc)]; a ``(None, None, exc)`` entry is a failed retention/budget
        sweep."""
        return self._pool.errors

    @_errors.setter
    def _errors(self, value: list) -> None:
        self._pool.errors = value

    # -------------------------------------------------------------- tenants
    def tenant(self, name: str) -> HistogramStore:
        """Get-or-create the named store (shared registry configuration).

        Names are str()-normalized everywhere (lookup and storage alike),
        so ``reg.tenant(5)`` and ``reg.tenant("5")`` are the same tenant.
        Stores are created synchronous (``async_ingest=False``) — the
        registry's own worker pool is the async plane.
        """
        name = str(name)
        with self._lock:
            store = self._stores.get(name)
            if store is None:
                store = HistogramStore(
                    num_buckets=self.num_buckets,
                    engine=self.engine,
                    T_node=self.T_node,
                    cache_size=self.cache_size,
                    retention=self.retention,
                    collapse=self.collapse,
                    arena=self.arena,
                )
                # key the store lock by tenant name: the witness enforces
                # the PR 5 sorted-order contract for multi-store sites
                # (_apply_groups_batched, save) via ascending-key checks
                store._lock.key = name
                self._stores[name] = store
            return store

    def __getitem__(self, name: str) -> HistogramStore:
        with self._lock:
            try:
                return self._stores[str(name)]
            except KeyError:
                raise KeyError(f"unknown tenant: {name!r}") from None

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return str(name) in self._stores

    def __len__(self) -> int:
        with self._lock:
            return len(self._stores)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._stores)

    # --------------------------------------------------------- self-healing
    def _breaker(self, name: str) -> CircuitBreaker | None:
        """This tenant's circuit breaker (lazily created; None when the
        registry runs without a ``breaker`` policy)."""
        if self.breaker_policy is None:
            return None
        with self._lock:
            b = self._breakers.get(name)
            if b is None:
                b = CircuitBreaker(self.breaker_policy)
                self._breakers[name] = b
            return b

    def _breaker_check(self, name: str) -> None:
        """Reject a submit for a quarantined tenant at the door."""
        b = self._breaker(name)
        if b is not None and not b.allow():
            raise TenantQuarantined(name, b.state)

    def _breaker_ok(self, name: str) -> None:
        b = self._breaker(name)
        if b is not None:
            b.record_success()

    def _breaker_fail(self, name: str) -> None:
        """Count one ingest failure against the tenant — whatever the
        cause (poison data, apply fault): ``threshold`` consecutive ones
        trip the breaker and quarantine the tenant."""
        b = self._breaker(name)
        if b is not None:
            b.record_failure()

    def scrub(self, *, repair: bool = False) -> dict:
        """Run the integrity scrubber over every tenant (core/scrub.py);
        with ``repair=True`` corrupted tenants are routed through
        WAL-replay rebuild.  The report also lands on ``last_scrub``
        (surfaced by :meth:`health`)."""
        return scrub_registry(self, repair=repair)

    def health(self) -> dict:
        """One-call serving-plane health: breaker/quarantine states,
        degraded-answer and backpressure counters, WAL and pool stats,
        and the latest recovery/scrub reports.  ``status`` is
        ``"degraded"`` when any tenant is quarantined, unflushed ingest
        errors are pending, or the last scrub saw corruption."""
        with self._lock:
            breakers = {n: b.snapshot() for n, b in self._breakers.items()}
            last_scrub = self.last_scrub
        quarantined = sorted(
            n for n, b in breakers.items() if b["state"] != "closed"
        )
        pool = self._pool.stats()
        degraded = bool(
            quarantined
            or pool["errors_pending"]
            or (last_scrub is not None and last_scrub["corrupt"])
        )
        # standing-query plane counters (subscription counts, push lag,
        # dedup/overflow accounting) — None when no plane is attached,
        # the single plane's stats dict in the common case
        planes = list(self._stale_listeners)
        if not planes:
            subscriptions = None
        elif len(planes) == 1:
            subscriptions = planes[0].stats()
        else:
            subscriptions = [p.stats() for p in planes]
        # replication stats read outside _lock (the Replicator takes its
        # own rank-2 lock, which must never nest inside registry._lock)
        replication = (
            None if self._replication is None else self._replication.stats()
        )
        return {
            "status": "degraded" if degraded else "ok",
            "tenants": len(self),
            "quarantined": quarantined,
            "breakers": breakers,
            "degraded_served": self.degraded_served,
            "pack_fallbacks": self.pack_fallbacks,
            "subscriptions": subscriptions,
            "pool": pool,
            "backpressure": pool["backpressure"],
            "replication": replication,
            "wal": self.wal_stats(),
            "last_recovery": self.last_recovery,
            "last_scrub": last_scrub,
            "last_salvage": self.last_salvage,
        }

    # ----------------------------------------------------------- Summarizer
    def _wal_log_sync(
        self, tenant: str, parts: dict[int, np.ndarray]
    ) -> list[int]:
        """Append a synchronous-ingest batch (one tenant) to the registry
        WAL with one group-commit fsync; empty without a log."""
        if self._wal is None or not parts:
            return []
        lsns = [
            self._wal.append(tenant, pid, _validated(v))
            for pid, v in parts.items()
        ]
        self._wal.commit(lsns[-1])
        return lsns

    def _replication_ship(self) -> None:
        """Ship-before-ack (core/replication.py): a failed ship fails
        the ingest, so the caller never holds an ack the follower
        directories don't hold bytes for.  Runs *outside* the
        breaker-attributed try (like the async path's ``on_durable``
        hook): a replication transport outage is a cluster condition,
        not tenant poison — it must not quarantine healthy tenants."""
        if self._replication is not None:
            self._replication.ship()

    def wal_stats(self) -> dict | None:
        """WAL depth / fsync-latency / footprint counters (telemetry),
        or ``None`` when the registry runs without a log."""
        return None if self._wal is None else self._wal.stats()

    def ingest(self, tenant: str, partition_id: int, values):
        """Synchronous single-partition ingest into the named tenant.

        With a ``breaker`` policy a quarantined tenant is rejected before
        any work (:class:`TenantQuarantined`); the outcome of the ingest
        is recorded against the tenant's breaker either way.
        """
        name = str(tenant)
        self._breaker_check(name)
        try:
            faults.hit("tenant.apply", tenant=name, parts=1)
            lsns = self._wal_log_sync(name, {int(partition_id): values})
            out = self.tenant(name).ingest(partition_id, values)
        except BaseException:
            self._breaker_fail(name)
            raise
        self._breaker_ok(name)
        self._replication_ship()
        if self._wal is not None:
            self._wal.mark_applied(lsns)
        self._enforce_budget_cached([name])
        self._notify_stale((name,))
        return out

    def ingest_many(self, tenant: str, partitions: dict[int, np.ndarray]) -> None:
        """Grouped one-dispatch bulk ingest into the named tenant (with a
        WAL: the whole batch logged under one group-commit fsync)."""
        name = str(tenant)
        self._breaker_check(name)
        try:
            faults.hit("tenant.apply", tenant=name, parts=len(partitions))
            lsns = self._wal_log_sync(name, dict(partitions))
            self.tenant(name).ingest_many(partitions)
        except BaseException:
            self._breaker_fail(name)
            raise
        self._breaker_ok(name)
        self._replication_ship()
        if self._wal is not None:
            self._wal.mark_applied(lsns)
        self._enforce_budget_cached([name])
        self._notify_stale((name,))

    def ingest_async(self, tenant: str, partition_id: int, values) -> None:
        """Enqueue one partition for the shared background worker pool.

        Validation is synchronous (a bad partition fails the caller, not
        the pool); visibility comes with the worker's next flush of the
        batch — call :meth:`flush` to wait for everything enqueued so far.
        """
        values = _validated(values)
        name = str(tenant)
        self._breaker_check(name)  # quarantined tenants rejected at the door
        self.tenant(name)  # create eagerly: queries can see the tenant
        # stable per-tenant routing keeps each tenant's partitions FIFO —
        # hash() is salted per process but stable within one, which is all
        # that per-tenant FIFO needs
        self._pool.submit((name, int(partition_id), values), route=hash(name))

    def _apply_worker_batch(
        self, batch: list[tuple[str, int, np.ndarray]]
    ) -> None:
        """IngestPool apply callback: group the drained batch by tenant and
        apply each group with the store's grouped one-dispatch summarizer.

        Per-tenant groups apply independently: a poison partition narrows
        the pool's retry to its own group's items (PartialBatchFailure),
        so tenants whose groups already applied are not re-summarized —
        and their store versions aren't churned.  A single-group batch
        lets the real exception propagate, so the per-item retry records
        the underlying error, not a wrapper.
        """
        groups: dict[str, dict[int, np.ndarray]] = {}
        for name, pid, values in batch:
            groups.setdefault(name, {})[pid] = values
        if len(groups) == 1:
            ((name, parts),) = groups.items()
            store = self.tenant(name)
            faults.hit("tenant.apply", tenant=name, parts=len(parts))
            store._apply(store._summarize_batch(parts))
            self._breaker_ok(name)
            return
        if self.arena is not None:
            self._apply_groups_batched(batch, groups)
            return
        suspects: list[tuple[str, int, np.ndarray]] = []
        for name, parts in groups.items():
            store = self.tenant(name)
            try:
                faults.hit("tenant.apply", tenant=name, parts=len(parts))
                store._apply(store._summarize_batch(parts))
                self._breaker_ok(name)
            except BaseException:
                suspects += [
                    item for item in batch if item[0] == name
                ]
        if suspects:
            raise PartialBatchFailure(suspects)

    def _apply_groups_batched(
        self,
        batch: list[tuple[str, int, np.ndarray]],
        groups: dict[str, dict[int, np.ndarray]],
    ) -> None:
        """Shared-arena apply: one cross-tenant pull-up per drained batch.

        Summarization runs per tenant first (failures narrow the pool's
        retry to that tenant's items, like the sequential path), then every
        successful group's leaves are written and ALL touched trees are
        pulled up together — one merge dispatch per level for the whole
        batch instead of per tenant (``pull_up_trees``).  The touched
        stores' locks are held for the whole write+pull-up (acquired in
        sorted-name order; per-tenant FIFO routing keeps two workers'
        tenant sets disjoint, and no other path acquires two store locks),
        so queries still see each tenant only in whole-batch states.
        """
        summarized: dict[str, tuple[HistogramStore, dict]] = {}
        suspects: list[tuple[str, int, np.ndarray]] = []
        for name, parts in groups.items():
            store = self.tenant(name)
            try:
                faults.hit("tenant.apply", tenant=name, parts=len(parts))
                summarized[name] = (store, store._summarize_batch(parts))
            except BaseException:
                suspects += [item for item in batch if item[0] == name]
        names = sorted(summarized)
        with ExitStack() as stack:
            for name in names:
                stack.enter_context(summarized[name][0]._lock)
            applied: list[HistogramStore] = []
            try:
                work = []
                for name in names:
                    store, summs = summarized[name]
                    tree, dirty = store._apply_deferred(summs)
                    applied.append(store)
                    if dirty:
                        work.append((tree, dirty))
                pull_up_trees(work)
                for name in names:
                    summarized[name][0]._tree._invalidate()
            except BaseException:
                # a mid-apply failure must not release the locks with any
                # tenant's leaves written but ancestors stale — a query
                # would verify and CACHE that state.  Rebuild each touched
                # tree from its (already updated) summaries before
                # re-raising; the pool's per-item retry then re-applies.
                for store in applied:
                    try:
                        store.rebuild_tree()
                    except BaseException:
                        pass  # best effort; the original error surfaces
                raise
        # breaker acks AFTER the store locks are released: _breaker_ok
        # takes registry._lock (rank 10), and holding store locks (rank
        # 20) at that point inverts the hierarchy against save()/
        # query_many()'s registry→store nesting — a latent ABBA deadlock
        # surfaced by the static lock graph (scripts/analyze.py)
        for name in names:
            self._breaker_ok(name)
        if suspects:
            raise PartialBatchFailure(suspects)

    def _wrap_async_error(self, item, exc: BaseException):
        # pool error record: (tenant, pid, exception); a failed retention/
        # budget sweep (item None) records as (None, None, exception).
        # This is also where an async-ingested partition's terminal
        # failure (after the pool's per-item retry budget) counts against
        # its tenant's circuit breaker.
        if item is None:
            return (None, None, exc)
        self._breaker_fail(item[0])
        return (item[0], item[1], exc)

    def _sweep_after_batch(
        self, batch: list[tuple[str, int, np.ndarray]]
    ) -> None:
        """Retention slot of the pool worker: per-tenant sweeps for the
        tenants this batch touched, then the registry-wide budget (the
        cached-total check — only touched tenants are recounted) — runs
        between flushes, before the pending count drops."""
        touched = {item[0] for item in batch}
        if self.retention is not None:
            for name in touched:
                with self._lock:
                    store = self._stores.get(name)
                if store is not None:
                    store.sweep_retention()
        self._enforce_budget_cached(touched)
        self._notify_stale(touched)

    def _notify_stale(self, names) -> None:
        """Tick the attached subscription planes: the named tenants'
        versions may have moved.  Called with NO locks held (plane
        bookkeeping ranks below ``registry._lock`` and may call back into
        the registry)."""
        for plane in list(self._stale_listeners):
            plane.mark_stale(names)

    def flush(self) -> None:
        """Block until every enqueued partition is visible (and swept);
        surface errors.

        Re-raises (wrapped) every per-partition failure the pool hit since
        the last flush; valid partitions co-batched with a poison one are
        retried and applied individually, so the pool never wedges.
        """
        errs = self._pool.drain()
        if errs:
            detail = "; ".join(
                f"tenant {t!r} partition {pid}: {e!r}"
                if t is not None
                else f"retention sweep: {e!r}"
                for t, pid, e in errs
            )
            raise RuntimeError(
                f"async ingest failed for {len(errs)} partition(s): {detail}"
            ) from errs[0][2]

    def close(self) -> None:
        """Drain the pool, stop its workers, surface pending errors.
        Attached subscription planes are closed first (their evaluation
        workers drain, subscribers see ``closed``)."""
        for plane in list(self._stale_listeners):
            plane.close()
        self._pool.close()
        self.flush()

    # ------------------------------------------------------------ retention
    def node_floats(self) -> dict[str, int]:
        """Per-tenant tree node-float footprints (version-cached)."""
        with self._lock:
            names = list(self._stores)
        return {name: self._store_floats(name) for name in names}

    def _store_floats(self, name: str) -> int:
        # lock order: store lock and registry lock are taken sequentially,
        # never nested (save() nests registry→store, so nesting store→
        # registry here would be a lock-order inversion)
        with self._lock:
            store = self._stores[name]
            hit = self._floats_cache.get(name)
        with store._lock:
            v = store._tree.version
            if hit is not None and hit[0] == v:
                return hit[1]
            floats = store._tree.node_floats()
        with self._lock:
            self._floats_cache[name] = (v, floats)
        return floats

    def _enforce_budget_cached(self, touched) -> None:
        """Budget check without the O(#tenants) lock scan — shared by
        sync ingest and the pool worker's between-flush sweep.

        Only the mutated tenants' footprints are recounted (their
        versions bumped anyway); untouched tenants answer from the
        version cache.  The full :meth:`enforce_budget` scan runs only
        when the cached total crosses the budget or some tenant has
        never been counted — so a hot ingest loop under budget costs one
        store recount per batch, not three lock round-trips per tenant.
        """
        if self.budget is None:
            return
        for name in touched:
            with self._lock:
                present = str(name) in self._stores
            if present:
                self._store_floats(str(name))
        with self._lock:
            cached_total = sum(f for _, f in self._floats_cache.values())
            complete = len(self._floats_cache) == len(self._stores)
        if not complete or cached_total > self.budget:
            self.enforce_budget()

    def enforce_budget(self) -> dict[str, list[int]]:
        """Evict until the summed node-float footprint fits ``budget``.

        Fairness rule: quota = budget / #tenants; while over budget, the
        **largest-over-quota tenant** gives up its oldest partitions
        first, down to its quota (or just far enough to fit the budget,
        whichever is less eviction) — an under-quota tenant is never
        touched, and no tenant loses its newest partition.  Returns
        ``{tenant: [evicted ids]}``.  No-op without a budget.
        """
        if self.budget is None:
            return {}
        evicted: dict[str, list[int]] = {}
        while True:
            sizes = self.node_floats()
            total = sum(sizes.values())
            if not sizes or total <= self.budget:
                break
            quota = self.budget / len(sizes)
            progressed = False
            # largest-over-quota tenant first
            for name in sorted(sizes, key=lambda n: -sizes[n]):
                if sizes[name] <= quota:
                    break  # nobody else is over quota either
                with self._lock:
                    store = self._stores[name]
                # shrink to quota, or just under the global overflow —
                # delegate the "how many oldest partitions" estimate to
                # the MemoryBudget policy and let the outer loop converge
                target = max(int(quota), sizes[name] - (total - self.budget))
                victims = []
                with store._lock:
                    stats = store._retention_stats()
                    victims = store.evict(
                        MemoryBudget(max(1, target)).victims(stats)
                    )
                if victims:
                    evicted.setdefault(name, []).extend(victims)
                    progressed = True
                    break
            if not progressed:
                break  # every over-quota tenant is down to one partition
        if evicted:
            # eviction moves versions too — standing queries over an
            # evicted tenant's windows are stale exactly like post-ingest
            self._notify_stale(evicted)
        return evicted

    # --------------------------------------------------------------- Merger
    def query(
        self, tenant: str, lo: int, hi: int, beta: int, **kwargs
    ) -> tuple[Histogram, float]:
        """Single-tenant query — delegates to the named store."""
        return self[tenant].query(lo, hi, beta, **kwargs)

    def query_many(
        self,
        queries: Sequence[tuple[str, int, int]],
        beta: int,
        *,
        strict: bool = True,
        degraded_ok: bool = False,
        deadline: float | None = None,
    ) -> list[tuple[Histogram | None, float]]:
        """Answer ``[(tenant, lo, hi), ...]`` with ≤ one merge dispatch.

        Each query's canonical node set is collected under its own store's
        lock (per-tenant snapshot consistency), per-tenant LRU caches are
        consulted first, and all misses — deduplicated, across tenants —
        are packed into one static-shape block and merged by a single
        jitted ``merge_stacks`` call.  Answers are returned in query order
        (stable indexing) and populated back into each tenant's cache.

        ``strict=False`` applies the store-level summary-loss contract per
        query: an unknown tenant or an interval with zero present summaries
        yields the placeholder ``(None, float("inf"))`` instead of killing
        the batch; with ``strict=True`` both raise ``KeyError``.

        ``degraded_ok=True`` is the self-healing serving contract: when
        answering *fails* — the merge dispatch (or a query's node
        selection) raises, or ``deadline`` (absolute, by the registry
        clock) has passed before the dispatch — the affected queries are
        served their last known-good answer as an
        :class:`~repro.core.resilience.Answer` with ``degraded=True`` and
        an **honestly widened** ``eps_total`` (the cached bound plus all
        mass added to or removed from the interval since it was cached),
        instead of killing the batch.  Strict-contract ``KeyError``\\ s
        still raise — a missing partition is a caller error, not a fault.
        Fresh answers stay plain ``(hist, eps)`` tuples (``degraded``
        reads False), and only ``degraded_ok=True`` calls record/maintain
        the last-known-good cache.
        """
        results: list[tuple[Histogram | None, float] | None] = [None] * len(
            queries
        )
        # mkey (store id + cache key) → (miss row, result slots)
        miss_map: dict[tuple, tuple[int, list[int]]] = {}
        miss_sels: list[list] = []
        miss_meta: list[tuple[HistogramStore, tuple, tuple, dict | None]] = []
        for qi, (name, lo, hi) in enumerate(queries):
            if not strict and name not in self:
                results[qi] = (None, float("inf"))
                continue
            gkey = (str(name), int(lo), int(hi), int(beta))
            try:
                store = self[name]
                tree = store._tree
                with store._lock:
                    ids = store._present_ids(lo, hi)
                    if strict and len(ids) != hi - lo + 1:
                        missing = sorted(set(range(lo, hi + 1)) - set(ids))
                        raise KeyError(
                            f"tenant {name!r}: missing partition summaries: "
                            f"{missing}"
                        )
                    keys = store._sync_tree(ids, lo, hi)
                    if not ids:
                        if strict:
                            raise KeyError(
                                f"tenant {name!r}: no partition summaries in "
                                f"requested interval"
                            )
                        results[qi] = (None, float("inf"))
                        continue
                    key = (int(lo), int(hi), int(beta), tree.version)
                    mkey = (id(store), key)
                    prior = miss_map.get(mkey)
                    if prior is not None:  # duplicate within this batch
                        prior[1].append(qi)
                        continue
                    hit = tree._cache_get(key)
                    if hit is not None:
                        results[qi] = hit
                        continue
                    tree.cache_misses += 1
                    sel = [tree.nodes[k] for k in keys]
                    members = (
                        {pid: store.summaries[pid].n for pid in ids}
                        if degraded_ok
                        else None
                    )
                    miss_map[mkey] = (len(miss_sels), [qi])
                    miss_sels.append(sel)
                    miss_meta.append((store, key, gkey, members))
            except KeyError:
                raise  # strict-contract violations are not faults
            except BaseException:
                if not degraded_ok:
                    raise
                results[qi] = self._degraded_answer(gkey)
        if miss_sels:
            try:
                if deadline is not None and self._clock() >= deadline:
                    raise TimeoutError(
                        "query deadline passed before the merge dispatch"
                    )
                faults.hit("tenant.merge", misses=len(miss_sels))
                # ONE cross-tenant merge dispatch for the whole batch.
                # Packing outside the store locks is safe: arena rows are
                # write-once and the node handles held in miss_sels pin
                # them against concurrent eviction + reuse (core/arena.py
                # slot lifecycle).
                packed = None
                if self.arena is not None:
                    # shared arena: assemble the whole merge stack with a
                    # single device gather — zero host-side row copies
                    packed = pack_device_rows(miss_sels)
                    if packed is None:
                        with self._lock:
                            self.pack_fallbacks += 1
                if packed is None:
                    # per-tenant arenas (or a mixed-plane selection, e.g.
                    # geometric T_node): host pack, one stacked copy per
                    # plane, padded to the plane width so the block is
                    # bit-identical to the gather path's
                    T_pad = max(nd.width for sel in miss_sels for nd in sel)
                    packed = pack_node_rows(
                        miss_sels, T_pad=T_pad, pad_row_copy=True
                    )
                bounds, sizes = packed
                with self._lock:  # counters read by concurrent servers
                    self.merge_dispatches += 1
                    self.merge_shapes.add(tuple(bounds.shape) + (int(beta),))
                bo, so = merge_stacks(bounds, sizes, int(beta))
                # one device→host transfer; per-row unpacking is free views
                bo, so = np.asarray(bo), np.asarray(so)
            except BaseException:
                if not degraded_ok:
                    raise
                # the dispatch failed (or the deadline passed): every miss
                # gets its last known-good answer, honestly widened
                for row, slots in miss_map.values():
                    _store, _key, gkey, members = miss_meta[row]
                    ans = self._degraded_answer(gkey, members)
                    for qi in slots:
                        results[qi] = ans
                return results
            for row, slots in miss_map.values():
                store, key, gkey, members = miss_meta[row]
                out = (
                    Histogram(bo[row], so[row]),
                    selection_eps(miss_sels[row]),
                )
                with store._lock:
                    store._tree._cache_put(key, out)
                if members is not None:
                    self._remember_good(gkey, out, members, key[3])
                for qi in slots:
                    results[qi] = out
        return results

    def _remember_good(
        self, gkey: tuple, out: tuple, members: dict, version: int
    ) -> None:
        """Record a fresh answer as ``gkey``'s degraded-serving fallback
        (bounded FIFO — oldest entries age out past the cap)."""
        with self._lock:
            self._last_good.pop(gkey, None)
            self._last_good[gkey] = (out[0], float(out[1]), members, version)
            while len(self._last_good) > self._last_good_cap:
                self._last_good.pop(next(iter(self._last_good)))

    def _degraded_answer(self, gkey: tuple, now: dict | None = None):
        """The last known-good answer for ``gkey`` as a degraded
        :class:`Answer`, its ``eps_total`` widened by every unit of mass
        added to or removed from the interval since it was cached (the
        honest bound on what staleness can have changed).  ``now`` is the
        current ``{pid: n}`` membership if the caller captured one; with
        no cached answer — or no way to read the current membership — the
        placeholder ``(None, inf)`` / an ``inf``-widened answer is served
        instead of guessing.
        """
        name, lo, hi, _beta = gkey
        if now is None:
            try:
                with self._lock:
                    store = self._stores.get(name)
                now = (
                    {}
                    if store is None
                    else {
                        pid: s.n
                        for pid, s in list(store.summaries.items())
                        if lo <= pid <= hi
                    }
                )
            except Exception:  # store too broken to read: widen to inf
                now = None
        with self._lock:
            self.degraded_served += 1
            cached = self._last_good.get(gkey)
        if cached is None:
            return Answer.make(None, float("inf"), degraded=True)
        hist, eps, members, version = cached
        if now is None:
            return Answer.make(
                hist, float("inf"), degraded=True, stale_version=version
            )
        drift = 0.0
        for pid, n in now.items():
            drift += abs(n - members.get(pid, 0))
        for pid, n in members.items():
            if pid not in now:
                drift += n
        return Answer.make(
            hist, eps + drift, degraded=True, stale_version=version
        )

    # ---------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Atomic one-npz write of every tenant (summaries + tree nodes).

        With a shared arena the node pools are exported **once for the
        whole registry** — compacted to the live rows of all tenants
        (``arena_ab_{width}``/``arena_as_{width}``), with each tenant's
        node records pointing into that one slot map — instead of one
        array dict per tenant.

        With a WAL this is the registry checkpoint: the log's
        ``stable_lsn`` is captured *before* any store state is read (so
        everything ≤ it is covered by this snapshot), persisted as
        ``meta["wal_stable_lsn"]``, and covered segments are deleted only
        after the atomic rename succeeds.
        """
        stable = None if self._wal is None else self._wal.stable_lsn
        with self._lock:
            names = sorted(self._stores)
            payload: dict[str, np.ndarray] = {}
            stores_meta: dict[str, dict] = {}
            with ExitStack() as stack:
                stores = [self._stores[n] for n in names]
                slot_map = None
                if self.arena is not None:
                    # hold every store lock so the export and each tree's
                    # node records describe one consistent snapshot
                    for store in stores:
                        stack.enter_context(store._lock)
                    arrays, slot_map = self.arena.export(
                        (nd.width, nd.row)
                        for store in stores
                        for nd in store._tree.nodes.values()
                    )
                    payload.update(
                        {f"arena_{k}": v for k, v in arrays.items()}
                    )
                for i, (name, store) in enumerate(zip(names, stores)):
                    if self.arena is None:
                        with store._lock:
                            meta_i, payload_i = store._state(prefix=f"t{i}_")
                    else:  # locks already held
                        meta_i, payload_i = store._state(
                            prefix=f"t{i}_", tree_slot_map=slot_map
                        )
                    stores_meta[name] = meta_i
                    payload.update(payload_i)
            meta = {
                "schema": _SCHEMA,
                "num_buckets": self.num_buckets,
                "engine": self.engine,
                "T_node": self.T_node,
                "cache_size": self.cache_size,
                "retention": (
                    None if self.retention is None else self.retention.spec()
                ),
                "budget": self.budget,
                "shared_arena": self.arena is not None,
                "collapse": self.collapse,
                "wal_stable_lsn": stable,
                "tenants": names,
                "stores": stores_meta,
            }
        atomic_savez(path, meta, payload)
        if self._wal is not None:
            self._wal.truncate(stable)

    @classmethod
    def load(cls, path: str, wal_dir: str | None = None) -> "TenantRegistry":
        """Restore every tenant from the one-npz container; with
        ``wal_dir``, also replay the log suffix the snapshot doesn't
        cover (see :meth:`recover` for the missing-snapshot case)."""
        # context-managed NpzFile (same fd-leak rule as HistogramStore
        # .load, pinned by tests/test_durability.py's fd-count test):
        # every tenant's arrays are materialized inside this block
        with np.load(path, allow_pickle=False) as data:
            meta = json.loads(str(data["meta"]))
            if meta.get("schema") != _SCHEMA:
                raise ValueError(
                    f"not a tenant registry file: schema="
                    f"{meta.get('schema')!r}"
                )
            T_node = meta.get("T_node")
            reg = cls(
                num_buckets=int(meta["num_buckets"]),
                engine=str(meta.get("engine", "tree")),
                T_node=(
                    T_node if T_node in (None, "geometric") else int(T_node)
                ),
                cache_size=int(meta.get("cache_size", 128)),
                retention=policy_from_spec(meta.get("retention")),
                budget=meta.get("budget"),
                shared_arena=bool(meta.get("shared_arena", False)),
                collapse=str(meta.get("collapse", "canonical")),
            )
            shared_pools = (
                _PrefixedArrays(data, "arena_") if reg.arena is not None else None
            )
            for i, name in enumerate(meta["tenants"]):
                store = reg.tenant(name)
                store._restore(
                    meta["stores"][name],
                    data,
                    prefix=f"t{i}_",
                    tree_arrays=shared_pools,
                )
        if wal_dir is not None:
            reg._attach_wal(wal_dir, meta.get("wal_stable_lsn"))
        return reg

    @classmethod
    def recover(
        cls,
        path: str,
        wal_dir: str,
        *,
        salvage: bool = False,
        **registry_kwargs,
    ) -> "TenantRegistry":
        """Crash-consistent startup: snapshot + WAL → the acked state.

        If ``path`` exists it is loaded and the WAL's uncovered suffix
        replayed on top; if the crash happened before the first save, the
        registry is rebuilt from the WAL alone using ``registry_kwargs``
        as its configuration.  Every acked ingest — including partitions
        that were still sitting in the in-memory queue when the process
        died — is present afterwards, and the registry keeps logging to
        ``wal_dir``.

        ``salvage=True`` adds the bit-rot leg of the self-healing plane:
        the snapshot's payload checksums are verified first
        (:func:`~repro.core.scrub.verify_snapshot`), and a corrupt or
        unloadable snapshot is moved aside to ``path + ".corrupt"`` and
        the registry rebuilt from the WAL alone — wrong answers are never
        served from rotted bytes.  The verification report lands on
        ``last_salvage`` (and :meth:`health`).
        """
        if os.path.exists(path):
            report = None
            if salvage:
                report = verify_snapshot(path)
            if report is None or report["ok"]:
                try:
                    reg = cls.load(path, wal_dir=wal_dir)
                    reg.last_salvage = report
                    return reg
                except Exception as e:
                    if not salvage:
                        raise
                    report = {"ok": False, "error": repr(e)}
            # corrupt snapshot: quarantine the file, rebuild from the WAL
            os.replace(path, path + ".corrupt")
            reg = cls(**registry_kwargs)
            reg._attach_wal(wal_dir, None)
            reg.last_salvage = report
            return reg
        reg = cls(**registry_kwargs)
        reg._attach_wal(wal_dir, None)
        return reg

    def _attach_wal(self, wal_dir: str, covered_lsn: int | None) -> None:
        """Open (or adopt) the log at ``wal_dir``, replay its uncovered
        suffix into the tenants it routes to, and log future submits."""
        self.wal_dir = str(wal_dir)
        self._wal = WriteAheadLog(self.wal_dir)
        self._wal.ensure_position(covered_lsn)
        self._pool.wal = self._wal
        self._pool.wal_record = lambda item: (item[0], item[1], item[2])
        self._replay_wal(-1 if covered_lsn is None else int(covered_lsn))

    def _replay_wal(self, covered_lsn: int) -> int:
        """Idempotent replay of the WAL suffix above ``covered_lsn``.

        Records are grouped by tenant route (creating tenants as needed —
        ``ingest_async`` created them eagerly pre-crash too) and each
        group re-ingests through the store's grouped summarizer after the
        pid-dedup/watermark reconciliation documented in core/workers.py.
        A record without a tenant route (a standalone store's WAL) is a
        config error and raises.  Returns the number of partitions
        replayed; per-run stats land on ``self.last_recovery``.
        """
        records = self._wal.recovered_records()
        per_tenant: dict[str, dict[int, np.ndarray]] = {}
        for rec in records:
            if rec.lsn <= covered_lsn:
                continue
            if rec.tenant is None:
                raise ValueError(
                    "WAL record without a tenant route — this log was "
                    "written by a standalone HistogramStore, not a registry"
                )
            # duplicate pids within the suffix: last append wins
            per_tenant.setdefault(str(rec.tenant), {})[rec.pid] = rec.values
        replayed = 0
        for name, parts in sorted(per_tenant.items()):
            store = self.tenant(name)
            fresh = {
                pid: v
                for pid, v in parts.items()
                if pid not in store.summaries
                and (store.watermark is None or pid > store.watermark)
            }
            if fresh:
                store._apply(store._summarize_batch(fresh))
                store._maybe_sweep()
                replayed += len(fresh)
        if per_tenant:
            self._enforce_budget_cached(per_tenant.keys())
        self._wal.mark_applied(rec.lsn for rec in records)
        self.last_recovery = {
            "records_scanned": len(records),
            "replayed": replayed,
            "skipped_covered": len(records) - replayed,
            "torn_records_dropped": self._wal.torn_records_dropped,
        }
        return replayed

    # ------------------------------------------------------------- utility
    def cache_stats(self) -> dict[str, int]:
        """Aggregated per-tenant cache counters + registry dispatch count."""
        with self._lock:
            stores = list(self._stores.values())
        hits = sum(s._tree.cache_hits for s in stores)
        misses = sum(s._tree.cache_misses for s in stores)
        return {
            "hits": hits,
            "misses": misses,
            "merge_dispatches": self.merge_dispatches,
            "merge_shapes": len(self.merge_shapes),
            "host_row_copies": self.host_row_copies,
        }
