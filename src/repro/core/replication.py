"""Hot-standby replication: WAL shipping, bounded-staleness replicas,
zero-loss failover.

PR 6 made a *process* crash-safe: every acked ingest is in the WAL, and
recovery replays it.  The process itself remained a single point of
failure — when it dies, serving stops until local recovery completes.
This module removes that: a :class:`Replicator` on the primary ships WAL
segment bytes to N follower directories *before the ingest ack*, a
:class:`Follower` tails the shipped segments and continuously replays
them into its own :class:`~repro.core.tenant.TenantRegistry` (the same
idempotent pid-dedup/watermark reconciliation recovery uses), and
``Follower.promote()`` is first-class failover: fence the deposed
primary by epoch, drain the shipped suffix, adopt the shipped log as the
new primary's WAL, re-attach subscription planes.

Zero acked loss, by construction
--------------------------------
The shipper runs on the ingest ack path: ``IngestPool.submit`` calls its
``on_durable`` hook after the group-commit fsync and *before* returning,
and the synchronous ingest path ships right after its commit + apply
(core/tenant.py ``_replication_ship``, outside the tenant's
breaker-attributed try — a replication outage fails the ingest but never
quarantines the tenant).  A ship failure therefore fails the submit —
the producer never holds an ack the follower directories don't hold
bytes for.  The streams are byte-level and idempotent: each
frame means "the segment's content from ``offset`` is exactly these
bytes; truncate anything beyond", so re-shipping after a partial failure
converges instead of corrupting.  A follower may hold *more* than the
acked set (appends whose ack never returned) — the same harmless
superset a local recovery replays, and the chaos harness's bit-match
oracle is superset-tolerant for exactly this reason.

Epoch fencing
-------------
``promote(fence=...)`` picks ``new_epoch`` = 1 + the highest epoch it
has observed and (best-effort) calls the fence callable against the old
primary: ``WriteAheadLog.fence(new_epoch)`` persists a fence mark that
makes every later ``append`` raise
:class:`~repro.core.resilience.PrimaryFenced` — a deposed primary's late
writes are rejected *at its own log*, even across a restart.  The
follower directory is fenced too: its ``epoch.json`` is bumped to
``new_epoch`` (under the same per-directory gate the dir transport
sends through, so an in-flight ship cannot slip bytes past the fence),
and both in-tree transports refuse to deliver frames stamped with a
lower epoch.  Segment files carry their writer's epoch in a 12-byte
header (core/workers.py); a follower configured with ``min_epoch``
additionally refuses to *apply* records from lower-epoch segments.

Snapshot bootstrap
------------------
``checkpoint()`` truncates snapshot-covered segments out of the WAL, so
a standby attached *after* a checkpoint can never receive that prefix
as log bytes.  Two pieces keep this from becoming silent data loss: the
WAL's durable shed-mass ledger (core/workers.py ``mass.json``) keeps
``mass_by_tenant()`` cumulative across truncation and restart, so the
manifest always claims the full history and an un-bootstrapped replica
degrades honestly; and ``Replicator.bootstrap`` ships the snapshot
itself (plus a ``bootstrap.json`` seed crediting the covered mass) as
atomic blobs, so a fresh :class:`Follower` adopts the snapshot-covered
state and serves non-degraded, bit-matching answers.  When shed mass
exists and the snapshot cannot be shipped, ``bootstrap`` refuses rather
than under-replicate.

Bounded-staleness replica reads
-------------------------------
Each ship writes a ``manifest.json`` next to the shipped segments:
``{epoch, written_lsn, mass, wall}`` where ``mass`` is the primary's
cumulative appended value-count per tenant.  The follower's drift bound
for a tenant is ``manifest mass − mass it has scanned`` (clamped at 0):
every unit of mass the replica provably hasn't seen can shift bucket
ranks by at most itself, which is exactly the currency of the paper's
ε guarantee — so ``Follower.query_many`` serves answers with ``eps``
widened by that bound, as :class:`~repro.core.resilience.Answer` objects
carrying ``lag_seconds``.  ``degraded=True`` marks every answer that
cannot be proven to bit-match the primary's acked state: the tenant has
nonzero drift, the manifest is missing, or the manifest's age exceeds
the configured staleness SLO.  A non-degraded replica answer therefore
bit-matches a fault-free replica — the invariant the chaos property
test machine-checks.

Locks: ``repl.replicator`` (rank 2) and ``repl.follower`` (rank 4) sit
*below* the whole serving hierarchy — ship/tail call into registry,
store and WAL locks, never the reverse; ``repl.dirgate`` (rank 5) is the
per-follower-directory send-vs-fence gate.  Failpoints: ``repl.ship`` /
``repl.tail`` / ``repl.apply`` / ``repl.promote`` (core/faults.py).
"""
from __future__ import annotations

import json
import os
import socket
import struct
import threading
import time
from typing import Callable

from repro.analysis.witness import OrderedLock
from repro.core import faults
from repro.core.resilience import Answer, PrimaryFenced
from repro.core.tenant import TenantRegistry
from repro.core.workers import (
    WriteAheadLog,
    atomic_write_json,
    mass_meta_path,
    read_segment_epoch,
    scan_wal_bytes,
)

__all__ = [
    "DirTransport",
    "Follower",
    "Replicator",
    "StreamReceiver",
    "StreamTransport",
    "manifest_path",
]

_MANIFEST = "manifest.json"
_FRAME_LEN = struct.Struct("<I")  # stream frame: header length prefix
_ACK = struct.Struct("<BQ")  # stream ack: status byte + receiver epoch

# per-follower-directory gate serializing transport sends against the
# promote-time fence write: a send that passed the epoch check cannot
# land its bytes after the fence, so promote's final drain is exact
_DIR_GATES: dict[str, OrderedLock] = {}
_DIR_GATES_GUARD = threading.Lock()


def _dir_gate(dir: str) -> OrderedLock:
    key = os.path.abspath(dir)
    with _DIR_GATES_GUARD:
        gate = _DIR_GATES.get(key)
        if gate is None:
            gate = _DIR_GATES[key] = OrderedLock("repl.dirgate")
        return gate


def manifest_path(dir: str) -> str:
    return os.path.join(dir, _MANIFEST)


def _dir_epoch(dir: str) -> int:
    """The epoch recorded in a directory's ``epoch.json`` (0 if none)."""
    try:
        with open(os.path.join(dir, "epoch.json")) as f:
            return int(json.load(f).get("epoch", 0))
    except (FileNotFoundError, ValueError, OSError):
        return 0


def _apply_frame(dir: str, name: str, offset: int, data: bytes) -> None:
    """One ship frame: segment content from ``offset`` is exactly
    ``data``; anything beyond is truncated away (idempotent)."""
    path = os.path.join(dir, os.path.basename(name))
    fd = os.open(path, os.O_CREAT | os.O_RDWR, 0o644)
    with os.fdopen(fd, "r+b") as f:
        f.seek(int(offset))
        f.write(data)
        f.truncate(int(offset) + len(data))


def _apply_blob(dir: str, name: str, data: bytes) -> None:
    """One whole auxiliary file (snapshot bootstrap), written atomically
    — a reader never sees a torn blob, unlike the truncate-as-you-go
    segment frame files."""
    path = os.path.join(dir, os.path.basename(name))
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)


def _check_epoch(dir: str, epoch: int) -> None:
    dest = _dir_epoch(dir)
    if dest > epoch:
        raise PrimaryFenced(epoch, dest)


class DirTransport:
    """Ship frames into a local follower directory (files by basename).

    Every delivery runs under the directory's ``repl.dirgate`` and
    re-checks the directory's epoch inside it: once a promotion bumped
    ``epoch.json`` past the sender's epoch, frames from the deposed
    primary raise :class:`PrimaryFenced` and *nothing* lands — not even
    a frame whose epoch check raced the fence write.
    """

    def __init__(self, dir: str):
        self.dir = str(dir)
        os.makedirs(self.dir, exist_ok=True)

    def send(self, name: str, offset: int, data: bytes, *, epoch: int) -> None:
        with _dir_gate(self.dir):
            _check_epoch(self.dir, epoch)
            _apply_frame(self.dir, name, offset, data)

    def send_blob(self, name: str, data: bytes, *, epoch: int) -> None:
        with _dir_gate(self.dir):
            _check_epoch(self.dir, epoch)
            _apply_blob(self.dir, name, data)

    def send_manifest(self, manifest: dict, *, epoch: int) -> None:
        with _dir_gate(self.dir):
            _check_epoch(self.dir, epoch)
            # not a durability artifact (losing it costs lag-unknown,
            # never data) — skip the fsync on the hot ack path
            atomic_write_json(
                manifest_path(self.dir), manifest, fsync=False
            )

    def close(self) -> None:
        pass


class StreamTransport:
    """Ship frames over a byte stream (socketpair/loopback) to a
    :class:`StreamReceiver`.  Each frame is acknowledged synchronously —
    the ingest ack is only issued once the receiver wrote the bytes —
    and a fenced receiver acks a rejection that surfaces here as
    :class:`PrimaryFenced`."""

    def __init__(self, sock: socket.socket):
        self.sock = sock

    def _roundtrip(self, header: dict, payload: bytes) -> None:
        blob = json.dumps(header).encode()
        self.sock.sendall(_FRAME_LEN.pack(len(blob)) + blob + payload)
        ack = _recv_exact(self.sock, _ACK.size)
        status, dest_epoch = _ACK.unpack(ack)
        if status != 1:
            raise PrimaryFenced(int(header["epoch"]), int(dest_epoch))

    def send(self, name: str, offset: int, data: bytes, *, epoch: int) -> None:
        self._roundtrip(
            {
                "kind": "frame",
                "name": os.path.basename(name),
                "offset": int(offset),
                "length": len(data),
                "epoch": int(epoch),
            },
            data,
        )

    def send_blob(self, name: str, data: bytes, *, epoch: int) -> None:
        self._roundtrip(
            {
                "kind": "blob",
                "name": os.path.basename(name),
                "length": len(data),
                "epoch": int(epoch),
            },
            data,
        )

    def send_manifest(self, manifest: dict, *, epoch: int) -> None:
        blob = json.dumps(manifest).encode()
        self._roundtrip(
            {"kind": "manifest", "length": len(blob), "epoch": int(epoch)},
            blob,
        )

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self.sock.close()


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise ConnectionError("replication stream closed mid-frame")
        buf += chunk
    return buf


class StreamReceiver:
    """Follower-side end of a :class:`StreamTransport`: a daemon thread
    that applies each frame into the follower directory (under the same
    dirgate/epoch discipline as :class:`DirTransport`) and acks it.

    ``close()`` joins the thread — after it returns no further bytes can
    land, which is what lets ``promote()`` on a stream-fed follower
    simply stop the receiver before its final drain."""

    def __init__(self, sock: socket.socket, dir: str):
        self.sock = sock
        self.dir = str(dir)
        os.makedirs(self.dir, exist_ok=True)
        self.frames = 0
        self.rejected = 0
        self.faults = 0  # stream terminations, incl. apply failures
        self._thread = threading.Thread(
            target=self._serve, name="repl-receiver", daemon=True
        )
        self._thread.start()

    def _serve(self) -> None:
        try:
            while True:
                (hlen,) = _FRAME_LEN.unpack(
                    _recv_exact(self.sock, _FRAME_LEN.size)
                )
                header = json.loads(_recv_exact(self.sock, hlen))
                payload = _recv_exact(self.sock, int(header["length"]))
                epoch = int(header["epoch"])
                with _dir_gate(self.dir):
                    dest = _dir_epoch(self.dir)
                    if dest > epoch:
                        self.rejected += 1
                        self.sock.sendall(_ACK.pack(0, dest))
                        continue
                    if header["kind"] == "frame":
                        _apply_frame(
                            self.dir,
                            header["name"],
                            int(header["offset"]),
                            payload,
                        )
                    elif header["kind"] == "blob":
                        _apply_blob(self.dir, header["name"], payload)
                    else:
                        atomic_write_json(
                            manifest_path(self.dir),
                            json.loads(payload),
                            fsync=False,
                        )
                    self.frames += 1
                self.sock.sendall(_ACK.pack(1, dest))
        except (ConnectionError, OSError, ValueError):
            # peer closed, close() shut us down, OR a follower-side
            # fault (disk error applying a frame, malformed header).
            # Either way the stream is dead: shut it down so a sender
            # blocked in its ack wait gets ConnectionError and fails
            # the submit fast, instead of wedging the primary's ingest
            # ack path forever.
            self.faults += 1
            try:
                self.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            return

    def close(self) -> None:
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._thread.join()
        self.sock.close()


class Replicator:
    """Primary-side shipper: WAL segment bytes → N follower transports.

    ``ship()`` is incremental and idempotent: it tracks a shipped byte
    offset per segment, reads closed segments lock-free (they are
    immutable; one deleted underneath by ``truncate()`` returns the
    clean rotated-away ``None`` and is dropped from tracking) and the
    active segment atomically under the WAL lock
    (:meth:`~repro.core.workers.WriteAheadLog.read_active` — an append
    rollback can never hand the shipper disowned bytes).  After shipping
    it publishes the manifest capturing ``written_lsn`` and the
    per-tenant appended mass *as of before the reads* — a lower bound of
    what the followers now hold, which keeps the follower's drift bound
    honest.

    Wire it onto a registry with :meth:`attach`: every durable ack then
    ships first (module docstring).  All shipping serializes under
    ``repl.replicator`` (rank 2 — below every lock it calls into).
    """

    def __init__(self, wal: WriteAheadLog, transports):
        self.wal = wal
        self.transports = list(transports)
        self._lock = OrderedLock("repl.replicator")
        self._offsets: dict[str, int] = {}  # segment path -> bytes shipped
        self.ships = 0
        self.bytes_shipped = 0
        self.ship_failures = 0
        self.shipped_lsn = 0

    def attach(self, registry: TenantRegistry) -> "Replicator":
        """Put this shipper on the registry's ingest ack paths (both the
        async pool's post-commit hook and the synchronous ingest hook)
        and on its ``health()["replication"]`` row."""
        registry._replication = self
        registry._pool.on_durable = self.ship
        return self

    def bootstrap(self, snapshot_path: str) -> bool:
        """Ship the checkpoint snapshot plus a seed-mass record so a
        fresh follower can reconstruct state the WAL no longer holds.

        A primary restarted after a ``checkpoint()`` has truncated the
        snapshot-covered prefix out of its log; shipping only the WAL
        suffix would leave followers *silently* missing that history
        (their drift bound would read 0 against a manifest that excluded
        it).  When the log has shed mass this call is mandatory and
        raises if it cannot run — no snapshot on disk, or a transport
        without ``send_blob`` — rather than under-replicate; with
        nothing shed it is a best-effort catch-up accelerator.  The
        seed record (``bootstrap.json``) carries the shed per-tenant
        mass so the follower's drift bound credits the snapshot-covered
        prefix it will never see as WAL bytes.  Returns True when the
        snapshot was shipped.
        """
        shed = self.wal.shed_mass_by_tenant()
        needed = any(shed.values())
        have = os.path.exists(snapshot_path)
        with self._lock:
            carriers = [
                tr for tr in self.transports if hasattr(tr, "send_blob")
            ]
            if needed and (not have or len(carriers) < len(self.transports)):
                raise ValueError(
                    "WAL no longer holds snapshot-covered history (shed "
                    f"mass {sum(shed.values())}) and the followers cannot "
                    "be bootstrapped: "
                    + (
                        f"no snapshot at {snapshot_path}"
                        if not have
                        else "a transport does not support send_blob"
                    )
                )
            if not have:
                return False
            with open(snapshot_path, "rb") as f:
                blob = f.read()
            seed = json.dumps(
                {
                    "epoch": self.wal.epoch,
                    "mass": {
                        ("" if t is None else str(t)): int(m)
                        for t, m in shed.items()
                    },
                }
            ).encode()
            # snapshot first, seed second: a follower that sees the seed
            # requires the snapshot it credits to already be in place
            for tr in carriers:
                tr.send_blob("registry.npz", blob, epoch=self.wal.epoch)
                tr.send_blob("bootstrap.json", seed, epoch=self.wal.epoch)
        return True

    def ship(self) -> int:
        """Ship every unshipped WAL byte to every follower; returns the
        byte count.  Raises on any transport failure (the caller — the
        ingest ack path — must not ack) after counting it."""
        faults.hit("repl.ship")
        with self._lock:
            try:
                return self._ship_locked()
            except BaseException:
                self.ship_failures += 1
                raise

    def _ship_locked(self) -> int:
        # capture the manifest numbers BEFORE reading segment bytes: both
        # only grow, so everything they claim is contained in what the
        # reads below deliver — the manifest never overstates a follower
        st = self.wal.stats()
        mass = self.wal.mass_by_tenant()
        view = self.wal.segment_view()
        live = {seg["path"] for seg in view}
        for path in list(self._offsets):
            if path not in live:
                del self._offsets[path]  # truncated away: follower keeps it
        sent = 0
        for seg in view:
            path = seg["path"]
            off = self._offsets.get(path, 0)
            end: int | None = seg["size"]
            if seg["active"]:
                got = self.wal.read_active(off)
                if got is not None and got[0] == path:
                    _apath, data, cur = got
                    if cur < off:
                        # append rollback shrank the segment: rewind the
                        # copies
                        self._send(path, cur, b"")
                        self._offsets[path] = cur
                        continue
                    if data:
                        self._send(path, off, data)
                        self._offsets[path] = off + len(data)
                        sent += len(data)
                    continue
                # the log rotated (or closed) between segment_view() and
                # read_active(): ``path`` is closed and immutable NOW, so
                # ship its remaining tail through the closed-segment read
                # in this same round — the manifest published below
                # claims these bytes, and the ingest ack must never
                # return while the followers lack them
                end = None
            if end is not None and off >= end:
                continue
            data = self.wal.read_segment(
                path, off, None if end is None else end - off
            )
            if data is None:
                self._offsets.pop(path, None)  # rotated away
                continue
            if data:
                self._send(path, off, data)
                self._offsets[path] = off + len(data)
                sent += len(data)
        if sent or self.ships == 0:
            manifest = {
                "epoch": self.wal.epoch,
                "written_lsn": st["written_lsn"],
                "mass": {
                    ("" if t is None else str(t)): int(m)
                    for t, m in mass.items()
                },
                "wall": time.time(),
            }
            for tr in self.transports:
                tr.send_manifest(manifest, epoch=self.wal.epoch)
            self.shipped_lsn = st["written_lsn"]
        self.ships += 1
        self.bytes_shipped += sent
        return sent

    def _send(self, path: str, offset: int, data: bytes) -> None:
        for tr in self.transports:
            tr.send(path, offset, data, epoch=self.wal.epoch)

    def heartbeat(self) -> None:
        """Publish a fresh manifest without requiring new bytes — keeps
        the followers' seconds-lag honest across idle stretches."""
        with self._lock:
            manifest = {
                "epoch": self.wal.epoch,
                "written_lsn": self.wal.stats()["written_lsn"],
                "mass": {
                    ("" if t is None else str(t)): int(m)
                    for t, m in self.wal.mass_by_tenant().items()
                },
                "wall": time.time(),
            }
            for tr in self.transports:
                tr.send_manifest(manifest, epoch=self.wal.epoch)

    def fence(self, min_epoch: int) -> None:
        """The promote-side fence hook: persist the fence mark on this
        primary's WAL so its later appends raise :class:`PrimaryFenced`."""
        self.wal.fence(min_epoch)

    def close(self) -> None:
        for tr in self.transports:
            tr.close()

    def stats(self) -> dict:
        with self._lock:
            return {
                "role": "primary",
                "epoch": self.wal.epoch,
                "followers": len(self.transports),
                "ships": self.ships,
                "bytes_shipped": self.bytes_shipped,
                "ship_failures": self.ship_failures,
                "shipped_lsn": self.shipped_lsn,
            }


class Follower:
    """Replica-side tailer: shipped segments → a live registry.

    Owns (or adopts) a :class:`TenantRegistry` with no WAL of its own —
    the shipped directory *is* its log, adopted wholesale at
    :meth:`promote`.  A shipped ``registry.npz`` + ``bootstrap.json``
    pair (:meth:`Replicator.bootstrap`) is adopted at construction:
    the snapshot becomes the starting registry and its covered mass is
    credited to the drift bound — that is how checkpoint-truncated
    history reaches a fresh replica.  ``tail()`` incrementally parses new segment bytes
    from remembered offsets and applies fresh records through the same
    grouped summarizer + pid/watermark dedup recovery uses, so tailing
    is idempotent: a fault between apply and state-commit re-scans the
    same bytes and the dedup skips what already landed.  State under
    ``repl.follower`` (rank 4, below the registry/store locks the apply
    path takes).
    """

    def __init__(
        self,
        dir: str,
        *,
        registry: TenantRegistry | None = None,
        min_epoch: int = 0,
        staleness_slo: float | None = None,
        clock: Callable[[], float] = time.time,
        **registry_kwargs,
    ):
        self.dir = str(dir)
        os.makedirs(self.dir, exist_ok=True)
        boot_registry: TenantRegistry | None = None
        boot_mass: dict[str, int] = {}
        if registry is None:
            snap = os.path.join(self.dir, "registry.npz")
            if os.path.exists(snap):
                # snapshot bootstrap (Replicator.bootstrap): the primary
                # checkpointed history out of its WAL — adopt the shipped
                # snapshot and credit its covered mass, so the drift
                # bound starts honest instead of silently reading 0
                try:
                    boot_registry = TenantRegistry.load(snap)
                    with open(os.path.join(self.dir, "bootstrap.json")) as f:
                        boot_mass = {
                            str(t): int(m)
                            for t, m in (
                                json.load(f).get("mass") or {}
                            ).items()
                        }
                except Exception:
                    # torn/corrupt bootstrap: start empty and credit
                    # nothing — the drift bound then *includes* the
                    # missing prefix, so the replica degrades honestly
                    # instead of answering wrong
                    if boot_registry is not None:
                        boot_registry.close()
                    boot_registry = None
                    boot_mass = {}
        self.registry = (
            registry
            if registry is not None
            else (
                boot_registry
                if boot_registry is not None
                else TenantRegistry(**registry_kwargs)
            )
        )
        self._boot_mass = boot_mass
        self.min_epoch = int(min_epoch)
        self.staleness_slo = (
            None if staleness_slo is None else float(staleness_slo)
        )
        self.clock = clock
        self._lock = OrderedLock("repl.follower")
        self._offsets: dict[str, int] = {}  # basename -> bytes consumed
        self._epochs: dict[str, int] = {}  # basename -> segment epoch
        self._data_start: dict[str, int] = {}  # basename -> header size
        # pre-dedup scanned mass, seeded with the bootstrap snapshot's
        # covered mass (the prefix this replica holds without ever
        # seeing its WAL bytes)
        self._seen_mass: dict[str, int] = dict(boot_mass)
        self.applied_lsn = 0
        self.tails = 0
        self.records_applied = 0
        self.apply_failures = 0
        self.fenced_segments_skipped = 0
        self.promoted_epoch: int | None = None

    # ----------------------------------------------------------- tailing
    def tail(self) -> int:
        """One tail pass: scan new shipped bytes, apply fresh records,
        commit offsets.  Returns the number of records applied."""
        faults.hit("repl.tail")
        with self._lock:
            applied, touched = self._tail_locked()
        if touched:
            # stale notifications with no locks held (tenant.py contract)
            self.registry._notify_stale(sorted(touched))
        return applied

    def _tail_locked(self) -> tuple[int, set]:
        progress = []  # (basename, new_offset, [records])
        for name in self._segment_names():
            scanned = self._scan_one(name)
            if scanned is not None:
                progress.append(scanned)
        records = sorted(
            (r for _n, _o, recs in progress for r in recs),
            key=lambda r: r.lsn,
        )
        per_tenant: dict[str, dict] = {}
        for rec in records:
            if rec.tenant is None:
                continue  # standalone-store log shipped by mistake
            per_tenant.setdefault(str(rec.tenant), {})[rec.pid] = rec.values
        applied = 0
        touched: set[str] = set()
        try:
            for tenant, parts in sorted(per_tenant.items()):
                faults.hit("repl.apply", tenant=tenant, parts=len(parts))
                store = self.registry.tenant(tenant)
                fresh = {
                    pid: v
                    for pid, v in parts.items()
                    if pid not in store.summaries
                    and (store.watermark is None or pid > store.watermark)
                }
                if fresh:
                    store._apply(store._summarize_batch(fresh))
                    store._maybe_sweep()
                    applied += len(fresh)
                    touched.add(tenant)
        except BaseException:
            self.apply_failures += 1
            raise  # offsets NOT committed: the next tail re-scans + dedups
        # every group applied: commit scan state atomically
        for name, new_off, recs in progress:
            self._offsets[name] = new_off
            for rec in recs:
                key = "" if rec.tenant is None else str(rec.tenant)
                self._seen_mass[key] = self._seen_mass.get(key, 0) + int(
                    rec.values.size
                )
                if rec.lsn > self.applied_lsn:
                    self.applied_lsn = rec.lsn
        self.tails += 1
        self.records_applied += applied
        return applied, touched

    def _segment_names(self) -> list[str]:
        try:
            return sorted(
                n
                for n in os.listdir(self.dir)
                if n.startswith("wal-") and n.endswith(".log")
            )
        except FileNotFoundError:
            return []

    def _scan_one(self, name: str):
        """``(name, new_offset, records)`` of one segment's unread tail,
        or ``None`` when there is nothing new."""
        path = os.path.join(self.dir, name)
        off = self._offsets.get(name, 0)
        try:
            with open(path, "rb") as f:
                size = os.fstat(f.fileno()).st_size
                if size < off:
                    # the primary rewound this segment (append rollback
                    # frame): nothing beyond a record boundary was ever
                    # consumed, so just adopt the shorter length
                    self._offsets[name] = size
                    return None
                f.seek(off)
                data = f.read()
        except FileNotFoundError:
            return None  # vanished under us — re-listed next pass
        if off == 0:
            epoch, start = read_segment_epoch(data)
            self._epochs[name] = epoch
            self._data_start[name] = start
            data = data[start:]
            off = start
        if not data:
            return None
        if self._epochs.get(name, 0) < self.min_epoch:
            # a fenced (deposed-primary) segment: never apply, but keep
            # the offset pinned so repeated tails stay O(new bytes) —
            # and count only when bytes actually arrived, so idle tail
            # polling doesn't inflate the stat
            self.fenced_segments_skipped += 1
            return (name, off + len(data), [])
        records, consumed = scan_wal_bytes(data, 0)
        if not records:
            return None  # incomplete record tail — retry once more arrives
        return (name, off + consumed, records)

    # --------------------------------------------------------------- lag
    def _read_manifest(self) -> dict | None:
        try:
            with open(manifest_path(self.dir)) as f:
                return json.load(f)
        except (FileNotFoundError, ValueError, OSError):
            return None

    def lag(self) -> dict:
        """The replica's staleness snapshot against the last manifest:
        ``records`` (LSN gap), ``seconds`` (manifest age), ``mass``
        (total drift bound), ``known`` False when no manifest shipped
        yet (everything else ``None`` — honesty over guesses)."""
        manifest = self._read_manifest()
        with self._lock:
            applied = self.applied_lsn
            seen = dict(self._seen_mass)
        if manifest is None:
            return {
                "known": False,
                "records": None,
                "seconds": None,
                "mass": None,
                "epoch": None,
            }
        mass = sum(
            max(0, int(m) - seen.get(t, 0))
            for t, m in (manifest.get("mass") or {}).items()
        )
        return {
            "known": True,
            "records": max(0, int(manifest.get("written_lsn", 0)) - applied),
            "seconds": max(0.0, self.clock() - float(manifest.get("wall", 0))),
            "mass": mass,
            "epoch": int(manifest.get("epoch", 0)),
        }

    def drift_by_tenant(self) -> dict[str, int] | None:
        """Per-tenant mass-drift bound (``None`` = unknown, no manifest):
        how much appended mass the primary claims that this replica
        provably hasn't scanned — the ε-widening currency of
        :meth:`query_many`."""
        manifest = self._read_manifest()
        if manifest is None:
            return None
        with self._lock:
            seen = dict(self._seen_mass)
        return {
            t: max(0, int(m) - seen.get(t, 0))
            for t, m in (manifest.get("mass") or {}).items()
        }

    # ------------------------------------------------------------ queries
    def query_many(
        self,
        queries,
        beta: int,
        *,
        strict: bool = False,
        deadline: float | None = None,
    ) -> list:
        """Replica-side batch answering with bounded staleness.

        Answers come from the follower's own registry (one merge
        dispatch, the normal serving path) and are wrapped as
        :class:`~repro.core.resilience.Answer` with ``eps`` widened by
        the tenant's mass-drift bound and ``lag_seconds`` attached.
        ``degraded=True`` whenever the answer cannot be proven current:
        the underlying answer was already degraded, the tenant's drift
        is nonzero, no manifest is known, or the manifest's age exceeds
        ``staleness_slo``.  With no manifest the widening is ``inf`` —
        an honest "we cannot bound this" instead of a guess.
        """
        lag = self.lag()
        drift = self.drift_by_tenant()
        over_slo = self.staleness_slo is not None and (
            not lag["known"] or lag["seconds"] > self.staleness_slo
        )
        answers = self.registry.query_many(
            queries, beta, strict=strict, degraded_ok=True, deadline=deadline
        )
        out = []
        for (name, _lo, _hi), ans in zip(queries, answers):
            hist, eps = ans
            if drift is None:
                widen: float = float("inf")
                stale = True
            else:
                widen = float(drift.get(str(name), 0))
                stale = widen > 0
            degraded = (
                bool(getattr(ans, "degraded", False)) or stale or over_slo
            )
            out.append(
                Answer.make(
                    hist,
                    eps + widen,
                    degraded=degraded,
                    stale_version=getattr(ans, "stale_version", None),
                    lag_seconds=lag["seconds"],
                )
            )
        return out

    # ----------------------------------------------------------- failover
    def promote(
        self,
        *,
        fence: Callable[[int], None] | None = None,
        epoch: int | None = None,
        planes=(),
        receivers=(),
    ) -> TenantRegistry:
        """First-class failover: fence the old primary, drain the
        shipped suffix, adopt the shipped log as this registry's WAL,
        re-attach subscription planes.  Returns the (now primary-role)
        registry.

        ``fence`` is called with the new epoch against the old primary
        (e.g. ``replicator.fence`` or ``wal.fence``) — best-effort, a
        dead primary that cannot be reached is exactly the scenario
        (its persisted ``epoch.json`` fence closes the gap if it ever
        restarts).  ``receivers`` (stream-fed followers) are closed
        *before* the final drain so no frame can land after it;
        dir-transport senders are fenced by the ``epoch.json`` bump
        under the directory gate.  ``planes`` are
        :class:`~repro.serve.subscriptions.SubscriptionPlane` objects to
        re-home onto the promoted registry.
        """
        faults.hit("repl.promote")
        manifest = self._read_manifest()
        with self._lock:
            observed = [self.min_epoch, _dir_epoch(self.dir)]
            observed.extend(self._epochs.values())
            if manifest is not None:
                observed.append(int(manifest.get("epoch", 0)))
        new_epoch = (
            max(observed) + 1 if epoch is None else int(epoch)
        )
        if fence is not None:
            try:
                fence(new_epoch)
            except (OSError, ConnectionError):
                pass  # a dead/unreachable primary is already fenced by fate
        for rc in receivers:
            rc.close()
        # bulk drain, then fence our own directory (under the send gate:
        # a dir-transport frame in flight either landed before — caught
        # by the final drain — or raises PrimaryFenced at the sender,
        # failing its ack), then catch the stragglers
        while self.tail():
            pass
        with _dir_gate(self.dir):
            atomic_write_json(
                os.path.join(self.dir, "epoch.json"),
                {"epoch": new_epoch, "fenced_at": None},
            )
        while self.tail():
            pass
        # adopt the shipped segments as the promoted primary's own WAL:
        # a fresh higher-epoch segment for new appends, everything
        # already applied marked so checkpoint truncation works.  The
        # bootstrap snapshot's covered mass goes into the adopted log's
        # durable shed ledger first, so this promoted primary's own
        # ship manifests stay cumulative for *its* future followers.
        if any(self._boot_mass.values()):
            atomic_write_json(
                mass_meta_path(self.dir),
                {
                    "shed": {
                        t: int(m)
                        for t, m in self._boot_mass.items()
                        if m
                    },
                    "pending": {},
                },
            )
        wal = WriteAheadLog(self.dir, epoch=new_epoch)
        wal.mark_applied(r.lsn for r in wal.recovered_records())
        reg = self.registry
        reg.wal_dir = self.dir
        reg._wal = wal
        reg._pool.wal = wal
        reg._pool.wal_record = lambda item: (item[0], item[1], item[2])
        for plane in planes:
            plane.reattach(reg)
        self.promoted_epoch = new_epoch
        return reg

    # ------------------------------------------------------------- stats
    def stats(self) -> dict:
        lag = self.lag()
        with self._lock:
            return {
                "role": (
                    "replica" if self.promoted_epoch is None else "primary"
                ),
                "epoch": (
                    self.promoted_epoch
                    if self.promoted_epoch is not None
                    else lag["epoch"]
                ),
                "applied_lsn": self.applied_lsn,
                "tails": self.tails,
                "records_applied": self.records_applied,
                "apply_failures": self.apply_failures,
                "fenced_segments_skipped": self.fenced_segments_skipped,
                "lag": lag,
            }

    def close(self) -> None:
        self.registry.close()
