"""Training-plane integrations of the mergeable-histogram primitive.

The paper's motivating statistic is "p95 latency over all servers for any
time window".  A large training job needs exactly that class of query over
four data planes, all served by the same summarize→merge machinery:

  1. gradient / activation distributions   (blowup & underflow monitoring)
  2. quantile gradient clipping             (optim/ uses ``grad_clip_value``)
  3. histogram-threshold gradient sparsification (optim/compression.py)
  4. per-host step-time stragglers          (``StragglerDetector``)
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.histogram import Histogram, build_exact, merge, quantile
from repro.core.distributed import tensor_histogram_in_step
from repro.core.retention import RetentionPolicy
from repro.core.tenant import TenantRegistry

__all__ = [
    "tensor_summary",
    "tree_summaries",
    "grad_quantile",
    "StragglerDetector",
    "TelemetryLog",
    "TelemetryHub",
]


def tensor_summary(
    x: jax.Array,
    T: int = 256,
    *,
    magnitude: bool = True,
    mesh: jax.sharding.Mesh | None = None,
    axis_names: tuple[str, ...] = (),
) -> Histogram:
    """T-bucket summary of one tensor, jit-compatible.

    With a mesh, uses the paper's per-shard summarize + all-gather merge
    (``O(k·T)`` comm); without one, an exact local histogram.
    """
    v = jnp.abs(x) if magnitude else x
    v = v.astype(jnp.float32)
    if mesh is not None and axis_names:
        return tensor_histogram_in_step(v, T, T, mesh, axis_names)
    flat = v.reshape(-1)
    return build_exact(flat, min(T, flat.shape[0]))


def tree_summaries(
    tree: Any,
    T: int = 256,
    *,
    mesh: jax.sharding.Mesh | None = None,
    axis_names: tuple[str, ...] = (),
    magnitude: bool = True,
) -> dict[str, Histogram]:
    """Per-leaf summaries of a pytree (e.g. the gradient tree)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = jax.tree_util.keystr(path)
        out[name] = tensor_summary(
            leaf, T, magnitude=magnitude, mesh=mesh, axis_names=axis_names
        )
    return out


def grad_quantile(
    grads: Any,
    q: float,
    T: int = 512,
    *,
    mesh: jax.sharding.Mesh | None = None,
    axis_names: tuple[str, ...] = (),
) -> jax.Array:
    """Approximate q-quantile of |g| over the whole gradient tree.

    Per-leaf summaries are *merged* (not averaged) — Theorem 1 bounds the
    rank error of the returned threshold by ``2/T`` of the total count, which
    is what makes quantile clipping and top-ρ compression principled instead
    of heuristic.  Cost: one tiny all-gather per leaf, no global sort.
    """
    per_leaf = tree_summaries(
        grads, T, mesh=mesh, axis_names=axis_names, magnitude=True
    )
    hs = list(per_leaf.values())
    T_max = max(h.sizes.shape[-1] for h in hs)
    bs, ss = [], []
    for h in hs:
        pad = T_max - h.sizes.shape[-1]
        bs.append(
            jnp.concatenate([h.boundaries, jnp.repeat(h.boundaries[-1:], pad)])
        )
        ss.append(jnp.concatenate([h.sizes, jnp.zeros((pad,), h.sizes.dtype)]))
    merged = merge(Histogram(jnp.stack(bs), jnp.stack(ss)), T_max)
    return quantile(merged, jnp.float32(q))


@dataclass
class StragglerDetector:
    """Flags hosts whose step time exceeds the merged-histogram median ×
    tolerance.

    Each host ingests its own recent step times (a "partition" in paper
    terms); ``flag()`` merges all host summaries (the paper's Merger over
    per-host summaries) and returns hosts whose recent mean exceeds
    ``tolerance ×`` the merged ``quantile_q`` step time.  The reference
    quantile defaults to the *median*: a straggling host carries 1/k of the
    merged mass, so any quantile above ``1 - 1/k`` would be set by the
    straggler itself and mask it.  The trainer reports flags each log
    interval (and a deployment would shrink the host's data share).
    """

    window: int = 64
    T: int = 64
    quantile_q: float = 0.5
    tolerance: float = 1.5
    _times: dict[int, list[float]] = field(default_factory=dict)

    def record(self, host_id: int, step_seconds: float) -> None:
        buf = self._times.setdefault(int(host_id), [])
        buf.append(float(step_seconds))
        if len(buf) > self.window:
            del buf[: len(buf) - self.window]

    def flag(self) -> tuple[list[int], float]:
        """Returns (straggler host ids, global q-quantile step time)."""
        hosts = [h for h, b in self._times.items() if len(b) >= 4]
        if len(hosts) < 2:
            return [], float("nan")
        hs = []
        for h in hosts:
            v = jnp.asarray(np.asarray(self._times[h], dtype=np.float32))
            hs.append(build_exact(v, min(self.T, v.shape[0])))
        T_max = max(h.sizes.shape[-1] for h in hs)
        bs, ss = [], []
        for h in hs:
            pad = T_max - h.sizes.shape[-1]
            bs.append(
                jnp.concatenate(
                    [h.boundaries, jnp.repeat(h.boundaries[-1:], pad)]
                )
            )
            ss.append(
                jnp.concatenate([h.sizes, jnp.zeros((pad,), h.sizes.dtype)])
            )
        merged = merge(Histogram(jnp.stack(bs), jnp.stack(ss)), T_max)
        cut = float(quantile(merged, jnp.float32(self.quantile_q)))
        flagged = [
            h
            for h in hosts
            if float(np.mean(self._times[h][-8:])) > self.tolerance * cut
        ]
        return flagged, cut


@dataclass
class TelemetryLog:
    """Host-side ring of per-step scalar statistics + histogram snapshots."""

    capacity: int = 1024
    scalars: dict[str, list[tuple[int, float]]] = field(default_factory=dict)
    snapshots: dict[str, dict[str, np.ndarray]] = field(default_factory=dict)

    def log_scalar(self, name: str, step: int, value: float) -> None:
        buf = self.scalars.setdefault(name, [])
        buf.append((int(step), float(value)))
        if len(buf) > self.capacity:
            del buf[: len(buf) - self.capacity]

    def log_histogram(self, name: str, step: int, hist: Histogram) -> None:
        self.snapshots[f"{name}@{step}"] = {
            "boundaries": np.asarray(hist.boundaries),
            "sizes": np.asarray(hist.sizes),
        }

    def last(self, name: str) -> float:
        return self.scalars[name][-1][1]


@dataclass
class TelemetryHub:
    """Many named metric streams through ONE multi-tenant registry.

    The serving-plane counterpart of :class:`TelemetryLog`: every metric
    (a gradient leaf's magnitudes, a host's step times, a service's
    latencies) is a *tenant* of a shared :class:`TenantRegistry`, and
    every window of raw samples (a step range, a day) is a partition —
    so one registry answers "p95 of ANY metric over ANY window" with
    per-metric stores, per-metric LRU caches, and a whole dashboard of
    cross-metric panels in a single merge dispatch
    (``TenantRegistry.query_many``).

    ``async_record=True`` routes samples through the registry's shared
    worker pool — the trainer thread only enqueues; call :meth:`flush`
    before reading fresh windows.

    A long-running trainer records windows forever, so the hub forwards
    the registry's bounded-memory knobs (core/retention.py): ``retention``
    ages every metric's old windows out (e.g. ``SlidingWindow(256)`` keeps
    the last 256 step-windows per metric), ``budget`` caps total node
    floats across ALL metrics with fair per-metric quotas.
    ``shared_arena=True`` pools every metric's tree nodes into one
    registry-owned arena (core/arena.py) — dashboards then assemble their
    cross-metric merge stacks with a single device gather.
    """

    T: int = 128
    async_record: bool = False
    registry: TenantRegistry = None
    retention: RetentionPolicy | None = None
    budget: int | None = None
    shared_arena: bool = False
    # durable ingest: a directory path gives the hub's registry a
    # write-ahead log — recorded windows survive a trainer crash between
    # record() and checkpoint() (core/workers.py WAL design note)
    wal_dir: str | None = None

    def __post_init__(self) -> None:
        if self.registry is None:
            self.registry = TenantRegistry(
                num_buckets=self.T,
                retention=self.retention,
                budget=self.budget,
                shared_arena=self.shared_arena,
                wal_dir=self.wal_dir,
            )
        elif (
            self.retention is not None
            or self.budget is not None
            or self.wal_dir is not None
        ):
            # an explicit registry carries its own knobs — silently
            # ignoring these would unbound the memory (or void the
            # durability) they promise
            raise ValueError(
                "pass retention/budget/wal_dir to the explicit "
                "TenantRegistry, not to TelemetryHub"
            )

    def record(self, metric: str, partition_id: int, values) -> None:
        """Summarize one window of raw samples for the named metric."""
        if self.async_record:
            self.registry.ingest_async(metric, partition_id, values)
        else:
            self.registry.ingest(metric, partition_id, values)

    def flush(self) -> None:
        self.registry.flush()

    def close(self) -> None:
        self.registry.close()

    def metrics(self) -> list[str]:
        return self.registry.names()

    def wal_stats(self) -> dict | None:
        """Durable-ingest telemetry: WAL depth (records appended but not
        yet applied), fsync count/latency, and byte/segment footprint —
        ``None`` when the hub's registry runs without a log."""
        return self.registry.wal_stats()

    def health(self) -> dict:
        """Serving-plane health aggregate: breaker/quarantine states,
        degraded-answer and backpressure counters (including the last
        backpressure reject's retry-after hint), WAL/pool stats, last
        recovery/scrub reports, and — when a :class:`Replicator` is
        attached to the registry — replication ship counters
        (``TenantRegistry.health``)."""
        return self.registry.health()

    def quantile(
        self, metric: str, lo: int, hi: int, q, beta: int | None = None
    ) -> np.ndarray:
        """q-quantile of one metric over windows ``lo..hi`` (paper-style:
        'p95 latency for any interval', now for any of N metrics)."""
        return self.registry[metric].quantile_query(lo, hi, q, beta)

    def dashboard(
        self,
        panels: "list[tuple[str, int, int]]",
        beta: int = 64,
    ) -> list[tuple[Histogram | None, float]]:
        """Answer a whole dashboard — ``[(metric, lo, hi), ...]`` — with at
        most one cross-tenant merge dispatch; missing metrics/windows come
        back as the ``(None, inf)`` placeholder instead of failing the
        refresh."""
        return self.registry.query_many(panels, beta, strict=False)

    def subscribe(
        self,
        metric: str,
        lo: int,
        hi: int,
        beta: int = 64,
        *,
        policy: str = "coalesce",
        queue_cap: int = 8,
    ):
        """Standing dashboard panel: instead of re-polling
        :meth:`dashboard`, receive pushed ``Update``s whenever windows
        ``lo..hi`` of the metric go stale (serve/subscriptions.py) —
        same hist/eps the pull path reports, one merge dispatch per
        ingest tick across every subscription on the hub."""
        # local import: serve/ imports core/, not the other way around
        from repro.serve.subscriptions import SubscriptionPlane

        planes = self.registry._stale_listeners
        plane = planes[0] if planes else SubscriptionPlane(self.registry)
        return plane.subscribe(
            metric, lo, hi, beta, policy=policy, queue_cap=queue_cap
        )

    def unsubscribe(self, sub) -> None:
        sub.plane.unsubscribe(sub)


def timed(fn: Callable) -> Callable:
    """Decorator: returns (result, wall_seconds); feeds StragglerDetector."""

    def wrapper(*a, **k):
        t0 = time.perf_counter()
        out = fn(*a, **k)
        jax.block_until_ready(out)
        return out, time.perf_counter() - t0

    return wrapper
