"""Windowed retention: TTL / sliding-window / memory-budget policies.

The paper's framework answers queries "for a given time interval", but the
summary store it implies is append-only: an infinite stream (a new partition
per day, forever) grows leaf summaries and pre-merged tree nodes without
bound, and old partitions can never leave the store.  This module makes the
time-interval semantics first-class: a :class:`RetentionPolicy` decides, per
sweep, which partitions have left the window, and the store evicts their
leaves (``IntervalTree.evict_leaves`` — ``set_leaf``'s pull-up in reverse,
with lazy subtree collapse) so memory stays bounded for always-on serving.

Watermark semantics
-------------------
Partition ids ARE the time axis (the paper's "days"), so retention is
**watermark-driven, not wall-clock-driven**: the watermark is the highest
partition id ever ingested, it only moves forward, and :class:`TTL` ages
partitions against it.  Replaying a historical stream therefore evicts
exactly what the live stream would have evicted, and a store reloaded from
npz (the watermark persists through ``HistogramStore._state``/``_restore``)
resumes aging where it stopped instead of resurrecting expired partitions.

Policies
--------
* ``TTL(max_age)``           — evict partitions older than ``max_age`` ids
  behind the watermark (keeps ids in ``[watermark - max_age, watermark]``).
* ``SlidingWindow(max_partitions)`` — keep only the newest
  ``max_partitions`` present partitions.
* ``MemoryBudget(max_node_floats)`` — evict oldest partitions until the
  tree's node-float footprint fits the budget (never evicts the newest
  partition, so a single oversized partition cannot livelock the sweeper).
* ``AnyOf(p1, p2, ...)``     — union of victims (e.g. TTL *and* a budget).

Policies are pure: ``victims(stats)`` maps a :class:`StoreStats` snapshot to
the partition ids to evict and never touches the store.  The sweeper
(``HistogramStore.sweep_retention``) re-evaluates until the policy returns
nothing, so ``MemoryBudget`` may converge over a few estimate-driven passes
while TTL/window converge in one.

Memory metering and collapse modes
----------------------------------
``StoreStats.node_floats`` (what :class:`MemoryBudget` meters) counts
*logical* summary floats per unique arena row — layout-independent, so
budget calibrations survive the pooled-arena storage (core/arena.py); the
resident pool size itself is ``NodeArena.allocated_floats`` /
``capacity_floats``.  Under ``HistogramStore(collapse="amortized")`` the
evicted dead prefix lingers until it exceeds half the tree capacity, so
the footprint rides up to one extra tree level above the canonical mode's
before the deferred re-root reclaims it — the sweeper's convergence loop
is unaffected because ``victims`` only ever names present partitions.

Where sweeps run
----------------
Synchronous ingest sweeps inline after each apply; asynchronous ingest runs
the sweeper on the shared ingest worker (core/workers.py ``on_batch_end``)
between flushes, so ``flush()`` returning implies retention has been
enforced on everything visible.  ``TenantRegistry(budget=...)`` adds the
cross-tenant layer: a global node-float budget with fair per-tenant quotas
(evict from the largest-over-quota tenant first) on top of any per-tenant
policy.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

__all__ = [
    "StoreStats",
    "RetentionPolicy",
    "TTL",
    "SlidingWindow",
    "MemoryBudget",
    "AnyOf",
    "policy_from_spec",
]


@dataclass(frozen=True)
class StoreStats:
    """Policy-facing snapshot of one store (taken under the store lock)."""

    ids: tuple[int, ...]  # sorted present partition ids
    watermark: int | None  # highest partition id ever ingested
    node_floats: int  # current tree node-float footprint (shared arrays
    #                   counted once — IntervalTree.node_floats)


class RetentionPolicy:
    """Decides which partitions leave the store.  Pure: no store access."""

    def victims(self, stats: StoreStats) -> list[int]:
        """Partition ids to evict given the snapshot (may be re-evaluated
        by the sweeper until it returns an empty list)."""
        raise NotImplementedError

    def spec(self) -> dict:
        """json-able self-description for npz persistence; inverse of
        :func:`policy_from_spec`."""
        raise NotImplementedError


@dataclass(frozen=True)
class TTL(RetentionPolicy):
    """Evict partitions more than ``max_age`` ids behind the watermark."""

    max_age: int

    def __post_init__(self) -> None:
        if self.max_age < 0:
            raise ValueError("max_age must be >= 0")

    def victims(self, stats: StoreStats) -> list[int]:
        if stats.watermark is None:
            return []
        horizon = stats.watermark - self.max_age
        return [p for p in stats.ids if p < horizon]

    def spec(self) -> dict:
        return {"kind": "ttl", "max_age": int(self.max_age)}


@dataclass(frozen=True)
class SlidingWindow(RetentionPolicy):
    """Keep only the newest ``max_partitions`` present partitions."""

    max_partitions: int

    def __post_init__(self) -> None:
        if self.max_partitions < 1:
            raise ValueError("max_partitions must be >= 1")

    def victims(self, stats: StoreStats) -> list[int]:
        k = len(stats.ids) - self.max_partitions
        return list(stats.ids[:k]) if k > 0 else []

    def spec(self) -> dict:
        return {"kind": "window", "max_partitions": int(self.max_partitions)}


@dataclass(frozen=True)
class MemoryBudget(RetentionPolicy):
    """Evict oldest partitions until node floats fit ``max_node_floats``.

    The victim count per pass is an estimate (``need / mean floats per
    partition``) because collapse frees internal nodes non-linearly; the
    sweeper's re-evaluation loop absorbs the estimation error.  The newest
    partition is never a victim.
    """

    max_node_floats: int

    def __post_init__(self) -> None:
        if self.max_node_floats < 1:
            raise ValueError("max_node_floats must be >= 1")

    def victims(self, stats: StoreStats) -> list[int]:
        if stats.node_floats <= self.max_node_floats or len(stats.ids) <= 1:
            return []
        per_part = stats.node_floats / len(stats.ids)
        need = stats.node_floats - self.max_node_floats
        k = min(len(stats.ids) - 1, max(1, math.ceil(need / per_part)))
        return list(stats.ids[:k])

    def spec(self) -> dict:
        return {"kind": "budget", "max_node_floats": int(self.max_node_floats)}


class AnyOf(RetentionPolicy):
    """Union of victims: a partition leaves when ANY member policy says so
    (e.g. ``AnyOf(TTL(30), MemoryBudget(1_000_000))``)."""

    def __init__(self, *policies: RetentionPolicy):
        if not policies:
            raise ValueError("AnyOf needs at least one policy")
        self.policies = tuple(policies)

    def victims(self, stats: StoreStats) -> list[int]:
        out: set[int] = set()
        for p in self.policies:
            out.update(p.victims(stats))
        return sorted(out)

    def spec(self) -> dict:
        return {"kind": "any_of", "policies": [p.spec() for p in self.policies]}

    def __eq__(self, other) -> bool:
        return isinstance(other, AnyOf) and self.policies == other.policies

    def __hash__(self) -> int:
        return hash(self.policies)

    def __repr__(self) -> str:
        return f"AnyOf{self.policies!r}"


def policy_from_spec(spec: dict | None) -> RetentionPolicy | None:
    """Rebuild a policy from its :meth:`RetentionPolicy.spec` dict."""
    if spec is None:
        return None
    kind = spec["kind"]
    if kind == "ttl":
        return TTL(int(spec["max_age"]))
    if kind == "window":
        return SlidingWindow(int(spec["max_partitions"]))
    if kind == "budget":
        return MemoryBudget(int(spec["max_node_floats"]))
    if kind == "any_of":
        return AnyOf(*(policy_from_spec(s) for s in spec["policies"]))
    raise ValueError(f"unknown retention policy kind: {kind!r}")
