"""Deterministic failpoint injection — the chaos plane's one entry point.

A production serving plane only honors the paper's error guarantee if it
keeps honoring it under partial failure: disk-full WAL appends, torn
writes, crashed ingest workers, corrupted snapshots, poisoned tenants.
Those faults are rare and timing-dependent in the wild, which is exactly
why they must be *injectable on demand and deterministically* in tests
and benchmarks.  This module is the named-failpoint registry every
fault-tolerant layer threads through:

    core/workers.py   wal.append / wal.append.torn / wal.fsync /
                      pool.batch / pool.retry
    core/arena.py     arena.alloc / arena.rows / arena.gather
    core/tenant.py    tenant.merge / tenant.apply
    core/stream.py    snapshot.save / snapshot.save.corrupt / snapshot.load
    checkpoint/       checkpoint.save / checkpoint.restore
    core/replication.py  repl.ship / repl.tail / repl.apply / repl.promote

Design rules
------------
* **Zero overhead when disarmed.**  Every site calls :func:`hit`, whose
  fast path is one module-global boolean read — nothing armed means no
  dict lookup, no lock, no allocation.  BENCH_faults.json machine-checks
  that the disabled framework costs ≤ 1 % on the ingest and query paths.
* **Deterministic triggers.**  A failpoint fires on an explicit schedule:
  ``times`` (first N matching hits), ``after`` (skip the first N),
  ``prob`` with a **seeded** per-failpoint RNG, or any combination.  The
  same seed and the same hit sequence produce the same fault schedule —
  the chaos property test replays schedules byte-for-byte.
* **Context filtering.**  Sites pass keyword context
  (``hit("tenant.apply", tenant=name)``); an armed failpoint may carry a
  ``match`` predicate over that context, so a test can poison exactly one
  tenant without touching the shared batch machinery.
* **Scoped arming.**  :func:`inject` is a context manager; on exit the
  failpoint is disarmed and the global flag drops back when the registry
  empties.  Nesting arms independent failpoints; re-arming the same name
  replaces the previous spec (last-in wins, restored on exit).

A failpoint either **raises** (``exc=``: an exception instance — re-used
as-is — or a zero-arg factory) or **acts** (``action=``: a zero-arg or
context-kwargs callable whose return value the site receives from
``hit``; sites use this for partial-effect faults like torn writes, where
the action returns how many bytes to write before the simulated crash).
``hit`` returns ``default`` when nothing fires, so sites read naturally::

    torn = faults.hit("wal.append.torn")     # None unless armed+triggered
    faults.hit("wal.fsync")                  # raises when armed+triggered

Observability: every :class:`Failpoint` counts ``hits`` (site reached)
and ``fires`` (fault actually delivered); :func:`stats` snapshots the
whole registry for the chaos harness and ``health()`` surfaces.
"""
from __future__ import annotations

import random
from typing import Callable

from repro.analysis.witness import OrderedLock

__all__ = [
    "FaultError",
    "Failpoint",
    "SITES",
    "fires",
    "hit",
    "inject",
    "is_armed",
    "reset",
    "stats",
]

# The declared failpoint sites — the single source of truth.  Every
# ``hit(name)`` call in src/ must name a member, every member must have a
# live call site, and every member must be referenced by at least one
# test; ``scripts/analyze.py``'s failpoint rule enforces all three, so a
# renamed or orphaned site fails CI instead of silently never firing.
SITES: frozenset[str] = frozenset({
    "wal.append",
    "wal.append.torn",
    "wal.fsync",
    "pool.batch",
    "pool.retry",
    "arena.alloc",
    "arena.rows",
    "arena.gather",
    "tenant.apply",
    "tenant.merge",
    "subs.eval",
    "subs.deliver",
    "snapshot.save",
    "snapshot.save.corrupt",
    "snapshot.load",
    "checkpoint.save",
    "checkpoint.restore",
    "repl.ship",
    "repl.tail",
    "repl.apply",
    "repl.promote",
})


class FaultError(Exception):
    """Default injected-fault type (sites never raise this themselves)."""


# fast-path flag: hit() reads this one global before anything else, so a
# fully-disarmed process pays a single boolean check per site
_ARMED = False
_LOCK = OrderedLock("faults.registry")
_REGISTRY: dict[str, "Failpoint"] = {}


class Failpoint:
    """One armed failpoint: trigger schedule + effect + counters."""

    def __init__(
        self,
        name: str,
        *,
        exc: BaseException | Callable[[], BaseException] | None = None,
        action: Callable | None = None,
        times: int | None = None,
        after: int = 0,
        prob: float = 1.0,
        seed: int = 0,
        match: Callable[[dict], bool] | None = None,
    ):
        if exc is not None and action is not None:
            raise ValueError("a failpoint raises OR acts, not both")
        if exc is None and action is None:
            exc = FaultError(name)
        self.name = name
        self.exc = exc
        self.action = action
        self.times = None if times is None else int(times)  # fires budget
        self.after = int(after)  # matching hits to skip before firing
        self.prob = float(prob)
        self.match = match
        self._rng = random.Random(seed)  # per-failpoint: schedules replay
        self.hits = 0  # site reached (post-match)
        self.fires = 0  # fault delivered

    def _check(self, ctx: dict):
        """(triggered, effect) under the registry lock."""
        if self.match is not None and not self.match(ctx):
            return False
        self.hits += 1
        if self.hits <= self.after:
            return False
        if self.times is not None and self.fires >= self.times:
            return False
        if self.prob < 1.0 and self._rng.random() >= self.prob:
            return False
        self.fires += 1
        return True


def hit(name: str, default=None, **ctx):
    """Failpoint site: raise or return the armed effect, else ``default``.

    The disarmed fast path is one global boolean read.  Armed, the
    trigger decision runs under the registry lock (counters and the
    seeded RNG stay race-free); the effect itself — raising ``exc`` or
    calling ``action`` — runs outside it, so an action may sleep or
    re-enter arbitrary code without holding the chaos plane's lock.
    """
    if not _ARMED:
        return default
    with _LOCK:
        fp = _REGISTRY.get(name)
        if fp is None or not fp._check(ctx):
            return default
        exc, action = fp.exc, fp.action
    if exc is not None:
        raise exc() if callable(exc) else exc
    try:
        return action(**ctx)
    except TypeError:
        if ctx:  # zero-arg action at a context-passing site
            return action()
        raise


class _Scope:
    """Context manager returned by :func:`inject` — disarm on exit,
    restoring whatever the name was armed with before (nesting-safe)."""

    def __init__(self, fp: Failpoint):
        global _ARMED
        self.fp = fp
        with _LOCK:
            self.prev = _REGISTRY.get(fp.name)
            _REGISTRY[fp.name] = fp
            _ARMED = True

    def __enter__(self) -> Failpoint:
        return self.fp

    def __exit__(self, *exc_info) -> None:
        global _ARMED
        with _LOCK:
            if _REGISTRY.get(self.fp.name) is self.fp:
                if self.prev is None:
                    _REGISTRY.pop(self.fp.name, None)
                else:
                    _REGISTRY[self.fp.name] = self.prev
            if not _REGISTRY:
                _ARMED = False


def inject(
    name: str,
    *,
    exc: BaseException | Callable[[], BaseException] | None = None,
    action: Callable | None = None,
    times: int | None = None,
    after: int = 0,
    prob: float = 1.0,
    seed: int = 0,
    match: Callable[[dict], bool] | None = None,
) -> _Scope:
    """Arm ``name`` for the duration of the returned context manager.

    >>> with faults.inject("wal.fsync", exc=OSError(28, "No space"),
    ...                    times=2):
    ...     store.ingest(0, values)     # first two fsyncs fail
    """
    return _Scope(
        Failpoint(
            name,
            exc=exc,
            action=action,
            times=times,
            after=after,
            prob=prob,
            seed=seed,
            match=match,
        )
    )


def is_armed(name: str) -> bool:
    if not _ARMED:
        return False
    with _LOCK:
        return name in _REGISTRY


def fires(name: str) -> int:
    """Faults delivered by the currently-armed failpoint (0 if disarmed)."""
    with _LOCK:
        fp = _REGISTRY.get(name)
        return 0 if fp is None else fp.fires


def stats() -> dict[str, dict[str, int]]:
    """Registry snapshot: ``{name: {hits, fires}}`` for armed failpoints."""
    with _LOCK:
        return {
            name: {"hits": fp.hits, "fires": fp.fires}
            for name, fp in _REGISTRY.items()
        }


def reset() -> None:
    """Disarm everything (test teardown belt-and-braces)."""
    global _ARMED
    with _LOCK:
        _REGISTRY.clear()
        _ARMED = False
