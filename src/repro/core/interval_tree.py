"""Segment-tree interval engine over stored partition summaries.

Why a tree
----------
The paper's Merger answers "equi-depth histogram over partitions lo..hi" by
merging the stored per-partition ``T``-bucket summaries.  Done flat, every
query re-merges the whole window: ``O(W)`` summaries sorted per query, and a
fresh XLA compile for every distinct window length ``k`` (the ``(k, T+1)``
merge shape is static).  This module maintains a power-of-two **segment
tree** over the partition axis instead:

    level 0   the stored leaf summaries (exact, ``T`` buckets)
    level l   one pre-merged ``T_node``-bucket summary per aligned pair of
              level-(l-1) nodes, i.e. node ``(l, i)`` summarizes partition
              slots ``[i·2^l, (i+1)·2^l)``

so any interval ``[lo, hi]`` decomposes into at most ``2·log2(W)`` canonical
nodes (the classic bottom-up cover), and a query merges only those:
``O(log W)`` summaries per query instead of ``O(W)``.  Node maintenance on
ingest is ``O(log W)`` pairwise merges; bulk (re)builds batch each level into
a single vmapped jitted merge.

Node storage — the shared arena
-------------------------------
Node summaries do not own their arrays: every node is a lightweight
:class:`TreeNode` *handle* — a ``(arena, width, row)`` reference into a
pooled :class:`~repro.core.arena.NodeArena` plane plus the error-bound
bookkeeping — and its ``boundaries``/``sizes`` are views of the pooled
rows.  One tree owns one arena by default; a multi-tenant registry can
hand every same-config tenant a single shared arena
(``TenantRegistry(shared_arena=True)``), which turns the cross-tenant
merge-stack pack into a single device gather (:func:`pack_device_rows`)
and lets a drained ingest batch pull up *all* touched trees with one
merge dispatch per level (:func:`pull_up_trees`).  Rows are write-once
and freed by handle garbage-collection, so an in-flight pack that holds
node handles can never observe a reused row — see the arena module
docstring for the slot-lifecycle contract.

Composed error bound (paper Theorem 1, applied per level)
---------------------------------------------------------
Theorem 1: merging ``k`` *exact* ``T``-bucket histograms of ``N`` total
values yields every bucket (and, Theorem 2, every contiguous bucket range)
within ``ε < 2N/T`` of ideal; integer-rounded inputs (``T ∤ |P_i|``) add a
``+2k`` slack.  The theorem composes recursively — the same fact the tile →
device → pod hierarchy exploits in ``core/distributed.py``: if the ``k``
inputs are themselves approximate with summary errors ``ε_i``, the output
error is bounded by

    ε_out  ≤  Σ_i ε_i  +  2N/T_in  +  2k                       (composition)

because the merge is exact w.r.t. the *claimed* input masses (±2N/T_in + 2k)
and the claims are off by at most Σ ε_i.  Each tree node therefore carries
its own accumulated bound: leaves have ``ε = 0``; an internal node built
from children with resolutions ``≥ T_in`` has

    ε_node = ε_left + ε_right + 2·n_node/T_in + 4 .

A query that merges canonical nodes {v} into β buckets reports

    ε_total = Σ_v ε_v + 2N/min_v T_v + 2·|{v}|
            < 2N · Σ_level 1/T_level  (+ integer slack),

the ``ε_total < 2N·Σ_level 1/T_level`` form of the module header, with
``T_level = T`` uniform giving ``ε_total < 2N·(1 + ⌈log2 W⌉)/T``.

**Geometric per-level resolution** (``geometric=True``): node resolution
doubles per level — a level-``l`` node holds ``T_node·2^l`` buckets — so the
per-level error terms form a geometric series and the composed bound
converges to ``ε_total < 4N/T_leaf`` *independent of depth*, at ``O(log W)``
extra memory per leaf (every level stores ``W·T`` bucket floats in total
instead of the uniform mode's ``W·T/2^l``).  Because a level-``l`` pair
merge emits exactly as many buckets as its two children jointly carry
boundaries, geometric nodes lose no resolution on the way up — the only
per-level error is the left-collapse term ``2n/T_in`` of the level below.
Exposed as ``HistogramStore(T_node="geometric")``.  In the arena layout
each level resolution is its own plane — the per-level views of the pool.

What is (and is not) bit-exact
------------------------------
The paper's merge is *lossy* (left-collapse repositions mass), so a
pre-merged internal node cannot reproduce the flat merge of its leaves
bit-for-bit — that is exactly why ε composes per level instead of being flat
``2N/T``.  What *is* bit-exact, proven below and asserted by
``tests/test_interval_tree.py``:

  * ``query`` ≡ ``merge_list`` over the selected canonical node summaries;
  * ``query_many`` (which pads every query's node set to one static
    ``(k_pad, T_pad)`` shape so a single jitted merge serves the whole
    batch) ≡ per-query ``query``;
  * intervals whose canonical cover is all leaves (single partition, or any
    two-partition span crossing a pair boundary) ≡ the flat
    ``merge_list`` over the raw leaf summaries.

Padding invariance: inserting a zero-mass boundary at any value ``v`` inside
``[min, max]`` of the pre-histogram leaves every output bit unchanged.  With
the inserted element at sorted position ``p``, the cumulative array ``A``
gains a duplicate of ``A[p-1]``; for each cut target ``t_j``, either
``A[p-1] ≤ t_j`` (then ``cut_j`` shifts by exactly the one inserted slot and
``pos[cut_j]`` is unchanged) or ``A[p-1] > t_j`` (then ``cut_j`` indexes the
untouched prefix).  First/last output boundaries are the global min/max,
which zero-mass interior padding cannot displace.  Hence the per-node ``T``
padding, the per-query ``k`` padding (rows of zero-mass duplicates of a real
boundary — whether a repeated scalar or a full copy of a real row), and the
arena's stored row padding are all bit-exact, and the engine can pad node
sets to the next power of two for a bounded jit-cache footprint.

Caching
-------
Answers are memoized in an LRU keyed ``(lo, hi, beta, version)`` where
``version`` bumps on every mutation — the hot dashboards-asking-the-same-
window path (millions of users, few distinct windows) is served from host
memory without touching XLA at all.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Sequence

import jax
import numpy as np

from repro.analysis.witness import OrderedLock
from repro.core.arena import NodeArena
from repro.core.histogram import Histogram, merge, next_pow2

__all__ = [
    "TreeNode",
    "IntervalTree",
    "canonical_decomposition",
    "merge_stacks",
    "pack_node_rows",
    "pack_device_rows",
    "pull_up_trees",
    "selection_eps",
]

COLLAPSE_MODES = ("canonical", "amortized")

# Ingest-path merge observability (module-wide: the cross-tenant batched
# pull-up issues ONE dispatch per level for a whole drained batch, so the
# counter cannot live on any single tree).  Benchmarks read and reset these
# to machine-check the "one dispatch per level across tenants" claim and
# the amortized-collapse merge-work claim.
_COUNTER_LOCK = OrderedLock("tree.counters")
PULLUP_STATS = {"dispatches": 0, "pair_merges": 0}


def reset_pullup_stats() -> dict[str, int]:
    with _COUNTER_LOCK:
        out = dict(PULLUP_STATS)
        PULLUP_STATS["dispatches"] = 0
        PULLUP_STATS["pair_merges"] = 0
    return out


class TreeNode:
    """One tree node: an arena row handle plus error-bound bookkeeping.

    ``boundaries``/``sizes`` are NumPy views of the pooled row (valid while
    the handle is referenced — the arena frees the row when the last handle
    is garbage-collected, which is what makes concurrent eviction safe
    against in-flight packs).  ``src`` optionally remembers the caller's
    original leaf arrays so the store's pointer-identity staleness scan
    (``HistogramStore._sync_tree``) works without re-reading row data.
    """

    __slots__ = ("arena", "width", "row", "T", "n", "eps", "leaves", "src")

    def __init__(
        self,
        arena: NodeArena,
        width: int,
        row: int,
        T: int,
        n: float,
        eps: float,
        leaves: int,
        src: tuple | None = None,
    ):
        self.arena = arena
        self.width = width
        self.row = row
        self.T = T
        self.n = n
        self.eps = eps
        self.leaves = leaves
        self.src = src

    def __del__(self):  # pragma: no cover - exercised indirectly everywhere
        arena = getattr(self, "arena", None)
        if arena is not None:
            try:
                arena._dead.append((self.width, self.row))
            except Exception:
                pass  # interpreter shutdown

    @property
    def boundaries(self) -> np.ndarray:
        return self.arena.view(self.width, self.row)[0][: self.T + 1]

    @property
    def sizes(self) -> np.ndarray:
        return self.arena.view(self.width, self.row)[1][: self.T]

    @property
    def num_buckets(self) -> int:
        return self.T

    def to_histogram(self) -> Histogram:
        import jax.numpy as jnp

        return Histogram(
            boundaries=jnp.asarray(self.boundaries),
            sizes=jnp.asarray(self.sizes),
        )


def canonical_decomposition(lo: int, hi: int) -> list[tuple[int, int]]:
    """Canonical segment-tree cover of leaf slots ``[lo, hi]`` (inclusive).

    Returns ``(level, index)`` keys, left-to-right, where node ``(l, i)``
    covers slots ``[i·2^l, (i+1)·2^l)``.  At most two nodes per level →
    ``≤ 2·⌈log2(hi-lo+1)⌉ + 1`` nodes total.
    """
    left: list[tuple[int, int]] = []
    right: list[tuple[int, int]] = []
    l, r = lo, hi + 1  # half-open
    level = 0
    while l < r:
        if l & 1:
            left.append((level, l))
            l += 1
        if r & 1:
            r -= 1
            right.append((level, r))
        l >>= 1
        r >>= 1
        level += 1
    return left + right[::-1]


@functools.partial(jax.jit, static_argnames=("beta",))
def merge_stacks(bounds: jax.Array, sizes: jax.Array, beta: int):
    """Batched merge: ``(Q, k, T+1)``/``(Q, k, T)`` → ``(Q, β+1)``/``(Q, β)``.

    One compile per static ``(Q, k, T, β)``; ``query`` pads ``k`` to a power
    of two and ``query_many`` pads a whole batch to one shape, so the cache
    stays small under production traffic.  Shared by every batched Merger
    path: the tree's own queries, its level maintenance, and the
    cross-tenant ``TenantRegistry.query_many`` (core/tenant.py), which
    stacks canonical node sets from *different* trees into one block.
    """
    return jax.vmap(lambda b, s: merge(Histogram(b, s), beta))(bounds, sizes)


@jax.jit
def _gather_rows(pool_b, pool_s, idx, mask):
    """Device-side merge-stack assembly: ``(n_slots, W+1)`` pools + a
    ``(Q, k_pad)`` slot index → ``(Q, k_pad, W+1)``/``(Q, k_pad, W)``.
    Pad entries point at a real row with a zero mask, so they become the
    bit-exact zero-mass-duplicate pad rows of the host pack."""
    import jax.numpy as jnp

    return (
        jnp.take(pool_b, idx, axis=0),
        jnp.take(pool_s, idx, axis=0) * mask[:, :, None],
    )


def _scatter_rows(
    bounds: np.ndarray,
    sizes: np.ndarray,
    entries: Sequence[tuple[tuple, TreeNode]],
    T_pad: int,
) -> None:
    """Fill pre-zeroed ``(..., T_pad+1)``/``(..., T_pad)`` blocks from arena
    rows with one fancy-index copy per (arena, plane) instead of one copy +
    pad per node.  ``entries`` maps a block position (an index tuple) to a
    node; rows stored narrower than ``T_pad`` get the zero-mass tail pad,
    rows stored wider truncate (their tail is zero-mass padding already —
    both directions are the bit-exact padding rule of the module docstring).
    """
    groups: dict[tuple[int, int], list[tuple[tuple, TreeNode]]] = {}
    for pos, nd in entries:
        groups.setdefault((id(nd.arena), nd.width), []).append((pos, nd))
    for (_, width), items in groups.items():
        arena = items[0][1].arena
        bblock, sblock = arena.rows(width, [nd.row for _, nd in items])
        pos_idx = tuple(
            np.asarray([pos[d] for pos, _ in items])
            for d in range(len(items[0][0]))
        )
        w = min(width, T_pad)
        bounds[pos_idx + (slice(None, w + 1),)] = bblock[:, : w + 1]
        if T_pad > width:
            bounds[pos_idx + (slice(width + 1, None),)] = bblock[:, width:][
                :, -1:
            ]
        sizes[pos_idx + (slice(None, w),)] = sblock[:, :w]


def pack_node_rows(
    rows: Sequence[Sequence[TreeNode]],
    *,
    T_pad: int | None = None,
    pad_row_copy: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-query node sets into one ``(Q, k_pad, T_pad)`` block.

    ``k`` pads to the next power of two with rows of zero-mass duplicates of
    a real boundary; ``T`` pads merge_list-style.  Both are bit-exact (module
    docstring).  Rows may come from *different* trees (the cross-tenant
    registry path) — only the summary arrays matter.  The block is filled
    with one stacked fancy-index copy per (arena, plane) rather than one
    copy per node (the copies are still counted by the arenas'
    ``host_row_copies`` — the device gather path exists precisely to make
    that counter stay zero).

    ``T_pad`` overrides the padded bucket width (default: the widest
    selected node) — the registry's host path pads to the arena plane width
    so its block is bit-identical to the device gather's.  ``pad_row_copy``
    pads ``k`` with full zero-mass copies of the row's last real node
    (matching the gather) instead of the scalar last-boundary fill; both
    rules are bit-exact.

    An empty row packs to an all-zero-mass constant row: its merge output
    is well-defined but meaningless, so callers answering queries must
    filter empty selections first (``HistogramStore.query_many``
    (strict=False) returns the documented ``(None, inf)`` placeholder
    instead of dispatching them).
    """
    k_max = max((len(r) for r in rows), default=0)
    if k_max == 0:
        raise ValueError("pack_node_rows: every node row is empty")
    k_pad = next_pow2(k_max)
    if T_pad is None:
        T_pad = max(nd.num_buckets for r in rows for nd in r)
    Q = len(rows)
    bounds = np.zeros((Q, k_pad, T_pad + 1), np.float32)
    sizes = np.zeros((Q, k_pad, T_pad), np.float32)
    entries = [
        ((qi, ki), nd) for qi, r in enumerate(rows) for ki, nd in enumerate(r)
    ]
    _scatter_rows(bounds, sizes, entries, T_pad)
    for qi, r in enumerate(rows):
        if r and len(r) < k_pad:
            # zero-mass pad rows built from this query's last real row
            # (already padded to T_pad in the block)
            last = bounds[qi, len(r) - 1]
            bounds[qi, len(r) :] = last if pad_row_copy else last[-1]
    return bounds, sizes


def pack_device_rows(rows: Sequence[Sequence[TreeNode]]):
    """Zero-host-copy merge-stack pack: one device gather over a shared
    arena plane.

    Requires every selected node to live in the same plane of the same
    arena (true for any uniform-``T_node`` registry with a shared arena —
    the default configuration); returns ``None`` otherwise so the caller
    falls back to the host pack.  The produced block is bit-identical to
    ``pack_node_rows(rows, T_pad=width, pad_row_copy=True)``: same rows,
    same zero-mass pad rows, assembled device-side from the plane's
    resident snapshot instead of copied row by row on the host.

    The caller must keep holding the node handles until the merge output is
    materialized — that reference is what pins the rows against concurrent
    eviction + reuse (arena module docstring).
    """
    import jax.numpy as jnp

    first: TreeNode | None = None
    k_max = 0
    for r in rows:
        if len(r) > k_max:
            k_max = len(r)
        for nd in r:
            if first is None:
                first = nd
            elif nd.arena is not first.arena or nd.width != first.width:
                return None
    if first is None:
        raise ValueError("pack_device_rows: every node row is empty")
    k_pad = next_pow2(k_max)
    Q = len(rows)
    idx = np.zeros((Q, k_pad), np.int32)
    mask = np.zeros((Q, k_pad), np.float32)
    for qi, r in enumerate(rows):
        k = len(r)
        if k:
            idx[qi, :k] = [nd.row for nd in r]
            idx[qi, k:] = r[-1].row
            mask[qi, :k] = 1.0
    pool_b, pool_s = first.arena.device(first.width)
    return _gather_rows(pool_b, pool_s, jnp.asarray(idx), jnp.asarray(mask))


def selection_eps(sel: Sequence[TreeNode]) -> float:
    """Composed ``ε_total`` of merging the canonical nodes ``sel`` (module
    docstring): accumulated per-node bounds + one more Theorem-1 level.
    One fused pass — this runs per query on the serving path."""
    n = 0.0
    eps = 0.0
    T_in = sel[0].T
    for nd in sel:
        n += nd.n
        eps += nd.eps
        if nd.T < T_in:
            T_in = nd.T
    return float(eps + 2.0 * n / T_in + 2.0 * len(sel))


def _merge_pairs_multi(
    entries: Sequence[tuple["IntervalTree", int, Sequence[int]]]
) -> None:
    """Merge sibling pairs across one or many trees with one batched
    dispatch per output resolution, writing the parent nodes (with their
    composed-ε bookkeeping) straight into the trees' arenas.

    ``entries`` holds ``(tree, level, pair_indices)`` jobs; same-config
    trees at the same level share an output resolution, so a whole drained
    cross-tenant ingest batch costs **one merge dispatch per level** — not
    one per tenant per level.  Node summaries are a pure function of the
    child summaries, so batch composition cannot change a single output
    bit (the determinism fact the retention tests pin).
    """
    jobs: dict[int, list] = {}
    for tree, level, pairs in entries:
        T_out = tree.node_T(level)
        for i in pairs:
            c0 = tree.nodes[(level - 1, 2 * i)]
            c1 = tree.nodes[(level - 1, 2 * i + 1)]
            jobs.setdefault(T_out, []).append((tree, level, i, c0, c1))
    for T_out, work in jobs.items():
        Q = len(work)
        Q_pad = next_pow2(Q)
        T_in = max(
            max(c0.num_buckets, c1.num_buckets) for _, _, _, c0, c1 in work
        )
        bs = np.zeros((Q_pad, 2, T_in + 1), np.float32)
        ss = np.zeros((Q_pad, 2, T_in), np.float32)
        scatter = []
        for q, (_, _, _, c0, c1) in enumerate(work):
            scatter.append(((q, 0), c0))
            scatter.append(((q, 1), c1))
        for q in range(Q, Q_pad):  # pad the batch with the last real pair
            scatter.append(((q, 0), work[-1][3]))
            scatter.append(((q, 1), work[-1][4]))
        _scatter_rows(bs, ss, scatter, T_in)
        with _COUNTER_LOCK:
            PULLUP_STATS["dispatches"] += 1
            PULLUP_STATS["pair_merges"] += Q
        bo, so = merge_stacks(bs, ss, T_out)
        bo, so = np.asarray(bo), np.asarray(so)
        # write merge outputs straight into arena rows: one block alloc per
        # destination arena (a shared arena takes one for ALL tenants)
        by_arena: dict[int, list[int]] = {}
        for q, (tree, _, _, _, _) in enumerate(work):
            by_arena.setdefault(id(tree.arena), []).append(q)
        for qs in by_arena.values():
            arena = work[qs[0]][0].arena
            rows = arena.alloc_block(T_out, bo[qs], so[qs])
            for q, row in zip(qs, rows):
                tree, level, i, c0, c1 = work[q]
                n = c0.n + c1.n
                t_in = min(c0.num_buckets, c1.num_buckets)
                tree.nodes[(level, i)] = TreeNode(
                    arena,
                    T_out,
                    row,
                    T_out,
                    n,
                    c0.eps + c1.eps + 2.0 * n / t_in + 4.0,
                    c0.leaves + c1.leaves,
                )


def pull_up_trees(work: Sequence[tuple["IntervalTree", set[int]]]) -> None:
    """Refresh the ancestor paths of dirty leaf slots across one or many
    trees, level by level, batching every tree's pair merges at a level
    into one dispatch (:func:`_merge_pairs_multi`).

    The single-tree case is :meth:`IntervalTree._pull_up_many`; the
    multi-tree case is the registry's cross-tenant batched apply (all
    touched stores' locks held by the caller).  Does NOT bump versions —
    callers invalidate once per batch.
    """
    states = [[tree, set(dirty)] for tree, dirty in work if dirty]
    if not states:
        return
    for level in range(1, max(tree.levels for tree, _ in states) + 1):
        entries = []
        for state in states:
            tree, parents = state
            if level > tree.levels:
                continue
            parents = {s >> 1 for s in parents}
            state[1] = parents
            pairs = [
                i
                for i in sorted(parents)
                if (level - 1, 2 * i) in tree.nodes
                and (level - 1, 2 * i + 1) in tree.nodes
            ]
            pair_set = set(pairs)
            for i in sorted(parents):
                if i not in pair_set:
                    tree._update(level, i)
            if pairs:
                entries.append((tree, level, pairs))
        if entries:
            _merge_pairs_multi(entries)


class IntervalTree:
    """Power-of-two segment tree of pre-merged partition summaries."""

    def __init__(
        self,
        T_node: int,
        cache_size: int = 128,
        *,
        geometric: bool = False,
        arena: NodeArena | None = None,
        collapse: str = "canonical",
    ):
        if T_node < 1:
            raise ValueError("T_node must be >= 1")
        if collapse not in COLLAPSE_MODES:
            raise ValueError(
                f"unknown collapse mode: {collapse!r} (use one of "
                f"{COLLAPSE_MODES})"
            )
        self.T_node = int(T_node)
        self.geometric = bool(geometric)
        # pooled node storage: own arena by default, or a registry-shared
        # one (core/arena.py) so same-config trees pack with one gather
        self.arena = arena if arena is not None else NodeArena()
        # eviction collapse policy: "canonical" keeps the post-eviction
        # tree bit-identical to a fresh build over the survivors (O(W)
        # merge work per window slide); "amortized" defers the re-root
        # until the dead slot prefix exceeds half the capacity — O(log W)
        # amortized merge work per ingest, answers still within eps_total
        # but no longer bit-equal to a fresh rebuild (see _collapse)
        self.collapse_mode = collapse
        self.levels = 0  # capacity = 2**levels leaf slots
        self.base: int | None = None  # partition id of slot 0
        self.nodes: dict[tuple[int, int], TreeNode] = {}
        self.version = 0
        self.cache_hits = 0
        self.cache_misses = 0
        # query-path merge dispatch observability (summarize_shapes-style):
        # every cache-missing query batch adds one dispatch + its shape
        self.merge_dispatches = 0
        self.merge_shapes: set[tuple[int, int, int, int]] = set()
        self._cache: OrderedDict[tuple, tuple[Histogram, float]] = (
            OrderedDict()
        )
        self._cache_size = int(cache_size)

    # ------------------------------------------------------------ structure
    @property
    def capacity(self) -> int:
        return 1 << self.levels

    def node_T(self, level: int) -> int:
        """Merge-output resolution of a level-``level`` node: uniform
        ``T_node``, or ``T_node·2^level`` in geometric mode."""
        return self.T_node << level if self.geometric else self.T_node

    def num_leaves(self) -> int:
        return sum(1 for (lvl, _) in self.nodes if lvl == 0)

    def node_floats(self) -> int:
        """Total logical floats held by node summaries, counting shared
        rows once.

        Single-child internal nodes *share* their child's arena row, so
        the footprint is deduplicated by row identity — this is the
        store's memory figure that
        :class:`~repro.core.retention.MemoryBudget` and the registry's
        cross-tenant budget act on (logical, un-padded widths, so budget
        calibrations are layout-independent; the *resident* pool size is
        ``arena.allocated_floats()``/``capacity_floats()``).
        """
        seen: set[tuple[int, int]] = set()
        total = 0
        for nd in self.nodes.values():
            key = (nd.width, nd.row)
            if key in seen:
                continue
            seen.add(key)
            total += 2 * nd.T + 1
        return total

    def _invalidate(self) -> None:
        self.version += 1
        self._cache.clear()

    # ---------------------------------------------------------- maintenance
    def _new_leaf(
        self, b: np.ndarray, s: np.ndarray, src: tuple | None = None
    ) -> TreeNode:
        """Copy one leaf summary into the arena (plane = its own logical
        width) and return its handle, remembering the source arrays for
        the store's pointer-identity staleness scan.  ``src`` carries a
        pre-existing identity token through rebuilds — losing it would
        make the first post-rebuild query mark every leaf stale and
        rebuild the whole tree a second time."""
        T = s.shape[-1]
        row = self.arena.alloc(T, b, s)
        return TreeNode(
            self.arena,
            T,
            row,
            T,
            float(s.sum()),
            0.0,
            1,
            src=src if src is not None else (b, s),
        )

    def set_leaf(self, partition_id: int, boundaries, sizes) -> None:
        """Insert/replace one leaf and refresh its ``O(log W)`` ancestors."""
        self.set_leaves({int(partition_id): (boundaries, sizes)})

    def set_leaves(
        self, leaves: dict[int, tuple[np.ndarray, np.ndarray]]
    ) -> None:
        """Insert/replace a batch of leaves with one level-batched pull-up.

        The ancestor paths of all ``k`` leaves are deduplicated per level and
        each level's pair merges go through a single vmapped jitted merge —
        ``O(log W)`` XLA dispatches per batch instead of per leaf.  This is
        the per-flush maintenance path of the async Summarizer; a single
        mutation (``set_leaf``) is the ``k = 1`` case.  Cache invalidation
        (and the version bump) happens once per batch.
        """
        if not leaves:
            return
        dirty = self._write_leaves(leaves)
        if dirty is None:  # base-shift path rebuilt (and invalidated)
            return
        self._pull_up_many(dirty)
        self._invalidate()

    def _write_leaves(
        self, leaves: dict[int, tuple[np.ndarray, np.ndarray]]
    ) -> set[int] | None:
        """Write leaf rows + grow capacity; return the dirty slot set for
        the caller's pull-up (the registry batches pull-ups across trees),
        or ``None`` when a below-base id forced a full rebuild here."""
        pids = sorted(int(p) for p in leaves)
        if self.base is None:
            self.base = pids[0]
        if pids[0] < self.base:
            # a partition id below base arrived: shift every slot (rare);
            # surviving leaves keep their src identity through the rebuild
            merged = {
                self.base + slot: (nd.boundaries, nd.sizes, nd.src)
                for (lvl, slot), nd in self.nodes.items()
                if lvl == 0
            }
            merged.update({int(p): v for p, v in leaves.items()})
            self.rebuild(merged)
            return None
        grew = False
        while pids[-1] - self.base >= self.capacity:
            self.levels += 1
            grew = True
        dirty: set[int] = set()
        for pid in pids:
            slot = pid - self.base
            b = np.asarray(leaves[pid][0], np.float32)
            s = np.asarray(leaves[pid][1], np.float32)
            self.nodes[(0, slot)] = self._new_leaf(b, s)
            dirty.add(slot)
        if grew:
            # growth re-roots: the old root gains new ancestors on slot 0's
            # path (which the dirty-slot paths only share from some level up)
            dirty.add(0)
        return dirty

    def adopt_leaf_arrays(self, partition_id: int, boundaries, sizes) -> bool:
        """Re-point a leaf's staleness token at equal-valued external arrays
        without recompute.

        Used after :meth:`from_state` so tree leaves are identity-linked to
        the caller's summary rows — the pointer-identity staleness checks
        then pass without re-merging anything.  Returns False (no-op) when
        the leaf is absent or the arrays don't match the stored values.
        """
        if self.base is None:
            return False
        key = (0, int(partition_id) - self.base)
        nd = self.nodes.get(key)
        if (
            nd is None
            or not isinstance(boundaries, np.ndarray)
            or not isinstance(sizes, np.ndarray)
            or boundaries.dtype != nd.boundaries.dtype
            or not np.array_equal(boundaries, nd.boundaries)
            or not np.array_equal(sizes, nd.sizes)
        ):
            return False
        nd.src = (boundaries, sizes)
        return True

    def _pull_up_many(self, dirty: set[int]) -> None:
        """Refresh the deduplicated ancestor paths of the given leaf slots,
        level by level, batching each level's pair merges into one vmapped
        jitted dispatch (padded to a power-of-two batch for a bounded
        jit-cache footprint)."""
        pull_up_trees([(self, dirty)])

    def _update(self, level: int, idx: int) -> None:
        c0 = self.nodes.get((level - 1, 2 * idx))
        c1 = self.nodes.get((level - 1, 2 * idx + 1))
        key = (level, idx)
        if c0 is None and c1 is None:
            self.nodes.pop(key, None)
        elif c0 is None or c1 is None:
            # single child: share its summary (same handle, same arena
            # row) — no merge, no added error
            self.nodes[key] = c0 if c1 is None else c1
        else:
            self._merge_level(level, [idx])

    def _merge_level(self, level: int, pairs: Sequence[int]) -> None:
        """Merge the sibling pairs under ``(level, i) for i in pairs`` with a
        single batched dispatch — the one-tree case of
        :func:`_merge_pairs_multi`."""
        _merge_pairs_multi([(self, level, pairs)])

    def evict_leaves(self, partition_ids) -> int:
        """Remove leaf summaries — :meth:`set_leaf`'s pull-up in reverse.

        The evicted slots' ancestor paths are refreshed with the same
        level-batched machinery as ingest (``_pull_up_many``: a parent left
        with both children re-merges in the level batch, one child shares
        its summary, none frees its row), then the tree **lazily
        collapses**: fully-evicted leading subtrees are dropped in one pass
        so the root re-anchors at the lowest surviving leaf (see
        :meth:`_collapse`).  One version bump per batch — every LRU-cached
        answer keyed on the old version can never serve evicted data.
        Dropped rows return to the arena free list as soon as their last
        handle dies (never while an in-flight pack still holds one).

        Returns the number of leaves actually removed (absent ids are
        ignored, so a policy may re-list already-evicted partitions).
        """
        if self.base is None:
            return 0
        dirty: set[int] = set()
        for pid in partition_ids:
            slot = int(pid) - self.base
            if (0, slot) in self.nodes:
                del self.nodes[(0, slot)]
                dirty.add(slot)
        if not dirty:
            return 0
        self._collapse(dirty)
        self._invalidate()
        return len(dirty)

    def _collapse(self, dirty: set[int]) -> None:
        """Lazy subtree collapse: re-root the tree at the smallest subtree
        whose slot range starts at the lowest surviving leaf.

        Eviction from an infinite stream always removes a *prefix* of the
        partition axis, so without collapse ``slot = pid - base`` (and with
        it tree depth and, in geometric mode, per-node resolution) would
        grow without bound.  Two paths, both batched per eviction sweep
        rather than per leaf:

        * **aligned rename** — when the survivors fit an aligned subtree
          ``(L, j)`` starting exactly at the lowest surviving slot, that
          subtree becomes the root by re-keying its nodes (zero merges;
          the single-child chain above it is dropped, freeing rows whose
          storage was shared anyway);
        * **rebase-rebuild** — when the survivors straddle an alignment
          boundary, they are re-based to slot 0 with one level-batched
          :meth:`rebuild`.  Under geometric ``T_node`` this is what
          *re-coarsens* the surviving ancestors: pair merges now happen at
          the shallow tree's levels, with resolution ``T·2^l`` for the new
          small ``l`` instead of the deep tree's.

        Either way the post-collapse tree is **bit-identical to a fresh
        build over the surviving leaves** (same base, minimal depth, and
        node summaries are a deterministic function of the slot→leaf map),
        which is what keeps post-eviction queries bit-exact vs a flat
        rebuild of the retained window (tests/test_retention_props.py).

        Cost, stated plainly: that bit-equality contract is what forces
        the rebuild path in the sliding-window steady state.  A window
        sliding by one shifts every slot by one, which re-pairs *every*
        level — a fresh build after the shift shares no internal node
        with the old tree — so any implementation honouring the contract
        re-merges O(window) pairs per slide.  The level batching keeps it
        at O(log W) *dispatches* (the dominant cost in the serving
        regime, per-dispatch overhead being ~50-70 µs against tiny
        per-pair merges).

        **Amortized mode** (``collapse="amortized"``): the re-root is
        deferred while the dead slot prefix is smaller than half the
        capacity — eviction then costs only the reverse pull-up of the
        evicted paths (O(log W) merges), and the O(W) re-root runs once
        per ~W/2 evictions, i.e. O(log W) *amortized* merge work per
        ingest for a high-frequency sliding window.  The trade, stated in
        the retention contract's terms: between re-roots the tree is
        deeper than a fresh build over the survivors (up to one extra
        level, plus the uncollapsed dead prefix), so answers are NOT
        bit-equal to a fresh rebuild — they remain exactly correct
        per-node merges whose reported ``eps_total`` still dominates the
        measured error (property-tested), just composed over a slightly
        deeper selection.
        """
        slots = sorted(s for (lvl, s) in self.nodes if lvl == 0)
        if not slots:
            self.nodes.clear()
            self.base = None
            self.levels = 0
            return
        lo, hi = slots[0], slots[-1]
        if self.collapse_mode == "amortized" and lo < (self.capacity >> 1):
            # dead prefix still below the slack threshold: defer the
            # re-root, just refresh the evicted slots' ancestor paths
            self._pull_up_many(dirty)
            return
        L = self.levels
        while L > 0 and (lo >> (L - 1)) == (hi >> (L - 1)):
            L -= 1
        j = lo >> L
        if (j << L) == lo:
            # no collapse (already rooted at slot 0, minimal depth) or an
            # aligned rename: either way the surviving ancestors stay, so
            # refresh the evicted slots' paths (the reverse pull-up) first
            self._pull_up_many(dirty)
            if not (lo == 0 and L == self.levels):
                # subtree (L, j) becomes the root by re-keying, no merges
                self.nodes = {
                    (lvl, i - (j << (L - lvl))): nd
                    for (lvl, i), nd in self.nodes.items()
                    if lvl <= L
                }
                self.base += j << L
                self.levels = L
        else:
            # straddling survivors: one level-batched rebase-rebuild from
            # the (untouched) leaf rows — every ancestor is recomputed, so
            # the reverse pull-up would be wasted dispatches here.  The
            # leaves carry their src identity so the store's staleness
            # scan does not re-rebuild everything on the next query
            leaves = {
                self.base + s: (nd.boundaries, nd.sizes, nd.src)
                for (lvl, s), nd in self.nodes.items()
                if lvl == 0
            }
            self.base = None
            self.rebuild(leaves)

    def rebuild(self, leaves: dict[int, tuple]) -> None:
        """Bulk (re)build from ``{partition_id: (boundaries, sizes)}``
        (an optional third tuple element carries a leaf's existing ``src``
        identity token through the rebuild — the collapse/rebase paths
        use it so post-rebuild staleness scans still pass).

        Level-by-level: all sibling pairs of a level go through *one*
        vmapped jitted merge, so a ``W``-partition build costs ``log2 W``
        XLA dispatches instead of ``W·log2 W`` (the incremental path's
        cost when used for bulk loads).
        """
        # callers may pass views of the current nodes' rows (the collapse
        # rebase path does) — keep the old handles alive until the new
        # rows are written, so the arena cannot reuse their slots mid-copy
        old_nodes = self.nodes  # noqa: F841  (lifetime anchor)
        self.nodes = {}
        self._invalidate()
        if not leaves:
            self.base = None
            self.levels = 0
            return
        pids = sorted(leaves)
        if self.base is None or pids[0] < self.base:
            self.base = pids[0]
        span = pids[-1] - self.base + 1
        self.levels = (span - 1).bit_length() if span > 1 else 0
        for pid in pids:
            val = leaves[pid]
            b = np.asarray(val[0], np.float32)
            s = np.asarray(val[1], np.float32)
            src = val[2] if len(val) > 2 else None
            self.nodes[(0, pid - self.base)] = self._new_leaf(b, s, src)
        self._pull_up_many({pid - self.base for pid in pids})

    # -------------------------------------------------------------- queries
    def decompose(self, lo: int, hi: int) -> list[tuple[int, int]]:
        """Present canonical node keys covering partition ids ``lo..hi``."""
        if self.base is None:
            return []
        s_lo = max(int(lo) - self.base, 0)
        s_hi = min(int(hi) - self.base, self.capacity - 1)
        if s_hi < s_lo:
            return []
        return [
            k for k in canonical_decomposition(s_lo, s_hi) if k in self.nodes
        ]

    def _selected(self, lo: int, hi: int) -> list[TreeNode]:
        sel = [self.nodes[k] for k in self.decompose(lo, hi)]
        if not sel:
            raise KeyError("no partition summaries in requested interval")
        return sel

    def _cache_get(self, key: tuple) -> tuple[Histogram, float] | None:
        """LRU lookup; counts (and refreshes) a hit, leaves misses to the
        caller — shared by query/query_many and the cross-tenant registry."""
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.cache_hits += 1
        return hit

    def _cache_put(self, key: tuple, out: tuple[Histogram, float]) -> None:
        self._cache[key] = out
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)

    def _dispatch(
        self, rows: Sequence[Sequence[TreeNode]], beta: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """One counted merge dispatch over packed node rows.

        Returns host arrays: one device→host transfer for the whole batch
        beats ``Q`` lazy per-row jax slices by orders of magnitude when
        answers are unpacked row by row.
        """
        bounds, sizes = pack_node_rows(rows)
        self.merge_dispatches += 1
        self.merge_shapes.add(bounds.shape + (int(beta),))
        bo, so = merge_stacks(bounds, sizes, int(beta))
        return np.asarray(bo), np.asarray(so)

    def query(self, lo: int, hi: int, beta: int) -> tuple[Histogram, float]:
        """β-bucket histogram over ``lo..hi`` plus its composed ``ε_total``.

        Merges only the ``≤ 2·log2 W`` canonical node summaries; answers are
        LRU-cached until the next mutation.
        """
        key = (int(lo), int(hi), int(beta), self.version)
        hit = self._cache_get(key)
        if hit is not None:
            return hit
        self.cache_misses += 1
        sel = self._selected(lo, hi)
        bo, so = self._dispatch([sel], beta)
        out = (Histogram(bo[0], so[0]), selection_eps(sel))
        self._cache_put(key, out)
        return out

    def query_many(
        self, intervals: Sequence[tuple[int, int]], beta: int
    ) -> list[tuple[Histogram, float]]:
        """Answer many interval queries with at most one jitted merge.

        The LRU answer cache is consulted *per interval* first (a repeated
        dashboard batch costs zero dispatches and counts its hits exactly
        like :meth:`query`); only the misses — deduplicated, so the same
        window twice in one batch merges once — are padded to a single
        static ``(k_pad, T_pad)`` shape and served by one XLA program
        regardless of the mix of window lengths, then cached for the next
        batch.
        """
        if not intervals:
            return []
        keys = [
            (int(lo), int(hi), int(beta), self.version)
            for lo, hi in intervals
        ]
        answers: dict[tuple, tuple[Histogram, float]] = {}
        miss_keys: list[tuple] = []
        pending: set[tuple] = set()  # dedups repeated misses in this batch
        for key in keys:
            if key in answers or key in pending:
                continue
            hit = self._cache_get(key)
            if hit is not None:
                answers[key] = hit
            else:
                self.cache_misses += 1
                pending.add(key)
                miss_keys.append(key)
        if miss_keys:
            sels = [self._selected(k[0], k[1]) for k in miss_keys]
            bo, so = self._dispatch(sels, beta)
            for i, (key, sel) in enumerate(zip(miss_keys, sels)):
                out = (Histogram(bo[i], so[i]), selection_eps(sel))
                answers[key] = out
                self._cache_put(key, out)
        return [answers[key] for key in keys]

    # ---------------------------------------------------------- persistence
    def state(
        self, slot_map: dict[tuple[int, int], int] | None = None
    ) -> tuple[dict, dict[str, np.ndarray]]:
        """(json-able meta, arrays) for npz persistence of the tree nodes.

        The arena layout persists the *pools*, compacted: ``ab_{width}`` /
        ``as_{width}`` blocks holding only the live (referenced) rows, with
        per-node ``[lvl, idx, n, eps, leaves, T, width, slot]`` records
        pointing into them — free-list fragmentation never reaches disk,
        and shared rows are written once.  With ``slot_map`` given (the
        registry's shared-arena save), the caller already exported the
        pools for *all* tenants at once and this tree emits only its node
        records against that map.
        """
        own_export = slot_map is None
        arrays: dict[str, np.ndarray] = {}
        if own_export:
            arrays, slot_map = self.arena.export(
                (nd.width, nd.row) for nd in self.nodes.values()
            )
        meta = {
            "T_node": self.T_node,
            "geometric": self.geometric,
            "layout": "arena/v1",
            "shared_pool": not own_export,
            "base": self.base,
            "levels": self.levels,
            "nodes": [
                [
                    lvl,
                    idx,
                    nd.n,
                    nd.eps,
                    nd.leaves,
                    nd.T,
                    nd.width,
                    slot_map[(nd.width, nd.row)],
                ]
                for (lvl, idx), nd in sorted(self.nodes.items())
            ],
        }
        return meta, arrays

    @classmethod
    def from_state(
        cls,
        meta: dict,
        arrays,
        cache_size: int = 128,
        *,
        arena: NodeArena | None = None,
        collapse: str = "canonical",
    ):
        tree = cls(
            int(meta["T_node"]),
            cache_size=cache_size,
            geometric=bool(meta.get("geometric", False)),
            arena=arena,
            collapse=collapse,
        )
        tree.base = None if meta["base"] is None else int(meta["base"])
        tree.levels = int(meta["levels"])
        if meta.get("layout") != "arena/v1":
            # pre-arena summary files: one tb_/ts_ array pair per node
            for lvl, idx, n, eps, leaves in meta["nodes"]:
                lvl, idx = int(lvl), int(idx)
                b = np.asarray(arrays[f"tb_{lvl}_{idx}"], np.float32)
                s = np.asarray(arrays[f"ts_{lvl}_{idx}"], np.float32)
                T = s.shape[-1]
                row = tree.arena.alloc(T, b, s)
                tree.nodes[(lvl, idx)] = TreeNode(
                    tree.arena, T, row, T, float(n), float(eps), int(leaves)
                )
            return tree
        pools: dict[int, tuple[np.ndarray, np.ndarray]] = {}
        handles: dict[tuple[int, int], TreeNode] = {}
        for lvl, idx, n, eps, leaves, T, width, slot in meta["nodes"]:
            lvl, idx, T, width, slot = (
                int(lvl),
                int(idx),
                int(T),
                int(width),
                int(slot),
            )
            nd = handles.get((width, slot))
            if nd is None:
                if width not in pools:
                    pools[width] = (
                        np.asarray(arrays[f"ab_{width}"], np.float32),
                        np.asarray(arrays[f"as_{width}"], np.float32),
                    )
                pb, ps = pools[width]
                # exported rows are width-padded; alloc re-pads the logical
                # prefix identically, so the live row is bit-identical
                row = tree.arena.alloc(width, pb[slot, : T + 1], ps[slot, :T])
                nd = TreeNode(
                    tree.arena,
                    width,
                    row,
                    T,
                    float(n),
                    float(eps),
                    int(leaves),
                )
                handles[(width, slot)] = nd
            tree.nodes[(lvl, idx)] = nd
        return tree
