"""Segment-tree interval engine over stored partition summaries.

Why a tree
----------
The paper's Merger answers "equi-depth histogram over partitions lo..hi" by
merging the stored per-partition ``T``-bucket summaries.  Done flat, every
query re-merges the whole window: ``O(W)`` summaries sorted per query, and a
fresh XLA compile for every distinct window length ``k`` (the ``(k, T+1)``
merge shape is static).  This module maintains a power-of-two **segment
tree** over the partition axis instead:

    level 0   the stored leaf summaries (exact, ``T`` buckets)
    level l   one pre-merged ``T_node``-bucket summary per aligned pair of
              level-(l-1) nodes, i.e. node ``(l, i)`` summarizes partition
              slots ``[i·2^l, (i+1)·2^l)``

so any interval ``[lo, hi]`` decomposes into at most ``2·log2(W)`` canonical
nodes (the classic bottom-up cover), and a query merges only those:
``O(log W)`` summaries per query instead of ``O(W)``.  Node maintenance on
ingest is ``O(log W)`` pairwise merges; bulk (re)builds batch each level into
a single vmapped jitted merge.

Composed error bound (paper Theorem 1, applied per level)
---------------------------------------------------------
Theorem 1: merging ``k`` *exact* ``T``-bucket histograms of ``N`` total
values yields every bucket (and, Theorem 2, every contiguous bucket range)
within ``ε < 2N/T`` of ideal; integer-rounded inputs (``T ∤ |P_i|``) add a
``+2k`` slack.  The theorem composes recursively — the same fact the tile →
device → pod hierarchy exploits in ``core/distributed.py``: if the ``k``
inputs are themselves approximate with summary errors ``ε_i``, the output
error is bounded by

    ε_out  ≤  Σ_i ε_i  +  2N/T_in  +  2k                       (composition)

because the merge is exact w.r.t. the *claimed* input masses (±2N/T_in + 2k)
and the claims are off by at most Σ ε_i.  Each tree node therefore carries
its own accumulated bound: leaves have ``ε = 0``; an internal node built
from children with resolutions ``≥ T_in`` has

    ε_node = ε_left + ε_right + 2·n_node/T_in + 4 .

A query that merges canonical nodes {v} into β buckets reports

    ε_total = Σ_v ε_v + 2N/min_v T_v + 2·|{v}|
            < 2N · Σ_level 1/T_level  (+ integer slack),

the ``ε_total < 2N·Σ_level 1/T_level`` form of the module header, with
``T_level = T`` uniform giving ``ε_total < 2N·(1 + ⌈log2 W⌉)/T``.

**Geometric per-level resolution** (``geometric=True``): node resolution
doubles per level — a level-``l`` node holds ``T_node·2^l`` buckets — so the
per-level error terms form a geometric series and the composed bound
converges to ``ε_total < 4N/T_leaf`` *independent of depth*, at ``O(log W)``
extra memory per leaf (every level stores ``W·T`` bucket floats in total
instead of the uniform mode's ``W·T/2^l``).  Because a level-``l`` pair
merge emits exactly as many buckets as its two children jointly carry
boundaries, geometric nodes lose no resolution on the way up — the only
per-level error is the left-collapse term ``2n/T_in`` of the level below.
Exposed as ``HistogramStore(T_node="geometric")``.

What is (and is not) bit-exact
------------------------------
The paper's merge is *lossy* (left-collapse repositions mass), so a
pre-merged internal node cannot reproduce the flat merge of its leaves
bit-for-bit — that is exactly why ε composes per level instead of being flat
``2N/T``.  What *is* bit-exact, proven below and asserted by
``tests/test_interval_tree.py``:

  * ``query`` ≡ ``merge_list`` over the selected canonical node summaries;
  * ``query_many`` (which pads every query's node set to one static
    ``(k_pad, T_pad)`` shape so a single jitted merge serves the whole
    batch) ≡ per-query ``query``;
  * intervals whose canonical cover is all leaves (single partition, or any
    two-partition span crossing a pair boundary) ≡ the flat
    ``merge_list`` over the raw leaf summaries.

Padding invariance: inserting a zero-mass boundary at any value ``v`` inside
``[min, max]`` of the pre-histogram leaves every output bit unchanged.  With
the inserted element at sorted position ``p``, the cumulative array ``A``
gains a duplicate of ``A[p-1]``; for each cut target ``t_j``, either
``A[p-1] ≤ t_j`` (then ``cut_j`` shifts by exactly the one inserted slot and
``pos[cut_j]`` is unchanged) or ``A[p-1] > t_j`` (then ``cut_j`` indexes the
untouched prefix).  First/last output boundaries are the global min/max,
which zero-mass interior padding cannot displace.  Hence both the per-node
``T`` padding and the per-query ``k`` padding (rows of zero-mass duplicates
of a real boundary) are bit-exact, and the engine can pad node sets to the
next power of two for a bounded jit-cache footprint.

Caching
-------
Answers are memoized in an LRU keyed ``(lo, hi, beta, version)`` where
``version`` bumps on every mutation — the hot dashboards-asking-the-same-
window path (millions of users, few distinct windows) is served from host
memory without touching XLA at all.
"""
from __future__ import annotations

import functools
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Sequence

import jax
import numpy as np

from repro.core.histogram import Histogram, merge, next_pow2

__all__ = [
    "TreeNode",
    "IntervalTree",
    "canonical_decomposition",
    "merge_stacks",
    "pack_node_rows",
    "selection_eps",
]


@dataclass(frozen=True)
class TreeNode:
    """One tree node: a T-bucket summary plus its error-bound bookkeeping."""

    boundaries: np.ndarray  # (T+1,) increasing
    sizes: np.ndarray  # (T,)
    n: float  # total summarized mass
    eps: float  # accumulated Theorem-1 bound of this summary
    leaves: int  # number of present leaf partitions beneath

    @property
    def num_buckets(self) -> int:
        return self.sizes.shape[-1]

    def to_histogram(self) -> Histogram:
        import jax.numpy as jnp

        return Histogram(
            boundaries=jnp.asarray(self.boundaries),
            sizes=jnp.asarray(self.sizes),
        )


def canonical_decomposition(lo: int, hi: int) -> list[tuple[int, int]]:
    """Canonical segment-tree cover of leaf slots ``[lo, hi]`` (inclusive).

    Returns ``(level, index)`` keys, left-to-right, where node ``(l, i)``
    covers slots ``[i·2^l, (i+1)·2^l)``.  At most two nodes per level →
    ``≤ 2·⌈log2(hi-lo+1)⌉ + 1`` nodes total.
    """
    left: list[tuple[int, int]] = []
    right: list[tuple[int, int]] = []
    l, r = lo, hi + 1  # half-open
    level = 0
    while l < r:
        if l & 1:
            left.append((level, l))
            l += 1
        if r & 1:
            r -= 1
            right.append((level, r))
        l >>= 1
        r >>= 1
        level += 1
    return left + right[::-1]


@functools.partial(jax.jit, static_argnames=("beta",))
def merge_stacks(bounds: jax.Array, sizes: jax.Array, beta: int):
    """Batched merge: ``(Q, k, T+1)``/``(Q, k, T)`` → ``(Q, β+1)``/``(Q, β)``.

    One compile per static ``(Q, k, T, β)``; ``query`` pads ``k`` to a power
    of two and ``query_many`` pads a whole batch to one shape, so the cache
    stays small under production traffic.  Shared by every batched Merger
    path: the tree's own queries, its level maintenance, and the
    cross-tenant ``TenantRegistry.query_many`` (core/tenant.py), which
    stacks canonical node sets from *different* trees into one block.
    """
    return jax.vmap(lambda b, s: merge(Histogram(b, s), beta))(bounds, sizes)


def _pad_summary(
    b: np.ndarray, s: np.ndarray, T: int
) -> tuple[np.ndarray, np.ndarray]:
    """Pad a summary to ``T`` buckets with zero-mass copies of its last
    boundary — the (bit-exact, see module docstring) merge_list padding."""
    pad = T - s.shape[-1]
    if pad == 0:
        return b, s
    return (
        np.concatenate([b, np.repeat(b[-1:], pad)]),
        np.concatenate([s, np.zeros((pad,), s.dtype)]),
    )


def pack_node_rows(
    rows: Sequence[Sequence[TreeNode]],
) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-query node sets into one ``(Q, k_pad, T_pad)`` block.

    ``k`` pads to the next power of two with rows of zero-mass copies of a
    real boundary; ``T`` pads merge_list-style.  Both are bit-exact (module
    docstring).  Rows may come from *different* trees (the cross-tenant
    registry path) — only the summary arrays matter.  An empty row packs to
    an all-zero-mass constant row: its merge output is well-defined but
    meaningless, so callers answering queries must filter empty selections
    first (``HistogramStore.query_many(strict=False)`` returns the
    documented ``(None, inf)`` placeholder instead of dispatching them).
    """
    k_max = max((len(r) for r in rows), default=0)
    if k_max == 0:
        raise ValueError("pack_node_rows: every node row is empty")
    k_pad = next_pow2(k_max)
    T_pad = max(nd.num_buckets for r in rows for nd in r)
    Q = len(rows)
    bounds = np.zeros((Q, k_pad, T_pad + 1), np.float32)
    sizes = np.zeros((Q, k_pad, T_pad), np.float32)
    for qi, r in enumerate(rows):
        for ki, nd in enumerate(r):
            b, s = _pad_summary(nd.boundaries, nd.sizes, T_pad)
            bounds[qi, ki] = b
            sizes[qi, ki] = s
        if r:  # zero-mass pad rows at a real boundary value of this query
            bounds[qi, len(r) :] = r[-1].boundaries[-1]
    return bounds, sizes


def selection_eps(sel: Sequence[TreeNode]) -> float:
    """Composed ``ε_total`` of merging the canonical nodes ``sel`` (module
    docstring): accumulated per-node bounds + one more Theorem-1 level."""
    n = sum(nd.n for nd in sel)
    T_in = min(nd.num_buckets for nd in sel)
    return float(
        sum(nd.eps for nd in sel) + 2.0 * n / T_in + 2.0 * len(sel)
    )


class IntervalTree:
    """Power-of-two segment tree of pre-merged partition summaries."""

    def __init__(
        self, T_node: int, cache_size: int = 128, *, geometric: bool = False
    ):
        if T_node < 1:
            raise ValueError("T_node must be >= 1")
        self.T_node = int(T_node)
        self.geometric = bool(geometric)
        self.levels = 0  # capacity = 2**levels leaf slots
        self.base: int | None = None  # partition id of slot 0
        self.nodes: dict[tuple[int, int], TreeNode] = {}
        self.version = 0
        self.cache_hits = 0
        self.cache_misses = 0
        # query-path merge dispatch observability (summarize_shapes-style):
        # every cache-missing query batch adds one dispatch + its shape
        self.merge_dispatches = 0
        self.merge_shapes: set[tuple[int, int, int, int]] = set()
        self._cache: OrderedDict[tuple, tuple[Histogram, float]] = (
            OrderedDict()
        )
        self._cache_size = int(cache_size)

    # ------------------------------------------------------------ structure
    @property
    def capacity(self) -> int:
        return 1 << self.levels

    def node_T(self, level: int) -> int:
        """Merge-output resolution of a level-``level`` node: uniform
        ``T_node``, or ``T_node·2^level`` in geometric mode."""
        return self.T_node << level if self.geometric else self.T_node

    def num_leaves(self) -> int:
        return sum(1 for (lvl, _) in self.nodes if lvl == 0)

    def node_floats(self) -> int:
        """Total floats held by node summaries, counting shared arrays once.

        Single-child internal nodes *share* their child's arrays (and tree
        leaves share the caller's stored-summary rows), so the footprint is
        deduplicated by array identity — this is the store's memory figure
        that :class:`~repro.core.retention.MemoryBudget` and the registry's
        cross-tenant budget act on.
        """
        seen: set[int] = set()
        total = 0
        for nd in self.nodes.values():
            key = id(nd.boundaries)
            if key in seen:
                continue
            seen.add(key)
            total += int(nd.boundaries.size) + int(nd.sizes.size)
        return total

    def _invalidate(self) -> None:
        self.version += 1
        self._cache.clear()

    # ---------------------------------------------------------- maintenance
    def set_leaf(self, partition_id: int, boundaries, sizes) -> None:
        """Insert/replace one leaf and refresh its ``O(log W)`` ancestors."""
        self.set_leaves({int(partition_id): (boundaries, sizes)})

    def set_leaves(
        self, leaves: dict[int, tuple[np.ndarray, np.ndarray]]
    ) -> None:
        """Insert/replace a batch of leaves with one level-batched pull-up.

        The ancestor paths of all ``k`` leaves are deduplicated per level and
        each level's pair merges go through a single vmapped jitted merge —
        ``O(log W)`` XLA dispatches per batch instead of per leaf.  This is
        the per-flush maintenance path of the async Summarizer; a single
        mutation (``set_leaf``) is the ``k = 1`` case.  Cache invalidation
        (and the version bump) happens once per batch.
        """
        if not leaves:
            return
        pids = sorted(int(p) for p in leaves)
        if self.base is None:
            self.base = pids[0]
        if pids[0] < self.base:
            # a partition id below base arrived: shift every slot (rare)
            merged = {
                self.base + slot: (nd.boundaries, nd.sizes)
                for (lvl, slot), nd in self.nodes.items()
                if lvl == 0
            }
            merged.update({int(p): v for p, v in leaves.items()})
            self.rebuild(merged)
            return
        grew = False
        while pids[-1] - self.base >= self.capacity:
            self.levels += 1
            grew = True
        dirty: set[int] = set()
        for pid in pids:
            slot = pid - self.base
            b = np.asarray(leaves[pid][0], np.float32)
            s = np.asarray(leaves[pid][1], np.float32)
            self.nodes[(0, slot)] = TreeNode(b, s, float(s.sum()), 0.0, 1)
            dirty.add(slot)
        if grew:
            # growth re-roots: the old root gains new ancestors on slot 0's
            # path (which the dirty-slot paths only share from some level up)
            dirty.add(0)
        self._pull_up_many(dirty)
        self._invalidate()

    def adopt_leaf_arrays(self, partition_id: int, boundaries, sizes) -> bool:
        """Re-point a leaf at equal-valued external arrays without recompute.

        Used after :meth:`from_state` so tree leaves share storage with the
        caller's summary rows — pointer-identity staleness checks then pass
        without re-merging anything.  Returns False (no-op) when the leaf is
        absent or the arrays don't match the stored values.
        """
        if self.base is None:
            return False
        key = (0, int(partition_id) - self.base)
        nd = self.nodes.get(key)
        if (
            nd is None
            or not isinstance(boundaries, np.ndarray)
            or not isinstance(sizes, np.ndarray)
            or boundaries.dtype != nd.boundaries.dtype
            or not np.array_equal(boundaries, nd.boundaries)
            or not np.array_equal(sizes, nd.sizes)
        ):
            return False
        self.nodes[key] = TreeNode(
            boundaries, sizes, nd.n, nd.eps, nd.leaves
        )
        return True

    def _pull_up_many(self, dirty: set[int]) -> None:
        """Refresh the deduplicated ancestor paths of the given leaf slots,
        level by level, batching each level's pair merges into one vmapped
        jitted dispatch (padded to a power-of-two batch for a bounded
        jit-cache footprint)."""
        parents = set(dirty)
        for level in range(1, self.levels + 1):
            parents = {s >> 1 for s in parents}
            pairs = [
                i
                for i in sorted(parents)
                if (level - 1, 2 * i) in self.nodes
                and (level - 1, 2 * i + 1) in self.nodes
            ]
            pair_set = set(pairs)
            for i in sorted(parents):
                if i not in pair_set:
                    self._update(level, i)
            if pairs:
                self._merge_level(level, pairs)

    def _update(self, level: int, idx: int) -> None:
        c0 = self.nodes.get((level - 1, 2 * idx))
        c1 = self.nodes.get((level - 1, 2 * idx + 1))
        key = (level, idx)
        if c0 is None and c1 is None:
            self.nodes.pop(key, None)
        elif c0 is None or c1 is None:
            # single child: share its summary — no merge, no added error
            self.nodes[key] = c0 if c1 is None else c1
        else:
            self._merge_level(level, [idx])

    def _merge_level(self, level: int, pairs: Sequence[int]) -> None:
        """Merge the sibling pairs under ``(level, i) for i in pairs`` with a
        single batched dispatch, writing the parent nodes (with their
        composed-ε bookkeeping)."""
        kids = [
            (self.nodes[(level - 1, 2 * i)], self.nodes[(level - 1, 2 * i + 1)])
            for i in pairs
        ]
        Q = len(kids)
        Q_pad = next_pow2(Q)
        padded_kids = list(kids) + [kids[-1]] * (Q_pad - Q)
        T_max = max(max(a.num_buckets, b.num_buckets) for a, b in kids)
        bs = np.stack(
            [
                np.stack(
                    [_pad_summary(c.boundaries, c.sizes, T_max)[0] for c in pair]
                )
                for pair in padded_kids
            ]
        )
        ss = np.stack(
            [
                np.stack(
                    [_pad_summary(c.boundaries, c.sizes, T_max)[1] for c in pair]
                )
                for pair in padded_kids
            ]
        )
        bo, so = merge_stacks(bs, ss, self.node_T(level))
        bo, so = np.asarray(bo), np.asarray(so)
        for row, i in enumerate(pairs):
            c0, c1 = kids[row]
            n = c0.n + c1.n
            T_in = min(c0.num_buckets, c1.num_buckets)
            self.nodes[(level, i)] = TreeNode(
                boundaries=bo[row],
                sizes=so[row],
                n=n,
                eps=c0.eps + c1.eps + 2.0 * n / T_in + 4.0,
                leaves=c0.leaves + c1.leaves,
            )

    def evict_leaves(self, partition_ids) -> int:
        """Remove leaf summaries — :meth:`set_leaf`'s pull-up in reverse.

        The evicted slots' ancestor paths are refreshed with the same
        level-batched machinery as ingest (``_pull_up_many``: a parent left
        with both children re-merges in the level batch, one child shares
        its summary, none frees its row), then the tree **lazily
        collapses**: fully-evicted leading subtrees are dropped in one pass
        so the root re-anchors at the lowest surviving leaf (see
        :meth:`_collapse`).  One version bump per batch — every LRU-cached
        answer keyed on the old version can never serve evicted data.

        Returns the number of leaves actually removed (absent ids are
        ignored, so a policy may re-list already-evicted partitions).
        """
        if self.base is None:
            return 0
        dirty: set[int] = set()
        for pid in partition_ids:
            slot = int(pid) - self.base
            if (0, slot) in self.nodes:
                del self.nodes[(0, slot)]
                dirty.add(slot)
        if not dirty:
            return 0
        self._collapse(dirty)
        self._invalidate()
        return len(dirty)

    def _collapse(self, dirty: set[int]) -> None:
        """Lazy subtree collapse: re-root the tree at the smallest subtree
        whose slot range starts at the lowest surviving leaf.

        Eviction from an infinite stream always removes a *prefix* of the
        partition axis, so without collapse ``slot = pid - base`` (and with
        it tree depth and, in geometric mode, per-node resolution) would
        grow without bound.  Two paths, both batched per eviction sweep
        rather than per leaf:

        * **aligned rename** — when the survivors fit an aligned subtree
          ``(L, j)`` starting exactly at the lowest surviving slot, that
          subtree becomes the root by re-keying its nodes (zero merges;
          the single-child chain above it is dropped, freeing rows whose
          arrays were shared anyway);
        * **rebase-rebuild** — when the survivors straddle an alignment
          boundary, they are re-based to slot 0 with one level-batched
          :meth:`rebuild`.  Under geometric ``T_node`` this is what
          *re-coarsens* the surviving ancestors: pair merges now happen at
          the shallow tree's levels, with resolution ``T·2^l`` for the new
          small ``l`` instead of the deep tree's.

        Either way the post-collapse tree is **bit-identical to a fresh
        build over the surviving leaves** (same base, minimal depth, and
        node summaries are a deterministic function of the slot→leaf map),
        which is what keeps post-eviction queries bit-exact vs a flat
        rebuild of the retained window (tests/test_retention_props.py).

        Cost, stated plainly: that bit-equality contract is what forces
        the rebuild path in the sliding-window steady state.  A window
        sliding by one shifts every slot by one, which re-pairs *every*
        level — a fresh build after the shift shares no internal node
        with the old tree — so any implementation honouring the contract
        re-merges O(window) pairs per slide.  The level batching keeps it
        at O(log W) *dispatches* (the dominant cost in the serving
        regime, per-dispatch overhead being ~50-70 µs against tiny
        per-pair merges); a future opt-in mode could defer collapse
        behind a dead-prefix slack for amortized O(log W) merge work at
        the price of rebuild bit-equality (see ROADMAP).
        """
        slots = sorted(s for (lvl, s) in self.nodes if lvl == 0)
        if not slots:
            self.nodes.clear()
            self.base = None
            self.levels = 0
            return
        lo, hi = slots[0], slots[-1]
        L = self.levels
        while L > 0 and (lo >> (L - 1)) == (hi >> (L - 1)):
            L -= 1
        j = lo >> L
        if (j << L) == lo:
            # no collapse (already rooted at slot 0, minimal depth) or an
            # aligned rename: either way the surviving ancestors stay, so
            # refresh the evicted slots' paths (the reverse pull-up) first
            self._pull_up_many(dirty)
            if not (lo == 0 and L == self.levels):
                # subtree (L, j) becomes the root by re-keying, no merges
                self.nodes = {
                    (lvl, i - (j << (L - lvl))): nd
                    for (lvl, i), nd in self.nodes.items()
                    if lvl <= L
                }
                self.base += j << L
                self.levels = L
        else:
            # straddling survivors: one level-batched rebase-rebuild from
            # the (untouched) leaf rows — every ancestor is recomputed, so
            # the reverse pull-up would be wasted dispatches here
            leaves = {
                self.base + s: (nd.boundaries, nd.sizes)
                for (lvl, s), nd in self.nodes.items()
                if lvl == 0
            }
            self.base = None
            self.rebuild(leaves)

    def rebuild(self, leaves: dict[int, tuple[np.ndarray, np.ndarray]]) -> None:
        """Bulk (re)build from ``{partition_id: (boundaries, sizes)}``.

        Level-by-level: all sibling pairs of a level go through *one*
        vmapped jitted merge, so a ``W``-partition build costs ``log2 W``
        XLA dispatches instead of ``W·log2 W`` (the incremental path's
        cost when used for bulk loads).
        """
        self.nodes = {}
        self._invalidate()
        if not leaves:
            self.base = None
            self.levels = 0
            return
        pids = sorted(leaves)
        if self.base is None or pids[0] < self.base:
            self.base = pids[0]
        span = pids[-1] - self.base + 1
        self.levels = (span - 1).bit_length() if span > 1 else 0
        for pid in pids:
            b = np.asarray(leaves[pid][0], np.float32)
            s = np.asarray(leaves[pid][1], np.float32)
            self.nodes[(0, pid - self.base)] = TreeNode(
                b, s, float(s.sum()), 0.0, 1
            )
        self._pull_up_many({pid - self.base for pid in pids})

    # -------------------------------------------------------------- queries
    def decompose(self, lo: int, hi: int) -> list[tuple[int, int]]:
        """Present canonical node keys covering partition ids ``lo..hi``."""
        if self.base is None:
            return []
        s_lo = max(int(lo) - self.base, 0)
        s_hi = min(int(hi) - self.base, self.capacity - 1)
        if s_hi < s_lo:
            return []
        return [
            k for k in canonical_decomposition(s_lo, s_hi) if k in self.nodes
        ]

    def _selected(self, lo: int, hi: int) -> list[TreeNode]:
        sel = [self.nodes[k] for k in self.decompose(lo, hi)]
        if not sel:
            raise KeyError("no partition summaries in requested interval")
        return sel

    def _cache_get(self, key: tuple) -> tuple[Histogram, float] | None:
        """LRU lookup; counts (and refreshes) a hit, leaves misses to the
        caller — shared by query/query_many and the cross-tenant registry."""
        hit = self._cache.get(key)
        if hit is not None:
            self._cache.move_to_end(key)
            self.cache_hits += 1
        return hit

    def _cache_put(self, key: tuple, out: tuple[Histogram, float]) -> None:
        self._cache[key] = out
        while len(self._cache) > self._cache_size:
            self._cache.popitem(last=False)

    def _dispatch(
        self, rows: Sequence[Sequence[TreeNode]], beta: int
    ) -> tuple[np.ndarray, np.ndarray]:
        """One counted merge dispatch over packed node rows.

        Returns host arrays: one device→host transfer for the whole batch
        beats ``Q`` lazy per-row jax slices by orders of magnitude when
        answers are unpacked row by row.
        """
        bounds, sizes = pack_node_rows(rows)
        self.merge_dispatches += 1
        self.merge_shapes.add(bounds.shape + (int(beta),))
        bo, so = merge_stacks(bounds, sizes, int(beta))
        return np.asarray(bo), np.asarray(so)

    def query(self, lo: int, hi: int, beta: int) -> tuple[Histogram, float]:
        """β-bucket histogram over ``lo..hi`` plus its composed ``ε_total``.

        Merges only the ``≤ 2·log2 W`` canonical node summaries; answers are
        LRU-cached until the next mutation.
        """
        key = (int(lo), int(hi), int(beta), self.version)
        hit = self._cache_get(key)
        if hit is not None:
            return hit
        self.cache_misses += 1
        sel = self._selected(lo, hi)
        bo, so = self._dispatch([sel], beta)
        out = (Histogram(bo[0], so[0]), selection_eps(sel))
        self._cache_put(key, out)
        return out

    def query_many(
        self, intervals: Sequence[tuple[int, int]], beta: int
    ) -> list[tuple[Histogram, float]]:
        """Answer many interval queries with at most one jitted merge.

        The LRU answer cache is consulted *per interval* first (a repeated
        dashboard batch costs zero dispatches and counts its hits exactly
        like :meth:`query`); only the misses — deduplicated, so the same
        window twice in one batch merges once — are padded to a single
        static ``(k_pad, T_pad)`` shape and served by one XLA program
        regardless of the mix of window lengths, then cached for the next
        batch.
        """
        if not intervals:
            return []
        keys = [
            (int(lo), int(hi), int(beta), self.version)
            for lo, hi in intervals
        ]
        answers: dict[tuple, tuple[Histogram, float]] = {}
        miss_keys: list[tuple] = []
        pending: set[tuple] = set()  # dedups repeated misses in this batch
        for key in keys:
            if key in answers or key in pending:
                continue
            hit = self._cache_get(key)
            if hit is not None:
                answers[key] = hit
            else:
                self.cache_misses += 1
                pending.add(key)
                miss_keys.append(key)
        if miss_keys:
            sels = [self._selected(k[0], k[1]) for k in miss_keys]
            bo, so = self._dispatch(sels, beta)
            for i, (key, sel) in enumerate(zip(miss_keys, sels)):
                out = (Histogram(bo[i], so[i]), selection_eps(sel))
                answers[key] = out
                self._cache_put(key, out)
        return [answers[key] for key in keys]

    # ---------------------------------------------------------- persistence
    def state(self) -> tuple[dict, dict[str, np.ndarray]]:
        """(json-able meta, arrays) for npz persistence of the tree nodes."""
        meta = {
            "T_node": self.T_node,
            "geometric": self.geometric,
            "base": self.base,
            "levels": self.levels,
            "nodes": [
                [lvl, idx, nd.n, nd.eps, nd.leaves]
                for (lvl, idx), nd in sorted(self.nodes.items())
            ],
        }
        arrays = {}
        for (lvl, idx), nd in self.nodes.items():
            arrays[f"tb_{lvl}_{idx}"] = nd.boundaries
            arrays[f"ts_{lvl}_{idx}"] = nd.sizes
        return meta, arrays

    @classmethod
    def from_state(cls, meta: dict, arrays, cache_size: int = 128):
        tree = cls(
            int(meta["T_node"]),
            cache_size=cache_size,
            geometric=bool(meta.get("geometric", False)),
        )
        tree.base = None if meta["base"] is None else int(meta["base"])
        tree.levels = int(meta["levels"])
        for lvl, idx, n, eps, leaves in meta["nodes"]:
            lvl, idx = int(lvl), int(idx)
            tree.nodes[(lvl, idx)] = TreeNode(
                boundaries=np.asarray(arrays[f"tb_{lvl}_{idx}"], np.float32),
                sizes=np.asarray(arrays[f"ts_{lvl}_{idx}"], np.float32),
                n=float(n),
                eps=float(eps),
                leaves=int(leaves),
            )
        return tree
