"""Integrity scrubber: checksum persisted payloads and live arena planes.

The durability chain (WAL → snapshot → arena rows) assumes bytes stay
what they were written as.  Disks and heaps disagree often enough that a
production plane *scrubs*: every :class:`~repro.core.stream.StoredSummary`
carries a CRC computed when it was summarized, every snapshot written by
``atomic_savez`` embeds per-array CRCs in its meta, and this module walks
both and reports (or repairs) what no longer matches.

Three layers, three checks
--------------------------
* :func:`verify_snapshot` — re-reads an npz written by ``atomic_savez``
  and compares each payload array against the ``payload_crc`` map in its
  meta.  An unreadable or checksum-failing snapshot is *corrupt*;
  ``TenantRegistry.recover(..., salvage=True)`` (and therefore
  ``serve.HistogramService``) moves it aside and rebuilds from the WAL
  alone rather than serving wrong answers.
* :func:`scrub_store` — recomputes each in-memory summary's CRC and
  compares the tree's leaf arena row against the summary bits (the
  pooled row is a write-once copy; a mismatch means the plane — or the
  summary — was corrupted in memory).
* :func:`scrub_divergence` — the replication cross-check: checksums
  every tenant/partition summary on a primary registry against a
  follower's (same CRC currency as :func:`scrub_store`), reporting
  partitions whose bits diverge and partitions only one side holds.
  Partitions the follower simply hasn't applied yet are *lag*, not
  divergence — they appear under ``behind`` so the caller can separate
  "catching up" from "corrupted in flight".
* :func:`scrub_registry` — runs :func:`scrub_store` over every tenant
  and, with ``repair=True``, routes each corrupted tenant through
  **WAL-replay rebuild**: the corrupted partitions are dropped, the
  tenant's tree is rebuilt from the surviving (verified) summaries, and
  any WAL record still on disk for a dropped partition is re-ingested —
  the same idempotent-replay contract recovery uses (core/workers.py).
  Partitions whose WAL segments were already truncated by a (healthy)
  snapshot cannot be re-summarized from raw values; they stay dropped
  and are reported, which degrades those windows honestly
  (``strict=False`` serving skips them) instead of serving bit-rot.
"""
from __future__ import annotations

import binascii
import json

import numpy as np

__all__ = [
    "checksum_array",
    "scrub_divergence",
    "scrub_registry",
    "scrub_store",
    "verify_snapshot",
]


def checksum_array(*arrays) -> int:
    """CRC32 over the raw bytes of each array, with dtype/shape mixed in
    (a reshaped or re-typed row must not collide)."""
    crc = 0
    for a in arrays:
        a = np.ascontiguousarray(a)
        crc = binascii.crc32(
            f"{a.dtype.str}{a.shape}".encode(), crc
        )
        crc = binascii.crc32(a.tobytes(), crc)
    return crc


def payload_checksums(payload: dict[str, np.ndarray]) -> dict[str, int]:
    """Per-key CRC map ``atomic_savez`` embeds as ``meta["payload_crc"]``."""
    return {key: checksum_array(arr) for key, arr in payload.items()}


def verify_snapshot(path: str) -> dict:
    """Checksum every payload array of an ``atomic_savez`` file.

    Returns ``{"ok", "checked", "unchecked", "bad_keys", "error"}``.
    ``ok`` is False when the file is unreadable/unparsable or any
    checksummed array fails; arrays written before the ``payload_crc``
    map existed count as ``unchecked`` (and cannot fail).
    """
    report = {
        "ok": True,
        "checked": 0,
        "unchecked": 0,
        "bad_keys": [],
        "error": None,
    }
    try:
        with np.load(path, allow_pickle=False) as data:
            meta = json.loads(str(data["meta"]))
            crcs = meta.get("payload_crc") or {}
            for key in data.files:
                if key == "meta":
                    continue
                want = crcs.get(key)
                if want is None:
                    report["unchecked"] += 1
                    continue
                report["checked"] += 1
                if checksum_array(data[key]) != int(want):
                    report["bad_keys"].append(key)
    except Exception as e:  # unreadable zip, truncated file, bad json
        report["ok"] = False
        report["error"] = repr(e)
        return report
    report["ok"] = not report["bad_keys"]
    return report


def scrub_store(store) -> dict:
    """Verify one store's summaries and their tree leaf rows in memory.

    Returns ``{"partitions", "checked", "corrupt": [pid, ...]}`` where a
    pid is corrupt when its summary bytes no longer match the CRC
    recorded at summarize time, or its arena leaf row (a write-once copy
    of those bytes) no longer matches the summary.
    """
    corrupt: list[int] = []
    checked = 0
    with store._lock:
        tree = store._tree
        for pid, s in sorted(store.summaries.items()):
            if s.crc is None:  # pre-CRC summary (legacy load): unverifiable
                continue
            checked += 1
            if checksum_array(s.boundaries, s.sizes) != s.crc:
                corrupt.append(pid)
                continue
            node = None
            if tree.base is not None and 0 <= pid - tree.base < tree.capacity:
                node = tree.nodes.get((0, pid - tree.base))
            if node is not None and not (
                np.array_equal(
                    np.asarray(node.boundaries),
                    np.asarray(s.boundaries, np.float32),
                )
                and np.array_equal(
                    np.asarray(node.sizes), np.asarray(s.sizes, np.float32)
                )
            ):
                corrupt.append(pid)  # arena plane drifted from the summary
        return {
            "partitions": len(store.summaries),
            "checked": checked,
            "corrupt": corrupt,
        }


def _wal_records_for(reg, tenant: str, pids: set[int]) -> dict[int, np.ndarray]:
    """Raw values still recoverable from the registry's WAL for ``pids``
    (re-scanned from disk — the in-memory recovered list only holds what
    existed at open time).  Last append wins for duplicate pids."""
    wal = getattr(reg, "_wal", None)
    if wal is None:
        return {}
    out: dict[int, np.ndarray] = {}
    for _path, _first, records, _torn, _epoch in wal._scan():
        for rec in records:
            if rec.tenant is not None and str(rec.tenant) == tenant:
                if rec.pid in pids:
                    out[rec.pid] = rec.values
    return out


def scrub_registry(reg, *, repair: bool = False) -> dict:
    """Scrub every tenant; with ``repair=True`` route corrupted tenants
    through WAL-replay rebuild (module docstring).

    Returns ``{"tenants", "checked", "corrupt": {name: [pids]},
    "repaired": {name: [pids]}, "dropped": {name: [pids]}}`` — repaired
    pids were re-summarized from WAL records, dropped ones had no
    surviving record (their windows degrade honestly under
    ``strict=False`` serving).
    """
    with reg._lock:
        names = sorted(reg._stores)
    out = {
        "tenants": len(names),
        "checked": 0,
        "corrupt": {},
        "repaired": {},
        "dropped": {},
    }
    for name in names:
        store = reg[name]
        rep = scrub_store(store)
        out["checked"] += rep["checked"]
        if not rep["corrupt"]:
            continue
        bad = rep["corrupt"]
        out["corrupt"][name] = list(bad)
        if not repair:
            continue
        salvaged = _wal_records_for(reg, name, set(bad))
        with store._lock:
            # drop the corrupted partitions and rebuild the tree from the
            # verified survivors — one version bump, so no cached answer
            # computed over corrupt rows can be served afterwards
            for pid in bad:
                store.summaries.pop(pid, None)
            store.rebuild_tree()
        if salvaged:
            store._apply(store._summarize_batch(salvaged))
            out["repaired"][name] = sorted(salvaged)
        lost = sorted(set(bad) - set(salvaged))
        if lost:
            out["dropped"][name] = lost
    reg.last_scrub = out
    return out


def _summary_crcs(reg) -> dict[str, dict[int, int]]:
    """``{tenant: {pid: crc}}`` snapshot of one registry, recomputed from
    the live summary bits (so in-memory rot on either side shows up as a
    divergence, not just a replication bug)."""
    with reg._lock:
        names = sorted(reg._stores)
    out: dict[str, dict[int, int]] = {}
    for name in names:
        store = reg[name]
        with store._lock:
            out[name] = {
                pid: checksum_array(s.boundaries, s.sizes)
                for pid, s in store.summaries.items()
            }
    return out


def scrub_divergence(primary, follower) -> dict:
    """Cross-check a follower registry's summaries against its primary's.

    Returns ``{"tenants", "checked", "diverged": {name: [pids]},
    "behind": {name: [pids]}, "extra": {name: [pids]}, "ok"}``:

    * ``diverged`` — partitions both sides hold whose summary CRCs
      differ.  Replication ships raw WAL records and summarization is
      bit-deterministic, so any mismatch means corruption (in flight, on
      the follower's disk, or in either heap) — never a rounding story.
    * ``behind`` — partitions the primary holds that the follower hasn't
      applied yet: replication lag, resolved by the next ``tail()``.
    * ``extra`` — partitions only the follower holds.  Normally empty;
      after a retention sweep on the primary it is the eviction lag
      mirror of ``behind``.

    ``ok`` is True iff ``diverged`` is empty — lag alone never fails the
    scrub (the staleness SLO owns that judgement).
    """
    p, f = _summary_crcs(primary), _summary_crcs(follower)
    diverged: dict[str, list[int]] = {}
    behind: dict[str, list[int]] = {}
    extra: dict[str, list[int]] = {}
    checked = 0
    for name in sorted(set(p) | set(f)):
        pc, fc = p.get(name, {}), f.get(name, {})
        bad = sorted(pid for pid in pc.keys() & fc.keys() if pc[pid] != fc[pid])
        lag = sorted(pc.keys() - fc.keys())
        ahead = sorted(fc.keys() - pc.keys())
        checked += len(pc.keys() & fc.keys())
        if bad:
            diverged[name] = bad
        if lag:
            behind[name] = lag
        if ahead:
            extra[name] = ahead
    return {
        "tenants": len(set(p) | set(f)),
        "checked": checked,
        "diverged": diverged,
        "behind": behind,
        "extra": extra,
        "ok": not diverged,
    }
