"""Self-healing primitives: bounded retry, circuit breaker, degraded answers.

The fault-injection plane (core/faults.py) makes runtime faults
reproducible; this module holds the *responses* the serving plane mounts
against them, all deterministic and clock/sleep-injectable so every
behavior is testable without real time passing:

* :class:`RetryPolicy` — bounded attempts with exponential backoff and
  seeded jitter.  The WAL retries transient fsync failures, the ingest
  pool retries suspect batch items, and both sleep through an
  *interruptible* wait (a ``threading.Event``), so ``close()`` never has
  to out-wait a backoff (core/workers.py).
* :class:`CircuitBreaker` / :class:`BreakerPolicy` — the per-tenant
  quarantine state machine (closed → open → half-open probe → closed).
  A tenant whose ingests keep failing trips its breaker: further submits
  are rejected at the door (:class:`TenantQuarantined`) instead of
  riding into shared batches and poisoning co-batched tenants; after a
  cooldown one probe is allowed through, and a probe success closes the
  breaker.
* :class:`Answer` — a ``(histogram, eps_total)`` pair that still unpacks
  like the historical 2-tuple but carries a ``degraded`` flag: when the
  merge dispatch fails (or a deadline has already passed), the registry
  serves the last known-good answer with an **honestly widened**
  ``eps_total`` — the cached bound plus the total mass added to and
  removed from the interval since the answer was computed, which bounds
  any bucket/range drift the staleness can have introduced — rather than
  raising.  ``degraded`` is never set on a freshly-merged answer, which
  is what lets the chaos harness assert that every non-degraded answer
  bit-matches a fault-free replica.
* :class:`IngestBackpressure` — raised to the *submitter* when durable
  ingest cannot make its ack true (the WAL append/fsync failed after
  retries).  A sick disk pushes back on producers instead of queueing
  acked-but-undurable partitions without bound.  The exception carries
  ``retry_after`` — the backoff the exhausted retry schedule would have
  slept next — so callers (and the replication shipper) can pace their
  resubmit instead of hot-looping; ``health()["backpressure"]`` mirrors
  the latest rejection for dashboards.
* :class:`PrimaryFenced` / :class:`NotPrimary` — the replication plane's
  epoch-fencing contract (core/replication.py): after a failover
  ``promote()`` stamps the new epoch and fences the deposed primary,
  whose late WAL appends are rejected with :class:`PrimaryFenced`
  (never retried, never wrapped in backpressure — the split-brain must
  surface, not pace).  :class:`NotPrimary` rejects ingest on a
  replica-role service.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass
from typing import Callable, Iterator

__all__ = [
    "Answer",
    "BreakerPolicy",
    "CircuitBreaker",
    "IngestBackpressure",
    "NotPrimary",
    "PrimaryFenced",
    "RetryPolicy",
    "TenantQuarantined",
]


class IngestBackpressure(RuntimeError):
    """Durable ingest rejected: the WAL could not make the ack true
    (append or fsync failed after bounded retries).  Nothing was
    enqueued — the caller owns the partition and may resubmit.

    ``retry_after`` (seconds, ``None`` when unknown) is the pacing hint:
    the backoff delay the exhausted retry schedule would have applied
    next.  Callers that resubmit sooner are hot-looping against a disk
    that just refused this exact work.
    """

    def __init__(self, message: str, *, retry_after: float | None = None):
        super().__init__(message)
        self.retry_after = retry_after


class PrimaryFenced(RuntimeError):
    """WAL append rejected by epoch fencing: a follower was promoted at
    a higher epoch than this (now deposed) primary's.  Not a transient
    fault — the caller must stop writing, not retry."""

    def __init__(self, epoch: int, fence_epoch: int):
        super().__init__(
            f"primary fenced: log epoch {epoch} < fence epoch "
            f"{fence_epoch} (a follower was promoted)"
        )
        self.epoch = int(epoch)
        self.fence_epoch = int(fence_epoch)


class NotPrimary(RuntimeError):
    """Write rejected: this service runs in ``role="replica"`` and only
    the primary accepts ingest (promote() flips the role)."""


class TenantQuarantined(RuntimeError):
    """Submit rejected by the tenant's open circuit breaker."""

    def __init__(self, tenant: str, state: str):
        super().__init__(
            f"tenant {tenant!r} is quarantined (breaker {state})"
        )
        self.tenant = tenant
        self.state = state


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with seeded jitter.

    ``attempts`` counts *total* tries (1 = no retry).  Delay before retry
    ``i`` (1-based) is ``min(cap, base * 2**(i-1))`` scaled by a jitter
    factor drawn uniformly from ``[1 - jitter, 1]`` — deterministic for a
    given ``seed``, so tests and the chaos harness replay exact schedules.
    """

    attempts: int = 3
    base: float = 0.01
    cap: float = 1.0
    jitter: float = 0.5
    seed: int = 0

    def delays(self) -> Iterator[float]:
        """The ``attempts - 1`` backoff delays, in order."""
        rng = random.Random(self.seed)
        for i in range(max(0, self.attempts - 1)):
            d = min(self.cap, self.base * (2.0**i))
            if self.jitter > 0.0:
                d *= 1.0 - self.jitter * rng.random()
            yield d

    def retry_after(self) -> float:
        """The (un-jittered) backoff that would follow the final attempt
        — the pacing hint :class:`IngestBackpressure` hands callers when
        this schedule is exhausted."""
        return min(self.cap, self.base * (2.0 ** max(0, self.attempts - 1)))


def retry_call(
    fn: Callable[[], object],
    policy: RetryPolicy,
    *,
    wait: Callable[[float], object] | None = None,
    retryable: Callable[[BaseException], bool] | None = None,
    on_retry: Callable[[int, BaseException], None] | None = None,
):
    """Run ``fn`` under ``policy``; re-raise the last failure when the
    attempt budget is spent.

    ``wait(delay)`` is the backoff sleep — pass an interruptible wait
    (e.g. ``closing_event.wait``) so a concurrent shutdown cuts the
    backoff short; the *remaining attempts still run* (immediately), so
    bounding the wait never drops the retried work.  ``retryable`` may
    veto retrying a permanent error; ``on_retry(attempt, exc)`` is the
    counter hook.
    """
    delays = list(policy.delays())
    last: BaseException | None = None
    for attempt in range(max(1, policy.attempts)):
        try:
            return fn()
        except BaseException as e:
            last = e
            if retryable is not None and not retryable(e):
                raise
            if attempt >= len(delays):
                raise
            if on_retry is not None:
                on_retry(attempt + 1, e)
            if wait is None:
                time.sleep(delays[attempt])
            else:
                wait(delays[attempt])
    raise last  # not reachable: the loop always returns or raises


@dataclass(frozen=True)
class BreakerPolicy:
    """Configuration of the per-tenant :class:`CircuitBreaker`.

    ``threshold`` consecutive failures open the breaker; after
    ``cooldown`` seconds (by ``clock``, injectable for deterministic
    tests) the next ``allow`` admits up to ``probes`` half-open probe
    submits; a recorded success closes the breaker, a failure re-opens
    it for another cooldown.
    """

    threshold: int = 5
    cooldown: float = 30.0
    probes: int = 1
    clock: Callable[[], float] = time.monotonic


class CircuitBreaker:
    """closed → open → half-open → closed, one instance per tenant.

    Thread-safe; every transition is driven by ``allow``/``record_*``
    calls only (no timers), so behavior is fully deterministic under an
    injected clock.
    """

    def __init__(self, policy: BreakerPolicy):
        self.policy = policy
        self._lock = threading.Lock()
        self.state = "closed"
        self.failures = 0  # consecutive, while closed
        self.opened_at = 0.0
        self.probes_in_flight = 0
        self.trips = 0  # closed/half-open → open transitions

    def allow(self) -> bool:
        """May a submit for this tenant proceed right now?  Open breakers
        transition to half-open by themselves once the cooldown elapsed
        (the probe budget admits the caller that observed it)."""
        with self._lock:
            if self.state == "closed":
                return True
            if self.state == "open":
                now = self.policy.clock()
                if now - self.opened_at < self.policy.cooldown:
                    return False
                self.state = "half_open"
                self.probes_in_flight = 0
            # half-open: admit up to `probes` concurrent probe submits
            if self.probes_in_flight < self.policy.probes:
                self.probes_in_flight += 1
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            if self.state == "half_open":
                self.state = "closed"
            self.failures = 0
            self.probes_in_flight = 0

    def record_failure(self) -> None:
        with self._lock:
            if self.state == "half_open":
                self._trip()
                return
            self.failures += 1
            if self.state == "closed" and (
                self.failures >= self.policy.threshold
            ):
                self._trip()

    def _trip(self) -> None:
        self.state = "open"
        self.opened_at = self.policy.clock()
        self.failures = 0
        self.probes_in_flight = 0
        self.trips += 1

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "state": self.state,
                "failures": self.failures,
                "trips": self.trips,
            }


class Answer(tuple):
    """``(histogram, eps_total)`` that unpacks like the historical
    2-tuple, plus the degraded-serving metadata.  Fresh answers stay
    plain tuples (zero overhead); only the degraded path allocates these.
    """

    degraded = False  # class default: plain answers read False
    stale_version: int | None = None  # store version the cached answer saw
    lag_seconds: float | None = None  # replication lag (replica-served)

    @property
    def histogram(self):
        return self[0]

    @property
    def eps(self) -> float:
        return self[1]

    @staticmethod
    def make(hist, eps: float, *, degraded: bool, stale_version=None,
             lag_seconds=None):
        a = Answer((hist, eps))
        a.degraded = degraded
        a.stale_version = stale_version
        a.lag_seconds = lag_seconds
        return a
