"""Equi-depth histogram construction and merging with quality guarantees.

Implements the core contribution of

    Yıldız, Büyüktanır, Emekci — "Equi-depth Histogram Construction for Big
    Data with Quality Guarantees" (cs.DB, 2016)

as pure-JAX, jit/vmap/shard_map-compatible primitives.

Representation
--------------
A ``T``-bucket equi-depth histogram over a value set ``P`` is

    H = {(b_1, s_1), ..., (b_T, s_T), (b_{T+1}, 0)}

stored as ``boundaries`` of shape ``(T+1,)`` (increasing) and ``sizes`` of
shape ``(T,)``.  Bucket ``i`` spans ``[b_i, b_{i+1})`` (the last bucket is
closed on the right) and holds ``s_i`` values.  For an *exact* equi-depth
histogram every ``s_i`` is ``|P|/T`` (±1 when ``T ∤ |P|``).

The merge (paper Algorithm 1)
-----------------------------
Given ``k`` exact ``T``-bucket histograms, the paper builds the pre-histogram
``H⁰`` whose boundaries are the ``k(T+1)`` sorted source boundaries and whose
approximate cumulative sizes ``A(m, H⁰)`` are computed under the
*left-collapse* assumption: all values of a source bucket are presumed to sit
at the bucket's left boundary.  Equivalently

    A(m, H⁰) = Σ_j  size_j · 1[left_j ≤ b_m]                       (★)

i.e. ``A`` is the CDF of point masses (one per source bucket, at its left
boundary) evaluated at the sorted boundary positions.  The paper then merges
consecutive ``H⁰`` buckets with a sequential two-pointer sweep until β buckets
remain (its main ``while`` loop).

**Parallel rank-select equivalence** (our TPU adaptation, proven equivalent
and bit-exactly tested against the sequential reference): because ``A`` is
non-decreasing, the sweep's cut for target ``t_j = j·N/β`` is exactly

    cut_j = searchsorted(A, t_j, side='right')
    b*_j  = pos[cut_j]                       (interior boundaries, j=1..β-1)
    S*_j  = A[cut_j - 1]                     (cumulative size at the cut)

so the whole merge is one sort + one cumsum + one batched binary search:
``O(kT log kT)`` work at ``O(log)`` depth instead of the paper's ``O(kT)``
sequential loop.  Output is identical (see tests/test_merge_equivalence.py).

Error bounds (paper Theorems 1 and 2)
-------------------------------------
For exact ``T``-bucket inputs whose per-bucket size is exactly ``|P_i|/T``,
every output bucket size and every contiguous range of output buckets is
within ``± ε_max`` of ideal, with

    ε_max < 2N/T = (2β/T) · (N/β).

When ``T ∤ |P_i|`` exact inputs have per-bucket sizes ``⌊|P_i|/T⌋`` or
``⌈|P_i|/T⌉``; Proof 1's two divided-bucket terms each grow by at most 1, so
the bound degrades to ``2N/T + 2k`` (this integer slack is what the property
tests assert; it vanishes under the paper's divisibility assumption).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Histogram",
    "build_exact",
    "build_exact_batched",
    "build_exact_padded",
    "build_exact_padded_batched",
    "pad_pow2",
    "next_pow2",
    "merge",
    "merge_histograms_sequential",
    "pre_histogram",
    "quantile",
    "cdf_left_collapse",
    "cdf_interp",
    "range_count",
    "boundary_error",
    "size_error",
    "theoretical_eps_max",
    "sample_histogram",
]


class Histogram(NamedTuple):
    """An (approximate) equi-depth histogram.

    boundaries: ``(..., T+1)`` increasing bucket boundaries.
    sizes:      ``(..., T)``   per-bucket value counts (float for mergeability
                               at ``N ≥ 2^24``; exact integers below that).
    """

    boundaries: jax.Array
    sizes: jax.Array

    @property
    def num_buckets(self) -> int:
        return self.sizes.shape[-1]

    @property
    def n(self) -> jax.Array:
        """Total number of summarized values."""
        return jnp.sum(self.sizes, axis=-1)

    def cumulative(self) -> jax.Array:
        """``S(i, H)`` for i = 1..T, shape ``(..., T)``."""
        return jnp.cumsum(self.sizes, axis=-1)


# ---------------------------------------------------------------------------
# Exact construction (the paper's Summarizer)
# ---------------------------------------------------------------------------


def _cut_indices(n: int, T: int) -> np.ndarray:
    """Sorted-array cut positions: bucket i covers [cuts[i], cuts[i+1])."""
    return np.floor(np.arange(T + 1) * n / T).astype(np.int32)


@functools.partial(jax.jit, static_argnames=("num_buckets", "count_dtype"))
def build_exact(
    values: jax.Array, num_buckets: int, count_dtype=jnp.float32
) -> Histogram:
    """Exact ``T``-bucket equi-depth histogram of a 1-D value array.

    Sorts the partition and cuts it into ``T`` near-equal runs — the paper's
    "well-known straight-forward" offline construction.  ``O(n log n)``.
    """
    n = values.shape[0]
    T = num_buckets
    if n < 1:
        raise ValueError("cannot summarize an empty partition")
    sv = jnp.sort(values)
    cuts = jnp.asarray(_cut_indices(n, T))
    boundaries = sv[jnp.minimum(cuts, n - 1)]
    sizes = jnp.diff(cuts).astype(count_dtype)
    return Histogram(boundaries=boundaries, sizes=sizes)


def build_exact_batched(
    values: jax.Array, num_buckets: int, count_dtype=jnp.float32
) -> Histogram:
    """vmap of :func:`build_exact` over a leading batch axis.

    ``values``: ``(k, n)`` → histogram with ``boundaries (k, T+1)``,
    ``sizes (k, T)``.  Used for VMEM-tile-level summaries and per-layer
    telemetry.
    """
    fn = functools.partial(
        build_exact, num_buckets=num_buckets, count_dtype=count_dtype
    )
    return jax.vmap(fn)(values)


# ---------------------------------------------------------------------------
# Shape-stable (mask-aware) construction — the batched Summarizer pipeline
# ---------------------------------------------------------------------------
#
# ``build_exact`` is jitted on the partition *shape*, so a stream of
# variable-length partitions costs one fresh XLA compile per distinct length.
# The padded variant below fixes the executable shape instead: partitions are
# padded with a +inf sentinel to a power-of-two length bucket and the cut
# indices are computed from the *true* length ``n`` (a traced scalar), so
# every length in a 2× band shares one compiled program — O(log max_n) total
# compiles for any mix of lengths.  Because the sentinel sorts past every
# real value and no cut index ever reaches it (``cuts ≤ n``, reads clamped to
# ``n-1``), the result is bit-identical to ``build_exact`` on the unpadded
# values (property-tested in tests/test_batched_ingest.py).


def next_pow2(k: int) -> int:
    """Smallest power of two ≥ ``k`` (``k ≥ 1``) — THE padding rule for
    every shape-stable batch/length axis (summarizer stacks, merge batch
    padding, tree pull-up batches); keep it single-sourced so the bounded
    jit-cache guarantees stay in sync."""
    return 1 << max(0, k - 1).bit_length()


def pad_pow2(values, min_len: int = 1) -> tuple[np.ndarray, int]:
    """Pad a 1-D array to the next power-of-two length with a +inf sentinel.

    Returns ``(padded, n)`` where ``n`` is the true length.  Integer dtypes
    use their max value as the sentinel; either way the pad elements sort to
    the tail and are never selected by the masked cut indices.
    """
    v = np.asarray(values).reshape(-1)
    n = int(v.shape[0])
    if n < 1:
        raise ValueError("cannot summarize an empty partition")
    n_pad = next_pow2(max(n, min_len))
    if n_pad == n:
        return v, n
    if np.issubdtype(v.dtype, np.floating):
        fill = np.array(np.inf, v.dtype)
    else:
        fill = np.array(np.iinfo(v.dtype).max, v.dtype)
    return np.concatenate([v, np.full(n_pad - n, fill, v.dtype)]), n


def _masked_cuts(n: jax.Array, T: int) -> jax.Array:
    """``floor(i·n/T)`` for i = 0..T with a *traced* ``n`` — exact integer
    arithmetic (``i·(n%T) < T² `` fits int32) so the cuts match
    :func:`_cut_indices` bit for bit."""
    i = jnp.arange(T + 1, dtype=jnp.int32)
    q, r = n // T, n % T
    return i * q + (i * r) // T


def _build_exact_masked(values, n, num_buckets, count_dtype):
    sv = jnp.sort(values)  # sentinel pad sorts past every real value
    n = jnp.asarray(n, jnp.int32)
    cuts = _masked_cuts(n, num_buckets)
    boundaries = sv[jnp.minimum(cuts, n - 1)]
    sizes = jnp.diff(cuts).astype(count_dtype)
    return Histogram(boundaries=boundaries, sizes=sizes)


@functools.partial(jax.jit, static_argnames=("num_buckets", "count_dtype"))
def build_exact_padded(
    values: jax.Array, n, num_buckets: int, count_dtype=jnp.float32
) -> Histogram:
    """Mask-aware :func:`build_exact` over a sentinel-padded partition.

    ``values``: ``(n_pad,)`` — the true values followed by +inf padding
    (see :func:`pad_pow2`); ``n``: true length, traced.  Bit-identical to
    ``build_exact(values[:n], num_buckets)``; compiles once per ``n_pad``.
    """
    return _build_exact_masked(values, n, num_buckets, count_dtype)


@functools.partial(jax.jit, static_argnames=("num_buckets", "count_dtype"))
def build_exact_padded_batched(
    values: jax.Array, ns, num_buckets: int, count_dtype=jnp.float32
) -> Histogram:
    """One-dispatch summarizer for a ``(k, n_pad)`` stack of padded
    partitions with true lengths ``ns`` of shape ``(k,)`` — the vmapped form
    of :func:`build_exact_padded`.  The whole stack is summarized by a
    single XLA program keyed only on ``(k, n_pad, T)``."""
    fn = functools.partial(
        _build_exact_masked, num_buckets=num_buckets, count_dtype=count_dtype
    )
    return jax.vmap(fn)(values, jnp.asarray(ns, jnp.int32))


# ---------------------------------------------------------------------------
# The merge — parallel rank-select form (production path)
# ---------------------------------------------------------------------------


def pre_histogram(histograms: Histogram) -> tuple[jax.Array, jax.Array]:
    """Assemble the paper's pre-histogram ``H⁰`` from stacked summaries.

    ``histograms``: stacked summaries — ``boundaries (k, T+1)``, ``sizes
    (k, T)`` (the per-source bucket counts; sources may have *different* T by
    padding with zero-size buckets).

    Returns ``(pos, A)`` where ``pos`` is the sorted flat boundary sequence,
    shape ``(k(T+1),)``, and ``A`` the left-collapse cumulative sizes of
    equation (★), shape ``(k(T+1) - 1,)`` — ``A[m-1] = A(m, H⁰)`` in paper
    notation.
    """
    b = histograms.boundaries
    s = histograms.sizes
    k = b.shape[0]
    # Point mass of each source bucket sits at its left boundary; the last
    # boundary of every source carries zero mass — the paper's (b_{T+1}, 0).
    mass = jnp.concatenate(
        [s, jnp.zeros((k, 1), dtype=s.dtype)], axis=-1
    ).reshape(-1)
    flat = b.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    pos = flat[order]
    cum = jnp.cumsum(mass[order])
    return pos, cum[:-1]


@functools.partial(jax.jit, static_argnames=("beta",))
def merge(histograms: Histogram, beta: int) -> Histogram:
    """Merge ``k`` stacked ``T``-bucket summaries into a β-bucket histogram.

    Vectorized rank-select equivalent of paper Algorithm 1 (see module
    docstring).  Fully jit-able: one sort + cumsum + batched searchsorted.
    """
    pos, A = pre_histogram(histograms)
    n = jnp.sum(histograms.sizes)
    targets = jnp.arange(1, beta, dtype=A.dtype) * (n / beta)
    cut = jnp.searchsorted(A, targets, side="right")  # (β-1,) in [0, len(A)]
    interior = pos[cut]
    boundaries = jnp.concatenate([pos[:1], interior, pos[-1:]])
    # Cumulative size at each cut: A[cut-1], with A[-1] treated as 0.
    s_at_cut = jnp.where(cut > 0, A[jnp.maximum(cut - 1, 0)], 0.0)
    full = jnp.concatenate(
        [jnp.zeros((1,), A.dtype), s_at_cut, n[None].astype(A.dtype)]
    )
    sizes = jnp.diff(full)
    return Histogram(boundaries=boundaries, sizes=sizes)


def merge_list(histograms: Sequence[Histogram], beta: int) -> Histogram:
    """Merge a Python list of (possibly differently-sized) summaries.

    Sources with differing bucket counts are padded with zero-size buckets at
    their last boundary, which leaves equation (★) unchanged.
    """
    T_max = max(h.sizes.shape[-1] for h in histograms)
    bs, ss = [], []
    for h in histograms:
        T = h.sizes.shape[-1]
        pad = T_max - T
        bs.append(
            jnp.concatenate([h.boundaries, jnp.repeat(h.boundaries[-1:], pad)])
        )
        ss.append(
            jnp.concatenate([h.sizes, jnp.zeros((pad,), dtype=h.sizes.dtype)])
        )
    stacked = Histogram(jnp.stack(bs), jnp.stack(ss))
    return merge(stacked, beta)


# ---------------------------------------------------------------------------
# The merge — faithful sequential Algorithm 1 (reference / paper baseline)
# ---------------------------------------------------------------------------


def merge_histograms_sequential(
    histograms: Sequence[Histogram] | Histogram, beta: int
) -> Histogram:
    """Direct host-side port of paper Algorithm 1 (two-pointer sweep).

    Used (a) as the paper-faithful baseline in benchmarks and (b) as the
    oracle for the equivalence property test of the vectorized `merge`.
    Runs in ``O(kT log k + kT)`` like the paper; not jit-able by design.
    """
    if isinstance(histograms, Histogram):
        b = np.asarray(histograms.boundaries)
        s = np.asarray(histograms.sizes)
    else:
        b = np.stack([np.asarray(h.boundaries) for h in histograms])
        s = np.stack([np.asarray(h.sizes) for h in histograms])
    k = b.shape[0]
    mass = np.concatenate([s, np.zeros((k, 1), s.dtype)], axis=-1).reshape(-1)
    flat = b.reshape(-1)
    order = np.argsort(flat, kind="stable")
    pos = flat[order]
    cum = np.cumsum(mass[order])
    A = cum[:-1]  # A[m-1] == A(m, H⁰)
    n = float(s.sum())

    out_b = [pos[0]]
    out_s = []
    prev_cum = 0.0
    nxt = 0  # 0-based index into A; paper's `next` pointer (monotone)
    for j in range(1, beta):
        target = j * n / beta
        # Paper inner while: advance while A(next, H⁰) ≤ current · N/β.
        while nxt < A.shape[0] and A[nxt] <= target:
            nxt += 1
        # MERGEBUCKETS(last, next-1): emitted bucket ends at boundary of the
        # first H⁰ bucket whose cumulative size exceeds the target.
        out_b.append(pos[nxt])
        cum_here = A[nxt - 1] if nxt > 0 else 0.0
        out_s.append(cum_here - prev_cum)
        prev_cum = cum_here
    out_b.append(pos[-1])
    out_s.append(n - prev_cum)
    return Histogram(
        boundaries=jnp.asarray(np.array(out_b)),
        sizes=jnp.asarray(np.array(out_s, dtype=np.float32)),
    )


# ---------------------------------------------------------------------------
# Queries
# ---------------------------------------------------------------------------


def cdf_left_collapse(hist: Histogram, x: jax.Array) -> jax.Array:
    """CDF estimate under the paper's left-collapse assumption.

    Count of values ``< x`` ≈ total mass of buckets with left boundary ≤ x.
    Within ``±2N/T`` of truth for exact inputs (Theorem 2 with a one-bucket
    range).
    """
    left = hist.boundaries[..., :-1]
    cum = hist.cumulative()
    idx = jnp.searchsorted(left, x, side="right")
    padded = jnp.concatenate([jnp.zeros_like(cum[..., :1]), cum], axis=-1)
    return padded[idx]


def cdf_interp(hist: Histogram, x: jax.Array) -> jax.Array:
    """Piecewise-linear CDF estimate (mass uniform inside each bucket)."""
    b = hist.boundaries
    cum = jnp.concatenate(
        [jnp.zeros_like(hist.sizes[..., :1]), hist.cumulative()], axis=-1
    )
    return jnp.interp(x, b, cum)


def quantile(hist: Histogram, q: jax.Array) -> jax.Array:
    """Approximate q-quantile (vector ``q`` ok) by inverse interpolated CDF.

    Rank error is bounded by the paper's ``ε_max``: the returned value's true
    rank is within ``q·N ± 2N/T`` for exact single-level summaries.
    """
    b = hist.boundaries
    cum = jnp.concatenate(
        [jnp.zeros_like(hist.sizes[..., :1]), hist.cumulative()], axis=-1
    )
    n = cum[..., -1]
    return jnp.interp(jnp.asarray(q) * n, cum, b)


def range_count(hist: Histogram, lo: jax.Array, hi: jax.Array) -> jax.Array:
    """Approximate number of values in ``[lo, hi)`` (Theorem 2 quantity)."""
    return cdf_interp(hist, hi) - cdf_interp(hist, lo)


# ---------------------------------------------------------------------------
# Error metrics (paper Eq. 9 and Eq. 10) and the theoretical bound
# ---------------------------------------------------------------------------


def boundary_error(approx: Histogram, exact: Histogram) -> jax.Array:
    """μ_b — normalized RMS boundary deviation (paper Eq. 9)."""
    B = approx.num_buckets
    ba, be = approx.boundaries, exact.boundaries
    vmax, vmin = be[-1], be[0]
    rms = jnp.sqrt(jnp.mean((ba - be) ** 2))
    return B / (vmax - vmin) * rms


def size_error(approx: Histogram, exact: Histogram) -> jax.Array:
    """μ_s — normalized RMS bucket-size deviation (paper Eq. 10)."""
    B = approx.num_buckets
    n = jnp.sum(exact.sizes)
    rms = jnp.sqrt(jnp.mean((approx.sizes - exact.sizes) ** 2))
    return B / n * rms


def theoretical_eps_max(n: float, T: int, k: int = 1, exact_inputs: bool = True) -> float:
    """Paper bound ``ε_max < 2N/T`` (+``2k`` integer slack, module docstring)."""
    slack = 0.0 if exact_inputs else 2.0 * k
    return 2.0 * n / T + slack


def empirical_sizes(values: jax.Array, boundaries: jax.Array) -> jax.Array:
    """TRUE per-bucket counts of ``values`` under ``boundaries``.

    Bucket i spans ``[b_i, b_{i+1})``; the last bucket is right-closed
    (paper convention).  This — not the reported approximate sizes — is what
    the paper's μ_s (Eq. 10) measures: how far the *actual* occupancy of the
    approximate buckets deviates from N/B.
    """
    v = jnp.sort(values.reshape(-1))
    b = boundaries
    lo = jnp.searchsorted(v, b[:-1], side="left")
    hi = jnp.searchsorted(v, b[1:], side="left")
    sizes = (hi - lo).astype(jnp.float32)
    eq_last = jnp.sum((v == b[-1]).astype(jnp.float32))
    return sizes.at[-1].add(eq_last)


def empirical_size_error(approx: Histogram, values: jax.Array) -> jax.Array:
    """μ_s (paper Eq. 10) with true bucket occupancy under approx boundaries."""
    B = approx.num_buckets
    n = values.size
    true_sizes = empirical_sizes(values, approx.boundaries)
    rms = jnp.sqrt(jnp.mean((true_sizes - n / B) ** 2))
    return B / n * rms


# ---------------------------------------------------------------------------
# The paper's comparison baseline: corrected tuple-level random sampling
# ---------------------------------------------------------------------------


def sample_histogram(
    values: jax.Array, num_buckets: int, sample_size: int, key: jax.Array
) -> Histogram:
    """`tuple` baseline of paper §7 — random sample + exact histogram of it.

    "Corrected" per the paper: the global min and max are force-included so
    sparse edges are represented.  Sizes are scaled back to ``N``.
    """
    n = values.shape[0]
    idx = jax.random.randint(key, (sample_size,), 0, n)
    sample = values[idx]
    vmin = jnp.min(values)
    vmax = jnp.max(values)
    sample = jnp.concatenate([vmin[None], sample, vmax[None]])
    h = build_exact(sample, num_buckets)
    scale = n / sample.shape[0]
    return Histogram(boundaries=h.boundaries, sizes=h.sizes * scale)
