"""Shared node-storage arena: one pooled ``(n_slots, T)`` layout for trees.

Why an arena
------------
The paper's merge framework treats every partition/node summary as an
identical ``(T+1 boundaries, T sizes)`` record — exactly the shape
homogeneity a pooled, columnar (SoA) layout exploits.  Before this module,
every :class:`~repro.core.interval_tree.TreeNode` owned its own little pair
of NumPy arrays: thousands of same-shape trees (one per tenant of a
:class:`~repro.core.tenant.TenantRegistry`) meant hundreds of thousands of
tiny heap allocations, and every cross-tenant ``query_many`` re-packed its
merge stack host-side, row by row — the same row-at-a-time materialization
trap PR 3 killed on the *output* path, still alive on the *input* path.

A :class:`NodeArena` instead holds a small number of **planes** — one pool
pair per row width ``W`` (number of buckets):

    boundaries pool   (capacity, W + 1)  float32
    sizes pool        (capacity, W)      float32

A node is then just a ``(width, row)`` reference into its plane; the
handle class (:class:`~repro.core.interval_tree.TreeNode`) carries that
reference plus the error-bound bookkeeping, and its ``boundaries`` /
``sizes`` properties are NumPy views of the pooled rows.  Uniform
``T_node`` trees live entirely in one plane; geometric ``T_node`` uses one
plane per level resolution (``T·2^l``) — the per-level views of the pool.

Rows are stored **pre-padded** to the plane width with the merge-exact
padding rule (zero-mass copies of the last real boundary — bit-exactness
argument in interval_tree.py's module docstring), so packing a merge stack
from the arena needs no per-row padding work at all:

* **host pack** — selected rows materialize with ONE fancy-index copy per
  plane (:meth:`rows`) instead of one copy + pad per row;
* **device pack** — :meth:`device` keeps a device-resident snapshot of
  each plane (rebuilt only when the plane version moved), so a whole
  cross-tenant merge stack is assembled with a single ``jnp.take`` gather
  (:func:`pack_device_rows`): zero host-side row copies, zero per-tenant
  transfers.  :attr:`host_row_copies` counts every host-side row
  materialization (mirroring the ``merge_dispatches`` observability
  idiom), so "the gather path copies nothing on the host" is a
  machine-checked claim, not a comment.

Slot lifecycle (the design note)
--------------------------------
Allocation is free-list + geometric growth: ``alloc``/``alloc_block`` pop
free rows (growing the plane ×2 when empty), write the row data **once**,
and return row indices.  Rows are *write-once*: replacing a leaf or
re-merging an internal node always allocates a new row and drops the old
handle — a live row's bits never change (growth reallocs the pool but
copies values verbatim; a view taken earlier still reads the same values
from the old buffer).

Deallocation is tied to **handle lifetime**, not tree bookkeeping: when
the last reference to a ``TreeNode`` handle dies, CPython's refcounting
calls its finalizer, which appends the ``(width, row)`` to the arena's
dead-list; the next allocation drains that list back into the free lists
(append is GIL-atomic, so the finalizer never takes a lock — it may run
at arbitrary points, including inside arena calls).  This is what makes
the concurrent snapshot contract cheap: a cross-tenant ``query_many``
that collected node handles under each store's lock *owns* those rows
until it drops the selection — eviction running concurrently merely
removes dict entries, and the rows cannot be freed (let alone reused and
overwritten) while the in-flight pack still references them.  The
retention race test pins exactly this.

Corollary for callers: hold a strong reference to the handle for as long
as you read its row views.  All in-tree paths do (the rebuild paths keep
the old node dict alive across the rebuild for this reason).

Invalidation vs store version
-----------------------------
The arena deliberately has **no** notion of answer staleness: the store
version (bumped once per mutation batch) keys the LRU answer caches, and
the *plane* version (bumped on every row write) keys only the device
snapshot.  The two move independently — e.g. a cache-invalidating
eviction that frees rows without writing any leaves the device snapshot
valid (freed rows still hold their old bits and are never gathered), so
warm-miss queries keep serving from the resident pools without an
upload.

Footprint metering
------------------
:meth:`allocated_floats` (live rows × padded width) is the *real* arena
footprint a :class:`~repro.core.retention.MemoryBudget` can meter;
``IntervalTree.node_floats`` keeps reporting logical (un-padded) floats
per unique slot so existing budget calibrations are unchanged.
"""
from __future__ import annotations

import numpy as np

from repro.analysis.witness import OrderedRLock
from repro.core import faults

__all__ = ["NodeArena"]

_MIN_CAPACITY = 64


class _Plane:
    """One ``(capacity, width)`` pool pair for a fixed row width."""

    __slots__ = (
        "width",
        "b",
        "s",
        "free",
        "live",
        "version",
        "_device",
        "_device_version",
    )

    def __init__(self, width: int, capacity: int = _MIN_CAPACITY):
        self.width = int(width)
        self.b = np.zeros((capacity, self.width + 1), np.float32)
        self.s = np.zeros((capacity, self.width), np.float32)
        self.free = list(range(capacity - 1, -1, -1))  # pop() → lowest first
        self.live = 0
        self.version = 0
        self._device = None
        self._device_version = -1

    @property
    def capacity(self) -> int:
        return self.b.shape[0]

    def _grow(self) -> None:
        old = self.capacity
        new = max(_MIN_CAPACITY, old * 2)
        b = np.zeros((new, self.width + 1), np.float32)
        s = np.zeros((new, self.width), np.float32)
        b[:old] = self.b
        s[:old] = self.s
        self.b, self.s = b, s
        self.free.extend(range(new - 1, old - 1, -1))


class NodeArena:
    """Pooled node storage: per-width planes, free lists, device snapshots.

    One arena may back a single tree (the default — every
    :class:`~repro.core.interval_tree.IntervalTree` owns one) or be shared
    by every same-config tenant of a registry
    (``TenantRegistry(shared_arena=True)``), which is what turns the
    cross-tenant merge-stack pack into a single device gather.
    """

    def __init__(self):
        self._planes: dict[int, _Plane] = {}
        # RLock: public entry points may nest (alloc → reap → free lists)
        self._lock = OrderedRLock("arena._lock")
        # rows whose last handle was garbage-collected; finalizers append
        # without taking the lock (list.append is GIL-atomic), alloc drains
        self._dead: list[tuple[int, int]] = []
        # host-side row materializations since construction/reset — the
        # machine-checked "zero-copy" counter (mirrors merge_dispatches)
        self.host_row_copies = 0

    # ------------------------------------------------------------ allocation
    def _plane(self, width: int) -> _Plane:
        plane = self._planes.get(width)
        if plane is None:
            plane = self._planes[width] = _Plane(width)
        return plane

    def _reap(self) -> None:
        """Drain GC-freed rows back into the free lists (under the lock)."""
        while self._dead:
            width, row = self._dead.pop()
            plane = self._planes.get(width)
            if plane is not None:
                plane.free.append(row)
                plane.live -= 1

    def _pop_slot(self, plane: _Plane) -> int:
        if not plane.free:
            plane._grow()
        plane.live += 1
        return plane.free.pop()

    def alloc(self, width: int, boundaries, sizes) -> int:
        """Write one logical ``(T+1,)``/``(T,)`` summary into a fresh row of
        the ``width`` plane (padded to the plane width with zero-mass copies
        of its last boundary) and return the row index."""
        b = np.asarray(boundaries, np.float32).reshape(-1)
        s = np.asarray(sizes, np.float32).reshape(-1)
        T = s.shape[0]
        if T > width:
            raise ValueError(f"summary of {T} buckets exceeds plane width {width}")
        faults.hit("arena.alloc", width=width)
        with self._lock:
            self._reap()
            plane = self._plane(width)
            row = self._pop_slot(plane)
            plane.b[row, : T + 1] = b
            plane.b[row, T + 1 :] = b[T]
            plane.s[row, :T] = s
            if T < width:
                plane.s[row, T:] = 0.0
            plane.version += 1
            return row

    def alloc_block(self, width: int, boundaries: np.ndarray, sizes: np.ndarray) -> list[int]:
        """Vectorized :meth:`alloc` of ``k`` uniform-width summaries:
        ``boundaries (k, T+1)``, ``sizes (k, T)`` → ``k`` row indices
        (one scatter per pool instead of per row — the merge-output write
        path of the level-batched pull-up)."""
        b = np.asarray(boundaries, np.float32)
        s = np.asarray(sizes, np.float32)
        k, T = s.shape
        if T > width:
            raise ValueError(f"summaries of {T} buckets exceed plane width {width}")
        faults.hit("arena.alloc", width=width, k=k)
        with self._lock:
            self._reap()
            plane = self._plane(width)
            rows = [self._pop_slot(plane) for _ in range(k)]
            idx = np.asarray(rows, np.int64)
            plane.b[idx, : T + 1] = b
            if T < width:
                plane.b[idx, T + 1 :] = b[:, T:]  # (k, 1) broadcasts
                plane.s[idx, T:] = 0.0
            plane.s[idx, :T] = s
            plane.version += 1
            return rows

    # -------------------------------------------------------------- reading
    def view(self, width: int, row: int) -> tuple[np.ndarray, np.ndarray]:
        """Full-width ``(boundaries, sizes)`` views of one row.  Valid for
        as long as the caller holds the row's handle (module docstring)."""
        plane = self._planes[width]
        return plane.b[row], plane.s[row]

    def rows(self, width: int, idx) -> tuple[np.ndarray, np.ndarray]:
        """Materialize many rows host-side with one fancy-index copy per
        pool — the 'one stacked copy per tree' pack path.  Counted in
        :attr:`host_row_copies` (under the lock: the counter is a
        machine-checked benchmark value and the host-pack fallback runs
        outside the store locks)."""
        idx = np.asarray(idx, np.int64)
        faults.hit("arena.rows", width=width)
        with self._lock:
            plane = self._planes[width]
            self.host_row_copies += int(idx.size)
            return plane.b[idx], plane.s[idx]

    def device(self, width: int):
        """Device-resident ``(boundaries, sizes)`` snapshot of the plane,
        rebuilt only when the plane version moved since the last call."""
        import jax.numpy as jnp

        faults.hit("arena.gather", width=width)
        with self._lock:
            plane = self._planes[width]
            if plane._device_version != plane.version:
                plane._device = (jnp.asarray(plane.b), jnp.asarray(plane.s))
                plane._device_version = plane.version
            return plane._device

    # ------------------------------------------------------------- metering
    def widths(self) -> list[int]:
        with self._lock:
            return sorted(self._planes)

    def live_rows(self) -> int:
        with self._lock:
            self._reap()
            return sum(p.live for p in self._planes.values())

    def allocated_floats(self) -> int:
        """Real pooled floats held by live rows (padded widths) — the
        figure a memory meter for the *arena itself* acts on."""
        with self._lock:
            self._reap()
            return sum(p.live * (2 * p.width + 1) for p in self._planes.values())

    def capacity_floats(self) -> int:
        """Total pooled floats including free rows (what is resident)."""
        with self._lock:
            return sum(
                p.capacity * (2 * p.width + 1) for p in self._planes.values()
            )

    # ---------------------------------------------------------- persistence
    def export(
        self, slot_refs
    ) -> tuple[dict[str, np.ndarray], dict[tuple[int, int], int]]:
        """Compact the live rows ``slot_refs`` (iterable of ``(width, row)``,
        duplicates allowed) into dense per-plane pools.

        Returns ``(arrays, slot_map)``: ``arrays`` holds ``ab_{width}`` /
        ``as_{width}`` blocks with only the referenced rows (free-list
        fragmentation compacts away on save), ``slot_map`` maps each
        distinct ``(width, row)`` to its dense index — shared handles keep
        sharing one exported row.  One fancy-index copy per plane.
        """
        by_width: dict[int, list[int]] = {}
        slot_map: dict[tuple[int, int], int] = {}
        for width, row in slot_refs:
            key = (width, row)
            if key in slot_map:
                continue
            rows = by_width.setdefault(width, [])
            slot_map[key] = len(rows)
            rows.append(row)
        arrays: dict[str, np.ndarray] = {}
        with self._lock:
            for width, rows in by_width.items():
                plane = self._planes[width]
                idx = np.asarray(rows, np.int64)
                arrays[f"ab_{width}"] = plane.b[idx].copy()
                arrays[f"as_{width}"] = plane.s[idx].copy()
        return arrays, slot_map
