"""Distributed summarize-and-merge — the paper's framework on a TPU mesh.

The Hadoop mapping (DESIGN.md §2):

    Summarizer job   →  per-device exact histogram of the local shard
                        (``shard_map`` + ``build_exact``; optionally the
                        Pallas tile-sort path, ``kernels/tile_sort``)
    summary files    →  ``(T+1)`` boundaries + ``T`` sizes per device
    Merger job       →  ``all_gather`` of the summaries (tiny) + vectorized
                        ``merge`` computed replicated on every device

Everything here composes with ``jax.jit`` under a mesh, so the training step
can call it inline (telemetry, quantile clipping) and XLA overlaps the
all-gather with surrounding compute.

Hierarchical merge (DESIGN.md §5): exact sorts only ever touch VMEM-tile-sized
blocks; the paper's own theorem is applied recursively tile → device → pod
with composed bound ``ε_total < 2N · Σ_level 1/T_level``.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.histogram import (
    Histogram,
    build_exact,
    build_exact_batched,
    merge,
)

__all__ = [
    "local_summarize",
    "gather_and_merge",
    "distributed_histogram",
    "hierarchical_device_summary",
    "hierarchical_eps_bound",
    "distributed_histogram_hierarchical",
    "tensor_histogram_in_step",
]


def hierarchical_eps_bound(
    n: int,
    T_levels: Sequence[int],
    merges_k: Sequence[int] = (),
) -> float:
    """Composed Theorem-1 bound for a multi-level merge hierarchy.

    ``ε_total < 2N · Σ_level 1/T_level`` plus ``2k`` integer slack per merge
    of ``k`` inputs — the recursion used tile → device → pod here and across
    time by the segment-tree interval engine (``core/interval_tree.py``).
    """
    eps = 2.0 * n * sum(1.0 / T for T in T_levels)
    return eps + 2.0 * sum(merges_k)


def local_summarize(x_local: jax.Array, T: int) -> Histogram:
    """Summarizer: exact T-bucket histogram of this device's shard."""
    return build_exact(x_local.reshape(-1), T)


def gather_and_merge(
    local: Histogram, beta: int, axis_names: str | tuple[str, ...]
) -> Histogram:
    """Merger: all-gather per-device summaries along mesh axes and merge.

    Must run inside ``shard_map`` (or any context where ``axis_names`` are
    bound).  Moves ``k·(2T+1)`` scalars instead of ``N`` raw values — the
    paper's shuffle-avoidance, realized on ICI.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    b = local.boundaries
    s = local.sizes
    for ax in axis_names:
        b = jax.lax.all_gather(b, ax)
        s = jax.lax.all_gather(s, ax)
    b = b.reshape(-1, local.boundaries.shape[-1])
    s = s.reshape(-1, local.sizes.shape[-1])
    return merge(Histogram(b, s), beta)


def hierarchical_device_summary(
    x_local: jax.Array, tile_size: int, T_tile: int, T_device: int
) -> Histogram:
    """Tile-level summarize + merge on one device (level 0 of the hierarchy).

    The shard is cut into VMEM-sized tiles; each tile is summarized exactly
    (this is what the Pallas ``tile_sort`` kernel accelerates on real TPUs)
    and the per-tile summaries are merged into the device summary.  The tail
    that does not fill a tile forms one final smaller exact histogram.
    """
    flat = x_local.reshape(-1)
    n = flat.shape[0]
    n_tiles = n // tile_size
    if n_tiles == 0:
        return build_exact(flat, T_device)
    head = flat[: n_tiles * tile_size].reshape(n_tiles, tile_size)
    tiles = build_exact_batched(head, T_tile)
    rem = n - n_tiles * tile_size
    if rem > 0:
        tail = build_exact(flat[n_tiles * tile_size :], min(T_tile, rem))
        pad = T_tile - tail.sizes.shape[-1]
        tb = jnp.concatenate(
            [tail.boundaries, jnp.repeat(tail.boundaries[-1:], pad)]
        )
        ts = jnp.concatenate([tail.sizes, jnp.zeros((pad,), tail.sizes.dtype)])
        tiles = Histogram(
            jnp.concatenate([tiles.boundaries, tb[None]], axis=0),
            jnp.concatenate([tiles.sizes, ts[None]], axis=0),
        )
    return merge(tiles, T_device)


def _shard_map(fn, mesh, in_specs, out_specs):
    # Replication checking is off (check_vma / legacy check_rep) because the
    # merged output is replicated by construction (post-all_gather).
    if hasattr(jax, "shard_map"):  # public API from jax 0.5 on
        return jax.shard_map(
            fn,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _sm

    return _sm(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )


def distributed_histogram(
    x: jax.Array,
    T: int,
    beta: int,
    mesh: jax.sharding.Mesh,
    axis_names: str | tuple[str, ...] = "data",
) -> Histogram:
    """β-bucket histogram of ``x`` sharded over ``axis_names``.

    ``x``: any-rank array whose leading dim is sharded over ``axis_names``.
    Returns a replicated :class:`Histogram`.
    """
    if isinstance(axis_names, str):
        axis_names = (axis_names,)

    def body(x_local):
        local = local_summarize(x_local, T)
        return gather_and_merge(local, beta, axis_names)

    spec = P(axis_names)
    out = _shard_map(
        body,
        mesh,
        in_specs=(spec,),
        out_specs=Histogram(P(), P()),
    )(x)
    return out


def distributed_histogram_hierarchical(
    x: jax.Array,
    mesh: jax.sharding.Mesh,
    *,
    tile_size: int = 8192,
    T_tile: int = 512,
    T_device: int = 4096,
    T_pod: int = 4096,
    beta: int = 254,
    data_axes: tuple[str, ...] = ("data",),
    pod_axis: str | None = "pod",
) -> Histogram:
    """Three-level tile → device → pod merge (DESIGN.md §5).

    Composed error bound: ``ε < 2N(1/T_tile + 1/T_device [+ 1/T_pod])``.
    When ``pod_axis`` is absent from the mesh the last level collapses.
    """
    axis_names = tuple(data_axes) + (
        (pod_axis,) if pod_axis and pod_axis in mesh.axis_names else ()
    )

    def body(x_local):
        dev = hierarchical_device_summary(x_local, tile_size, T_tile, T_device)
        if pod_axis and pod_axis in mesh.axis_names:
            mid = gather_and_merge(dev, T_pod, tuple(data_axes))
            return gather_and_merge(mid, beta, (pod_axis,))
        return gather_and_merge(dev, beta, tuple(data_axes))

    spec = P(axis_names)
    return _shard_map(
        body, mesh, in_specs=(spec,), out_specs=Histogram(P(), P())
    )(x)


def tensor_histogram_in_step(
    x: jax.Array,
    T: int,
    beta: int,
    mesh: jax.sharding.Mesh,
    axis_names: tuple[str, ...],
) -> Histogram:
    """Histogram of an arbitrary (possibly sharded) tensor inside a jitted step.

    Flattens, truncates the tail so the length divides the mesh size (< one
    element per device dropped — negligible for telemetry and documented),
    lays the flat vector out across all mesh axes and runs the paper's
    summarize+merge.  The all-gather is ``O(k·T)`` bytes, so per-step
    telemetry of every layer's gradients is affordable — this is the paper's
    "cheap statistics over partitioned data" applied to the optimizer plane.
    """
    k = 1
    for ax in axis_names:
        k *= mesh.shape[ax]
    flat = x.reshape(-1)
    n = flat.shape[0]
    usable = max((n // k) * k, 0)
    if usable < k:  # tiny tensor: replicate instead of sharding
        h = build_exact(flat.astype(jnp.float32), min(T, max(n, 1)))
        return h
    flat = jax.lax.with_sharding_constraint(
        flat[:usable].astype(jnp.float32),
        jax.sharding.NamedSharding(mesh, P(axis_names)),
    )

    def body(x_local):
        local = local_summarize(x_local, min(T, usable // k))
        return gather_and_merge(local, beta, axis_names)

    return _shard_map(
        body, mesh, in_specs=(P(axis_names),), out_specs=Histogram(P(), P())
    )(flat)
