"""HistogramStore — the paper's Summarizer/Merger processing framework.

The paper's deployment (§5, Fig. 13): every new partition (a day of logs) is
summarized *once, offline* into a T-bucket exact histogram stored next to the
data; any time-interval query is answered *on demand* by merging the stored
summaries, never re-touching raw data.

This module is the host-side control plane of that framework:

  * ``HistogramStore.ingest(partition_id, values)``  — the Summarizer job
  * ``HistogramStore.query(lo, hi, beta)``           — the Merger job
  * npz persistence                                   — the HDFS summary files

It is deliberately NumPy/host-resident (like the NameNode metadata path);
the heavy lifting — per-partition sort — runs through the jitted JAX
``build_exact`` (or the distributed/hierarchical variants for sharded
partitions).  In the training framework the same store tracks per-step
summaries of step times and gradient statistics (core/telemetry.py).
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Iterable

import jax
import numpy as np

from repro.core.histogram import (
    Histogram,
    build_exact,
    merge_list,
    quantile,
    theoretical_eps_max,
)

__all__ = ["StoredSummary", "HistogramStore"]


@dataclass(frozen=True)
class StoredSummary:
    """One partition's summary — a row of the paper's summary file."""

    partition_id: int
    n: int
    boundaries: np.ndarray
    sizes: np.ndarray

    def to_histogram(self) -> Histogram:
        return Histogram(
            boundaries=jax.numpy.asarray(self.boundaries),
            sizes=jax.numpy.asarray(self.sizes),
        )


@dataclass
class HistogramStore:
    """Append-only store of per-partition exact equi-depth summaries."""

    num_buckets: int  # T — summary resolution; pick T ≥ 40·β for ≤5 % error
    summaries: dict[int, StoredSummary] = field(default_factory=dict)

    # ----------------------------------------------------------- Summarizer
    def ingest(self, partition_id: int, values) -> StoredSummary:
        """Summarize one new partition (the scheduled Summarizer job)."""
        values = np.asarray(values).reshape(-1)
        T = min(self.num_buckets, values.shape[0])
        h = build_exact(jax.numpy.asarray(values), T)
        summ = StoredSummary(
            partition_id=int(partition_id),
            n=int(values.shape[0]),
            boundaries=np.asarray(h.boundaries),
            sizes=np.asarray(h.sizes),
        )
        self.summaries[int(partition_id)] = summ
        return summ

    def ingest_summary(self, partition_id: int, hist: Histogram) -> None:
        """Store an externally-built summary (e.g. from the distributed or
        Pallas tile path) — the framework does not care who summarized."""
        self.summaries[int(partition_id)] = StoredSummary(
            partition_id=int(partition_id),
            n=int(np.asarray(hist.sizes).sum()),
            boundaries=np.asarray(hist.boundaries),
            sizes=np.asarray(hist.sizes),
        )

    # --------------------------------------------------------------- Merger
    def query(
        self, lo: int, hi: int, beta: int, *, strict: bool = True
    ) -> tuple[Histogram, float]:
        """β-bucket histogram over partitions ``lo..hi`` (inclusive).

        Returns ``(histogram, eps_max)`` where ``eps_max`` is the paper's
        guaranteed maximum bucket/range-size error for this answer.  With
        ``strict=False`` missing partitions are skipped (summary-loss
        tolerance: a lost shard degrades the answer instead of failing it).
        """
        ids = [i for i in range(lo, hi + 1) if i in self.summaries]
        if strict and len(ids) != hi - lo + 1:
            missing = sorted(set(range(lo, hi + 1)) - set(ids))
            raise KeyError(f"missing partition summaries: {missing}")
        if not ids:
            raise KeyError("no partition summaries in requested interval")
        hs = [self.summaries[i].to_histogram() for i in ids]
        merged = merge_list(hs, beta)
        n = sum(self.summaries[i].n for i in ids)
        eps = theoretical_eps_max(
            n, self.num_buckets, k=len(ids), exact_inputs=False
        )
        return merged, eps

    def quantile_query(
        self, lo: int, hi: int, q, beta: int | None = None
    ) -> np.ndarray:
        """e.g. the paper's motivating '95th-percentile latency for any
        interval': ``store.quantile_query(day0, day1, 0.95)``."""
        beta = beta or min(self.num_buckets, 254)
        h, _ = self.query(lo, hi, beta, strict=False)
        return np.asarray(quantile(h, np.asarray(q)))

    # ---------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Atomic write (tmpfile + rename) — summary files survive crashes."""
        payload = {}
        meta = {"num_buckets": self.num_buckets, "ids": sorted(self.summaries)}
        for pid, s in self.summaries.items():
            payload[f"b_{pid}"] = s.boundaries
            payload[f"s_{pid}"] = s.sizes
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        os.close(fd)
        np.savez(tmp, meta=json.dumps(meta), **payload)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)

    @classmethod
    def load(cls, path: str) -> "HistogramStore":
        data = np.load(path, allow_pickle=False)
        meta = json.loads(str(data["meta"]))
        store = cls(num_buckets=int(meta["num_buckets"]))
        for pid in meta["ids"]:
            b = data[f"b_{pid}"]
            s = data[f"s_{pid}"]
            store.summaries[int(pid)] = StoredSummary(
                partition_id=int(pid),
                n=int(s.sum()),
                boundaries=b,
                sizes=s,
            )
        return store

    # ------------------------------------------------------------- utility
    def ids(self) -> list[int]:
        return sorted(self.summaries)

    def total_n(self, ids: Iterable[int] | None = None) -> int:
        ids = list(ids) if ids is not None else self.ids()
        return sum(self.summaries[i].n for i in ids)
