"""HistogramStore — the paper's Summarizer/Merger processing framework.

The paper's deployment (§5, Fig. 13): every new partition (a day of logs) is
summarized *once, offline* into a T-bucket exact histogram stored next to the
data; any time-interval query is answered *on demand* by merging the stored
summaries, never re-touching raw data.

This module is the host-side control plane of that framework:

  * ``HistogramStore.ingest(partition_id, values)``  — the Summarizer job
  * ``HistogramStore.query(lo, hi, beta)``           — the Merger job
  * npz persistence                                   — the HDFS summary files

The Merger runs on a **segment-tree interval engine** by default
(``core/interval_tree.py``): internal tree nodes hold pre-merged summaries,
so a query merges ``O(log W)`` node summaries instead of re-merging the whole
``O(W)`` window flat, answers are LRU-cached per store version, and
``query_many`` serves a whole batch of concurrent interval queries with one
static-shape jitted merge.  ``engine="flat"`` keeps the paper-literal path
(and its tighter single-level bound) for comparison and benchmarks.

It is deliberately NumPy/host-resident (like the NameNode metadata path);
the heavy lifting — per-partition sort — runs through the jitted JAX
``build_exact`` (or the distributed/hierarchical variants for sharded
partitions).  In the training framework the same store tracks per-step
summaries of step times and gradient statistics (core/telemetry.py).
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import jax
import numpy as np

from repro.core.histogram import (
    Histogram,
    build_exact,
    merge_list,
    quantile,
    theoretical_eps_max,
)
from repro.core.interval_tree import IntervalTree

__all__ = ["StoredSummary", "HistogramStore"]


@dataclass(frozen=True)
class StoredSummary:
    """One partition's summary — a row of the paper's summary file."""

    partition_id: int
    n: int
    boundaries: np.ndarray
    sizes: np.ndarray

    def to_histogram(self) -> Histogram:
        return Histogram(
            boundaries=jax.numpy.asarray(self.boundaries),
            sizes=jax.numpy.asarray(self.sizes),
        )


@dataclass
class HistogramStore:
    """Append-only store of per-partition exact equi-depth summaries."""

    num_buckets: int  # T — summary resolution; pick T ≥ 40·β for ≤5 % error
    summaries: dict[int, StoredSummary] = field(default_factory=dict)
    engine: str = "tree"  # default Merger path: "tree" | "flat"
    T_node: int | None = None  # internal-node resolution (default: T)
    cache_size: int = 128  # LRU capacity of the tree's answer cache
    _tree: IntervalTree = field(init=False, repr=False, default=None)

    def __post_init__(self) -> None:
        self._tree = IntervalTree(
            self.T_node or self.num_buckets, cache_size=self.cache_size
        )
        for pid, s in self.summaries.items():
            self._tree.set_leaf(pid, s.boundaries, s.sizes)

    @property
    def version(self) -> int:
        """Bumps on every mutation — keys the interval engine's LRU cache."""
        return self._tree.version

    # ----------------------------------------------------------- Summarizer
    def _summarize(self, partition_id: int, values) -> StoredSummary:
        values = np.asarray(values).reshape(-1)
        T = min(self.num_buckets, values.shape[0])
        h = build_exact(jax.numpy.asarray(values), T)
        return StoredSummary(
            partition_id=int(partition_id),
            n=int(values.shape[0]),
            boundaries=np.asarray(h.boundaries),
            sizes=np.asarray(h.sizes),
        )

    def ingest(self, partition_id: int, values) -> StoredSummary:
        """Summarize one new partition (the scheduled Summarizer job)."""
        summ = self._summarize(partition_id, values)
        self._put(summ)
        return summ

    def ingest_summary(self, partition_id: int, hist: Histogram) -> None:
        """Store an externally-built summary (e.g. from the distributed or
        Pallas tile path) — the framework does not care who summarized."""
        self._put(
            StoredSummary(
                partition_id=int(partition_id),
                n=int(np.asarray(hist.sizes).sum()),
                boundaries=np.asarray(hist.boundaries),
                sizes=np.asarray(hist.sizes),
            )
        )

    def ingest_many(self, partitions: dict[int, "np.ndarray"]) -> None:
        """Bulk-summarize many partitions, then build the tree level-batched
        (``log W`` XLA dispatches) instead of per-ingest incremental."""
        for pid, values in partitions.items():
            summ = self._summarize(pid, values)
            self.summaries[summ.partition_id] = summ
        self.rebuild_tree()

    def _put(self, summ: StoredSummary) -> None:
        self.summaries[summ.partition_id] = summ
        self._tree.set_leaf(summ.partition_id, summ.boundaries, summ.sizes)

    def rebuild_tree(self) -> None:
        self._tree.rebuild(
            {p: (s.boundaries, s.sizes) for p, s in self.summaries.items()}
        )

    def _sync_tree(self, ids: list[int], lo: int, hi: int) -> None:
        """Re-sync after direct ``summaries`` dict mutation (the documented
        summary-loss idiom ``del store.summaries[pid]``, or outright row
        replacement).  Every tree leaf shares its arrays with the stored
        summary, so staleness detection is an O(interval) pointer-identity
        scan — the price of supporting raw dict mutation on the hot path;
        callers that only mutate through ingest* never trigger a rebuild.
        Replaced leaves are re-pointed incrementally (O(log W) merges each);
        deletions rebuild level-batched."""
        tree = self._tree
        stale = []
        for pid in ids:
            node = None
            if tree.base is not None and 0 <= pid - tree.base < tree.capacity:
                node = tree.nodes.get((0, pid - tree.base))
            s = self.summaries[pid]
            if (
                node is None
                or node.boundaries is not s.boundaries
                or node.sizes is not s.sizes
            ):
                stale.append(pid)
        for pid in stale:
            s = self.summaries[pid]
            tree.set_leaf(pid, s.boundaries, s.sizes)
        sel = tree.decompose(lo, hi)
        if sum(tree.nodes[k].leaves for k in sel) != len(ids):
            self.rebuild_tree()  # leaves were deleted from the dict

    # --------------------------------------------------------------- Merger
    def query(
        self,
        lo: int,
        hi: int,
        beta: int,
        *,
        strict: bool = True,
        engine: str | None = None,
    ) -> tuple[Histogram, float]:
        """β-bucket histogram over partitions ``lo..hi`` (inclusive).

        Returns ``(histogram, eps_max)`` where ``eps_max`` is the guaranteed
        maximum bucket/range-size error of *this* answer: the segment-tree
        engine reports its composed per-level bound, the flat engine the
        paper's single-level ``2N/T + 2k``.  With ``strict=False`` missing
        partitions are skipped (summary-loss tolerance: a lost shard degrades
        the answer instead of failing it).
        """
        ids = [i for i in range(lo, hi + 1) if i in self.summaries]
        if strict and len(ids) != hi - lo + 1:
            missing = sorted(set(range(lo, hi + 1)) - set(ids))
            raise KeyError(f"missing partition summaries: {missing}")
        if not ids:
            raise KeyError("no partition summaries in requested interval")
        if (engine or self.engine) == "tree":
            self._sync_tree(ids, lo, hi)
            return self._tree.query(lo, hi, beta)
        hs = [self.summaries[i].to_histogram() for i in ids]
        merged = merge_list(hs, beta)
        n = sum(self.summaries[i].n for i in ids)
        eps = theoretical_eps_max(
            n, self.num_buckets, k=len(ids), exact_inputs=False
        )
        return merged, eps

    def query_many(
        self,
        intervals: Sequence[tuple[int, int]],
        beta: int,
        *,
        strict: bool = True,
    ) -> list[tuple[Histogram, float]]:
        """Answer a batch of interval queries with one jitted merge.

        The serving path for many concurrent users: every query's canonical
        node set is padded to one static shape, so the whole batch costs a
        single XLA dispatch regardless of the mix of window lengths.
        ``strict`` behaves exactly as in :meth:`query` (and defaults the
        same way): missing partitions raise unless ``strict=False``.
        """
        for lo, hi in intervals:
            ids = [i for i in range(lo, hi + 1) if i in self.summaries]
            if strict and len(ids) != hi - lo + 1:
                missing = sorted(set(range(lo, hi + 1)) - set(ids))
                raise KeyError(f"missing partition summaries: {missing}")
            self._sync_tree(ids, lo, hi)
        return self._tree.query_many(intervals, beta)

    def quantile_query(
        self, lo: int, hi: int, q, beta: int | None = None
    ) -> np.ndarray:
        """e.g. the paper's motivating '95th-percentile latency for any
        interval': ``store.quantile_query(day0, day1, 0.95)``."""
        beta = beta or min(self.num_buckets, 254)
        h, _ = self.query(lo, hi, beta, strict=False)
        return np.asarray(quantile(h, np.asarray(q)))

    # ---------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        """Atomic write (tmpfile + rename) — summary files survive crashes.

        Persists the pre-merged tree nodes next to the leaf summaries so a
        reloaded store serves interval queries without re-merging anything.
        """
        payload = {}
        tree_meta, tree_arrays = self._tree.state()
        meta = {
            "num_buckets": self.num_buckets,
            "ids": sorted(self.summaries),
            "n": {str(p): s.n for p, s in self.summaries.items()},
            "tree": tree_meta,
        }
        for pid, s in self.summaries.items():
            payload[f"b_{pid}"] = s.boundaries
            payload[f"s_{pid}"] = s.sizes
        payload.update(tree_arrays)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        os.close(fd)
        np.savez(tmp, meta=json.dumps(meta), **payload)
        os.replace(tmp + ".npz" if os.path.exists(tmp + ".npz") else tmp, path)

    @classmethod
    def load(cls, path: str) -> "HistogramStore":
        data = np.load(path, allow_pickle=False)
        meta = json.loads(str(data["meta"]))
        store = cls(num_buckets=int(meta["num_buckets"]))
        for pid in meta["ids"]:
            b = data[f"b_{pid}"]
            s = data[f"s_{pid}"]
            store.summaries[int(pid)] = StoredSummary(
                partition_id=int(pid),
                n=int(meta.get("n", {}).get(str(pid), s.sum())),
                boundaries=b,
                sizes=s,
            )
        if "tree" in meta:  # restore pre-merged nodes — no re-merge on load
            store._tree = IntervalTree.from_state(
                meta["tree"], data, cache_size=store.cache_size
            )
            # share leaf storage with the summary rows so _sync_tree's
            # pointer-identity staleness scan passes without re-merging
            for pid, s in store.summaries.items():
                store._tree.adopt_leaf_arrays(pid, s.boundaries, s.sizes)
        else:  # summary file from an older layout: rebuild level-batched
            store.rebuild_tree()
        return store

    # ------------------------------------------------------------- utility
    def ids(self) -> list[int]:
        return sorted(self.summaries)

    def total_n(self, ids: Iterable[int] | None = None) -> int:
        ids = list(ids) if ids is not None else self.ids()
        return sum(self.summaries[i].n for i in ids)

    def cache_stats(self) -> dict[str, int]:
        return {
            "hits": self._tree.cache_hits,
            "misses": self._tree.cache_misses,
            "version": self._tree.version,
        }
