"""HistogramStore — the paper's Summarizer/Merger processing framework.

The paper's deployment (§5, Fig. 13): every new partition (a day of logs) is
summarized *once, offline* into a T-bucket exact histogram stored next to the
data; any time-interval query is answered *on demand* by merging the stored
summaries, never re-touching raw data.

This module is the host-side control plane of that framework:

  * ``HistogramStore.ingest(partition_id, values)``  — the Summarizer job
  * ``HistogramStore.query(lo, hi, beta)``           — the Merger job
  * npz persistence                                   — the HDFS summary files

The Merger runs on a **segment-tree interval engine** by default
(``core/interval_tree.py``): internal tree nodes hold pre-merged summaries,
so a query merges ``O(log W)`` node summaries instead of re-merging the whole
``O(W)`` window flat, answers are LRU-cached per store version, and
``query_many`` serves a whole batch of concurrent interval queries with one
static-shape jitted merge.  ``engine="flat"`` keeps the paper-literal path
(and its tighter single-level bound) for comparison and benchmarks.

The Summarizer is **shape-stable and batched**: partitions are padded with a
+inf sentinel to power-of-two length buckets and summarized through the
mask-aware ``build_exact_padded`` (bit-identical to the per-length exact
build), so any mix of partition lengths compiles O(log max_n) XLA programs
instead of one per distinct length, and ``ingest_many`` groups partitions by
padded shape and summarizes each group with **one vmapped dispatch**.

Async ingest consistency model
------------------------------
With ``async_ingest=True`` (or via ``ingest_async``) partitions are pushed
onto a bounded queue and a background maintenance thread drains it in
batches: each drained batch is summarized with the grouped one-dispatch
summarizer, then applied to the store — leaves written and the tree's
ancestor paths refreshed with *one* level-batched pull-up per flush — under
the store lock, bumping the version once per batch.  Guarantees:

  * **Snapshot consistency** — queries take the same lock as batch
    application, so every answer reflects a complete set of applied
    batches (never a half-applied batch), with ``eps`` computed from
    exactly that snapshot's tree; the version key makes cached answers
    equally consistent.
  * **Prefix visibility** — batches are drained FIFO, so the visible
    partition set is always a prefix of the enqueue order.
  * **Explicit freshness** — ``flush()`` blocks until everything enqueued
    so far is visible (and re-raises any background summarization error);
    ``close()`` stops the worker after a final drain.  Nothing is
    timing-dependent: synchronization is by lock/condition only.
  * **Retention between flushes** — with a ``retention`` policy
    (core/retention.py) the watermark-driven sweeper runs on the ingest
    worker after each applied batch and *before* the pending count drops,
    so ``flush()`` returning also implies retention has been enforced on
    everything visible (synchronous ingest sweeps inline after each
    apply).  Eviction bumps the store version, so answers cached before
    an eviction can never be served after it.

The drain/poison-isolation/flush/close machinery itself is the shared
:class:`~repro.core.workers.IngestPool` — one lock-sensitive
implementation for this store's single worker and the multi-tenant
registry's pool alike.

Durable ingest (``wal_dir=...``)
--------------------------------
The queue above is in-memory: without a log, a crash between ``ingest``
and ``save`` silently loses acked partitions.  With ``wal_dir`` every
ingest — sync or async — appends a checksummed record to a segmented
write-ahead log and fsyncs (group commit) **before the call returns**;
``save`` captures the log's applied watermark, persists it, and
truncates fully-covered segments; ``load(path, wal_dir=...)`` /
``recover(path, wal_dir, ...)`` replay the uncovered suffix with
idempotent pid dedup reconciled against the retention watermark.  Record
layout, fsync-batching policy, truncation-on-save invariant, and the
idempotent-replay contract are documented in core/workers.py.

Watermark persistence format
----------------------------
Retention ages partitions against the **watermark** — the highest
partition id ever ingested (ids are the time axis; see
core/retention.py).  It is persisted as the ``"watermark"`` key of the
:meth:`HistogramStore._state` meta dict (json int, or null for an empty
store) next to ``"ids"``/``"n"``/``"tree"``, and restored by
:meth:`_restore` (falling back to ``max(ids)`` for summary files written
before this key existed).  The retention policy itself round-trips
through ``save``/``load`` as the json spec ``meta["retention"]``
(``RetentionPolicy.spec()`` / ``policy_from_spec``), so a reloaded store
resumes aging exactly where it stopped instead of resurrecting expired
partitions — the registry's one-npz container persists both per tenant
the same way.

It is deliberately NumPy/host-resident (like the NameNode metadata path);
the heavy lifting — per-partition sort — runs through the jitted JAX
``build_exact_padded`` (or the distributed/hierarchical variants for sharded
partitions).  In the training framework the same store tracks per-step
summaries of step times and gradient statistics (core/telemetry.py).
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from dataclasses import dataclass, field
from typing import Iterable, Sequence

import jax
import numpy as np

from repro.core.histogram import (
    Histogram,
    build_exact,
    build_exact_padded_batched,
    merge_list,
    next_pow2,
    pad_pow2,
    quantile,
    theoretical_eps_max,
)
from repro.analysis.witness import OrderedRLock
from repro.core import faults
from repro.core.arena import NodeArena
from repro.core.interval_tree import COLLAPSE_MODES, IntervalTree
from repro.core.retention import RetentionPolicy, StoreStats, policy_from_spec
from repro.core.scrub import checksum_array, payload_checksums
from repro.core.workers import IngestPool, PoolStateView, WriteAheadLog

__all__ = ["StoredSummary", "HistogramStore", "atomic_savez"]


def _validated(values) -> np.ndarray:
    """Flatten + reject empty — the synchronous ingest validation rule."""
    v = np.asarray(values).reshape(-1)
    if v.shape[0] < 1:
        raise ValueError("cannot summarize an empty partition")
    return v


def atomic_savez(path: str, meta: dict, payload: dict[str, np.ndarray]) -> None:
    """Crash-safe npz write: mkstemp + fd write + fsync + atomic rename.

    Writing through the open fd keeps np.savez from appending its implicit
    ``.npz`` suffix (no stray twin files); the rename makes readers see
    either the old file or the complete new one.  Two fsyncs make that
    hold across power loss, not just process death: the temp file's fd is
    fsynced *before* ``os.replace`` (otherwise the rename can land while
    the data blocks are still dirty, leaving a zero-length "atomically
    saved" file), and the containing directory's fd is fsynced *after*
    (otherwise the rename itself may not be durable and the file simply
    vanishes).  Shared by ``HistogramStore.save`` and the multi-tenant
    registry's one-file-for-all-tenants save (core/tenant.py).

    Every payload array's CRC32 is embedded as ``meta["payload_crc"]``
    so the integrity scrubber (core/scrub.py) can prove a snapshot is
    still the bytes that were written — atomicity protects against torn
    writes, the checksums against the bit-rot that atomicity can't see.
    """
    faults.hit("snapshot.save", path=path)
    meta = {**meta, "payload_crc": payload_checksums(payload)}
    dirname = os.path.dirname(path) or "."
    os.makedirs(dirname, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=dirname, suffix=".npz")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, meta=json.dumps(meta), **payload)
            f.flush()
            os.fsync(f.fileno())  # data durable before the rename
        rot = faults.hit("snapshot.save.corrupt", path=path)
        if rot is not None:  # injected bit-rot that survives the rename
            with open(tmp, "r+b") as f:
                f.seek(int(rot))
                f.write(b"\xde\xad\xbe\xef")
        os.replace(tmp, path)
        dfd = os.open(dirname, os.O_RDONLY)
        try:
            os.fsync(dfd)  # the rename durable too
        finally:
            os.close(dfd)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


class _PrefixedArrays:
    """Key-prefixing view over an npz/dict — lets ``IntervalTree.from_state``
    read its ``tb_*``/``ts_*`` arrays out of a namespaced container."""

    def __init__(self, data, prefix: str):
        self._data = data
        self._prefix = prefix

    def __getitem__(self, key: str):
        return self._data[self._prefix + key]


class _VersionedDict(dict):
    """``summaries`` dict that counts its own mutations.

    The documented summary-loss idiom mutates the dict directly
    (``del store.summaries[pid]``, row replacement), which is why every
    query used to re-scan its whole interval for tree/dict desync.  The
    mutation counter turns that into an O(1) staleness token: the scan
    (and the sorted-ids cache below) re-runs only when the counter moved
    since it last verified — zero per-query cost on the hot serving path.
    Mutating through ``dict.__setitem__`` directly on the instance is the
    one way around the counter, and is out of contract.
    """

    __slots__ = ("mutations",)

    def __init__(self, *a, **k):
        super().__init__(*a, **k)
        self.mutations = 0

    def __setitem__(self, key, value):
        self.mutations += 1
        super().__setitem__(key, value)

    def __delitem__(self, key):
        self.mutations += 1
        super().__delitem__(key)

    def update(self, *a, **k):
        self.mutations += 1
        super().update(*a, **k)

    def pop(self, *a):
        self.mutations += 1
        return super().pop(*a)

    def popitem(self):
        self.mutations += 1
        return super().popitem()

    def clear(self):
        self.mutations += 1
        super().clear()

    def setdefault(self, key, default=None):
        self.mutations += 1
        return super().setdefault(key, default)

# Max rows per batched-summarizer dispatch.  Chunking the batch axis keeps
# the power-of-two row padding waste ≤ ~12 % on large groups (padding 579
# rows straight to 1024 would nearly double the sort work) while the set of
# compiled shapes stays O(log k · log max_n).
_BATCH_ROWS = 256


@dataclass(frozen=True)
class StoredSummary:
    """One partition's summary — a row of the paper's summary file.

    ``crc`` is the CRC32 of the summary arrays at summarize time — the
    in-memory integrity baseline the scrubber (core/scrub.py) verifies
    rows and arena planes against.  ``None`` marks a summary injected
    through a legacy path that never checksummed (unverifiable, not
    corrupt).
    """

    partition_id: int
    n: int
    boundaries: np.ndarray
    sizes: np.ndarray
    crc: int | None = None

    def to_histogram(self) -> Histogram:
        return Histogram(
            boundaries=jax.numpy.asarray(self.boundaries),
            sizes=jax.numpy.asarray(self.sizes),
        )


def _make_summary(pid: int, n: int, boundaries, sizes) -> StoredSummary:
    """StoredSummary with its integrity CRC stamped over the exact arrays
    being stored (scrub_store recomputes over the same attributes)."""
    return StoredSummary(
        partition_id=int(pid),
        n=int(n),
        boundaries=boundaries,
        sizes=sizes,
        crc=checksum_array(boundaries, sizes),
    )


@dataclass
class HistogramStore(PoolStateView):
    """Store of per-partition exact equi-depth summaries (append-only by
    default; a ``retention`` policy bounds it for infinite streams)."""

    num_buckets: int  # T — summary resolution; pick T ≥ 40·β for ≤5 % error
    summaries: dict[int, StoredSummary] = field(default_factory=dict)
    engine: str = "tree"  # default Merger path: "tree" | "flat"
    # internal-node resolution: None → T uniform; an int → that resolution
    # uniform; "geometric" → T·2^level per level (depth-independent ε bound)
    T_node: int | str | None = None
    cache_size: int = 128  # LRU capacity of the tree's answer cache
    async_ingest: bool = False  # route ``ingest`` through the background queue
    queue_size: int = 1024  # bound of the pending-partition queue
    # retention policy (core/retention.py): None → append-only (unbounded)
    retention: RetentionPolicy | None = None
    # eviction collapse policy: "canonical" keeps post-eviction trees
    # bit-identical to a fresh build over the survivors; "amortized" defers
    # the re-root behind a dead-prefix slack — O(log W) amortized merge
    # work per ingest for high-frequency sliding windows, answers still
    # within eps_total (IntervalTree._collapse documents the trade)
    collapse: str = "canonical"
    # pooled node storage (core/arena.py): None → the tree owns its own
    # arena; a TenantRegistry(shared_arena=True) passes one shared arena
    # to every tenant so cross-tenant packs become a single device gather
    arena: NodeArena | None = None
    # durable ingest (core/workers.py WriteAheadLog): a directory path
    # makes every ingest — sync or async — append + fsync a log record
    # before it acks, so an acked partition survives a crash between
    # ingest and save.  ``save`` truncates log segments covered by the
    # snapshot; ``load(path, wal_dir=...)`` / ``recover`` replay the
    # uncovered suffix with idempotent pid dedup.  The constructor never
    # replays leftover segments itself (replay needs the snapshot's
    # summaries/watermark as its dedup baseline) — use ``recover``.
    wal_dir: str | None = None
    _tree: IntervalTree = field(init=False, repr=False, default=None)

    def __post_init__(self) -> None:
        if isinstance(self.T_node, str) and self.T_node != "geometric":
            raise ValueError(f"unknown T_node mode: {self.T_node!r}")
        if self.collapse not in COLLAPSE_MODES:
            raise ValueError(f"unknown collapse mode: {self.collapse!r}")
        geometric = self.T_node == "geometric"
        self._tree = IntervalTree(
            self.num_buckets
            if (self.T_node is None or geometric)
            else self.T_node,
            cache_size=self.cache_size,
            geometric=geometric,
            arena=self.arena,
            collapse=self.collapse,
        )
        # distinct (k_pad, n_pad, T) summarizer dispatch shapes seen so far —
        # observability for the compile-stability tests and benchmarks
        self.summarize_shapes: set[tuple[int, int, int]] = set()
        # guards summaries + tree + queries.  Standalone stores carry no
        # key; TenantRegistry.tenant() keys the lock by tenant name so the
        # witness can check the sorted multi-store acquisition contract
        self._lock = OrderedRLock("store._lock")
        # mutation-counted dict + staleness tokens: queries verify
        # tree/dict sync once per (dict mutation, tree version) state
        # instead of re-scanning their interval every time (_sync_tree)
        self.summaries = _VersionedDict(self.summaries)
        self._sync_token: tuple[int, int] | None = None
        self._ids_cache: tuple[int, np.ndarray] | None = None
        # highest partition id ever ingested — the retention watermark
        # (persisted; survives the eviction of the partitions beneath it)
        self._watermark: int | None = (
            max(self.summaries) if self.summaries else None
        )
        # stats of the last WAL replay (recover/load), None until then
        self.last_recovery: dict | None = None
        # durable-ingest log (None → in-memory-only queue, the historical
        # contract); single-store records carry no tenant route
        self._wal: WriteAheadLog | None = (
            WriteAheadLog(self.wal_dir) if self.wal_dir is not None else None
        )
        # the background ingest plane: shared drain/poison-isolation/flush
        # machinery (core/workers.py); threads start lazily on first enqueue.
        # on_batch_end runs the retention sweeper on the worker between
        # flushes, before the pending count drops.
        self._pool = IngestPool(
            apply_batch=self._apply_worker_batch,
            wrap_error=self._wrap_async_error,
            workers=1,
            queue_size=self.queue_size,
            name="histstore-ingest",
            on_batch_end=self._sweep_after_batch,
            wal=self._wal,
            wal_record=lambda item: (None, item[0], item[1]),
        )
        for pid, s in self.summaries.items():
            self._tree.set_leaf(pid, s.boundaries, s.sizes)

    # (PoolStateView provides _cv/_pending/_ingest_mutex onto the pool)
    @property
    def _async_errors(self) -> list:
        """Every failed partition since the last flush: [(pid, exception)];
        a ``(None, exception)`` entry is a failed retention sweep."""
        return self._pool.errors

    @_async_errors.setter
    def _async_errors(self, value: list) -> None:
        self._pool.errors = value

    @property
    def version(self) -> int:
        """Bumps on every mutation — keys the interval engine's LRU cache."""
        return self._tree.version

    @property
    def watermark(self) -> int | None:
        """Highest partition id ever ingested (monotonic; drives TTL)."""
        return self._watermark

    # ----------------------------------------------------------- Summarizer
    def _summarize_batch(self, parts: dict[int, np.ndarray]) -> dict[int, StoredSummary]:
        """Summarize many partitions with O(#shape buckets) dispatches.

        Partitions are padded to power-of-two length buckets and each bucket
        is summarized by ONE vmapped ``build_exact_padded_batched`` call
        (its batch axis padded to a power of two as well, so the jit cache
        holds O(log k_max · log max_n) executables total).  Results are
        bit-identical to the per-partition ``build_exact`` path.
        """
        out: dict[int, StoredSummary] = {}
        small: list[tuple[int, np.ndarray]] = []
        groups: dict[int, list[tuple[int, np.ndarray, int]]] = {}
        for pid, values in parts.items():
            v = np.asarray(values).reshape(-1)
            if v.shape[0] < 1:
                raise ValueError("cannot summarize an empty partition")
            if v.shape[0] < self.num_buckets:
                # tiny partition: summarized exactly at T = n (legacy rule)
                small.append((int(pid), v))
            else:
                padded, n = pad_pow2(v)
                groups.setdefault(padded.shape[0], []).append(
                    (int(pid), padded, n)
                )
        for pid, v in small:
            h = build_exact(jax.numpy.asarray(v), v.shape[0])
            out[pid] = _make_summary(
                pid, v.shape[0], np.asarray(h.boundaries), np.asarray(h.sizes)
            )
        for n_pad, all_rows in sorted(groups.items()):
            for at in range(0, len(all_rows), _BATCH_ROWS):
                rows = all_rows[at : at + _BATCH_ROWS]
                k = len(rows)
                k_pad = next_pow2(k)
                stack = np.stack(
                    [r[1] for r in rows] + [rows[-1][1]] * (k_pad - k)
                )
                ns = np.asarray(
                    [r[2] for r in rows] + [rows[-1][2]] * (k_pad - k),
                    np.int32,
                )
                self.summarize_shapes.add((k_pad, n_pad, self.num_buckets))
                h = build_exact_padded_batched(
                    jax.numpy.asarray(stack), ns, self.num_buckets
                )
                bs, ss = np.asarray(h.boundaries), np.asarray(h.sizes)
                for row, (pid, _, n) in enumerate(rows):
                    out[pid] = _make_summary(pid, n, bs[row], ss[row])
        return out

    def _summarize(self, partition_id: int, values) -> StoredSummary:
        pid = int(partition_id)
        return self._summarize_batch({pid: values})[pid]

    def ingest(self, partition_id: int, values) -> StoredSummary | None:
        """Summarize one new partition (the scheduled Summarizer job).

        With ``async_ingest=True`` the partition is enqueued for the
        background worker instead and ``None`` is returned — call
        :meth:`flush` for visibility.
        """
        if self.async_ingest:
            self.ingest_async(partition_id, values)
            return None
        v = _validated(values)
        lsns = self._wal_log_sync({int(partition_id): v})
        summ = self._summarize(partition_id, v)
        self._put(summ)
        if self._wal is not None:
            self._wal.mark_applied(lsns)
        return summ

    def ingest_summary(self, partition_id: int, hist: Histogram) -> None:
        """Store an externally-built summary (e.g. from the distributed or
        Pallas tile path) — the framework does not care who summarized."""
        self._put(
            _make_summary(
                int(partition_id),
                int(np.asarray(hist.sizes).sum()),
                np.asarray(hist.boundaries),
                np.asarray(hist.sizes),
            )
        )

    def ingest_many(self, partitions: dict[int, "np.ndarray"]) -> None:
        """Bulk-summarize many partitions — grouped one-dispatch summaries
        plus a single level-batched tree maintenance pass (``log W`` XLA
        dispatches total) instead of per-partition work.

        With ``async_ingest=True`` the batch is *enqueued* (input-validated
        synchronously, like :meth:`ingest_async` — and all-or-nothing, so a
        bad partition fails the call before anything is enqueued) instead
        of applied in-line, preserving FIFO prefix visibility with respect
        to every other enqueued partition — a synchronous bulk apply here
        could make later partitions visible before earlier queued ones.
        The worker drains the whole batch into one grouped summarization;
        call :meth:`flush` for visibility.
        """
        validated = {
            int(pid): _validated(values) for pid, values in partitions.items()
        }
        if self.async_ingest:
            for pid, v in validated.items():
                self._enqueue(pid, v)
            return
        # sync durable path: the whole batch is appended with ONE group-
        # commit fsync (the WAL's fsync-batching policy), then applied
        lsns = self._wal_log_sync(validated)
        self._apply(self._summarize_batch(validated))
        if self._wal is not None:
            self._wal.mark_applied(lsns)
        self._maybe_sweep()

    def _put(self, summ: StoredSummary) -> None:
        self._apply({summ.partition_id: summ})
        self._maybe_sweep()

    def _apply(self, summs: dict[int, StoredSummary]) -> None:
        """Make a batch of summaries visible atomically (one version bump)."""
        if not summs:
            return
        with self._lock:
            self.summaries.update(summs)
            newest = max(summs)
            if self._watermark is None or newest > self._watermark:
                self._watermark = newest
            self._tree.set_leaves(
                {pid: (s.boundaries, s.sizes) for pid, s in summs.items()}
            )

    def _apply_deferred(self, summs: dict[int, StoredSummary]):
        """:meth:`_apply` minus the pull-up and version bump: write the
        summaries + leaf rows now, return ``(tree, dirty_slots)`` so the
        registry's shared-arena batched apply can pull up *all* touched
        trees with one merge dispatch per level and invalidate each once.
        Caller holds ``_lock`` (and keeps holding it through the pull-up);
        ``dirty_slots`` is ``None`` when a below-base id forced an inline
        rebuild (that path already left the tree consistent).
        """
        self.summaries.update(summs)
        newest = max(summs)
        if self._watermark is None or newest > self._watermark:
            self._watermark = newest
        dirty = self._tree._write_leaves(
            {pid: (s.boundaries, s.sizes) for pid, s in summs.items()}
        )
        return self._tree, dirty

    def rebuild_tree(self) -> None:
        with self._lock:
            self._tree.rebuild(
                {p: (s.boundaries, s.sizes) for p, s in self.summaries.items()}
            )

    # ------------------------------------------------------------ retention
    def evict(self, partition_ids: Iterable[int]) -> list[int]:
        """Drop partitions from the store: summaries and tree leaves leave
        together (``set_leaf``'s pull-up in reverse, with lazy subtree
        collapse), with one version bump — cached answers from before the
        eviction can never be served after it.  Returns the partition ids
        actually evicted (absent ids are ignored).  The watermark does NOT
        move: evicted history stays expired after a save/load round-trip.
        """
        with self._lock:
            victims = sorted(
                {int(p) for p in partition_ids} & self.summaries.keys()
            )
            if not victims:
                return []
            for pid in victims:
                del self.summaries[pid]
            self._tree.evict_leaves(victims)
            return victims

    def sweep_retention(self) -> list[int]:
        """Evaluate the retention policy against the watermark and evict
        its victims; re-evaluates until the policy is satisfied (so
        ``MemoryBudget`` converges over its estimate-driven passes).
        Returns everything evicted.  No-op without a policy.
        """
        if self.retention is None:
            return []
        evicted: list[int] = []
        with self._lock:
            while True:
                victims = self.evict(
                    self.retention.victims(self._retention_stats())
                )
                if not victims:
                    return evicted
                evicted += victims

    def _retention_stats(self) -> StoreStats:
        """Policy-facing snapshot (callers hold ``_lock``)."""
        ids = tuple(sorted(self.summaries))
        wm = self._watermark
        if wm is None and ids:
            wm = ids[-1]  # summaries injected without _apply (rare)
        return StoreStats(
            ids=ids, watermark=wm, node_floats=self._tree.node_floats()
        )

    def _maybe_sweep(self) -> None:
        if self.retention is not None:
            self.sweep_retention()

    def _sweep_after_batch(self, batch) -> None:
        """Retention slot of the ingest worker (IngestPool on_batch_end):
        runs between flushes, before the pending count drops."""
        self._maybe_sweep()

    def node_floats(self) -> int:
        """Current tree node-float footprint (shared arrays counted once)
        — the figure retention budgets act on."""
        with self._lock:
            return self._tree.node_floats()

    # -------------------------------------------------------- async ingest
    def ingest_async(self, partition_id: int, values) -> None:
        """Enqueue a partition for the background Summarizer.

        Non-blocking unless the bounded queue is full.  The partition
        becomes visible when the worker's next flush applies it; call
        :meth:`flush` to wait for (and surface errors from) everything
        enqueued so far.  Input validation happens here, synchronously, so
        an obviously-bad partition fails the caller instead of the queue.
        """
        self._enqueue(int(partition_id), _validated(values))

    def _enqueue(self, pid: int, values: np.ndarray) -> None:
        """Post-validation enqueue body shared with async ``ingest_many``.
        With a WAL the pool appends + fsyncs the record before returning."""
        self._pool.submit((pid, values))

    # ------------------------------------------------------------ WAL plane
    def _wal_log_sync(self, parts: dict[int, np.ndarray]) -> list[int]:
        """Append a synchronous-ingest batch to the WAL with one group-
        commit fsync; returns the LSNs to ``mark_applied`` after the
        apply.  No-op (empty list) without a WAL."""
        if self._wal is None or not parts:
            return []
        lsns = [self._wal.append(None, pid, v) for pid, v in parts.items()]
        self._wal.commit(lsns[-1])
        return lsns

    def wal_stats(self) -> dict | None:
        """WAL depth / fsync-latency / footprint counters (telemetry),
        or ``None`` when the store runs without a log."""
        return None if self._wal is None else self._wal.stats()

    def _replay_wal(self, covered_lsn: int) -> int:
        """Re-ingest the WAL suffix not covered by the loaded snapshot.

        The idempotent-replay contract (core/workers.py docstring):
        records with ``lsn <= covered_lsn`` are covered by the snapshot's
        state; above that, a pid already present was applied after the
        stable capture but still made the snapshot (skip), and a pid ≤
        the watermark was applied and later evicted by retention (skip —
        replay must not resurrect expired partitions).  Everything else
        is re-summarized and applied in one batch.  Returns the number of
        partitions replayed and records recovery stats on
        ``self.last_recovery``.
        """
        records = self._wal.recovered_records()
        fresh: dict[int, np.ndarray] = {}
        for rec in records:
            if rec.lsn <= covered_lsn:
                continue
            if rec.pid in self.summaries:
                continue
            if self._watermark is not None and rec.pid <= self._watermark:
                continue
            fresh[rec.pid] = rec.values  # duplicate pids: last append wins
        if fresh:
            self._apply(self._summarize_batch(fresh))
            self._maybe_sweep()
        # scanned records are now reflected in memory (or deliberately
        # skipped) — eligible for truncation at the next save
        self._wal.mark_applied(rec.lsn for rec in records)
        self.last_recovery = {
            "records_scanned": len(records),
            "replayed": len(fresh),
            "skipped_covered": len(records) - len(fresh),
            "torn_records_dropped": self._wal.torn_records_dropped,
        }
        return len(fresh)

    def _attach_wal(self, wal_dir: str, covered_lsn: int | None) -> None:
        """Open (or adopt) the log at ``wal_dir``, replay its uncovered
        suffix, and route future submits through it."""
        self.wal_dir = str(wal_dir)
        self._wal = WriteAheadLog(self.wal_dir)
        self._wal.ensure_position(covered_lsn)
        self._pool.wal = self._wal
        self._pool.wal_record = lambda item: (None, item[0], item[1])
        self._replay_wal(-1 if covered_lsn is None else int(covered_lsn))

    def _apply_worker_batch(self, batch: list[tuple[int, np.ndarray]]) -> None:
        """IngestPool apply callback: one grouped summarization + one
        level-batched tree maintenance pass per drained batch (also the
        per-item retry body of the pool's poison isolation)."""
        self._apply(self._summarize_batch(dict(batch)))

    @staticmethod
    def _wrap_async_error(item, exc: BaseException):
        # pool error record: (pid, exception); a failed retention sweep
        # (item None — the on_batch_end hook) records as (None, exception)
        return (item[0] if item is not None else None, exc)

    def flush(self) -> None:
        """Block until every enqueued partition is summarized, visible, and
        retention-swept.

        Re-raises (wrapped) every per-partition error the background worker
        hit since the last flush; the queue keeps draining either way, so a
        poison partition never wedges it — and never takes down the valid
        partitions drained into the same batch (they are retried and
        applied individually).
        """
        errs = self._pool.drain()
        if errs:
            detail = "; ".join(
                f"partition {pid}: {e!r}"
                if pid is not None
                else f"retention sweep: {e!r}"
                for pid, e in errs
            )
            raise RuntimeError(
                f"async ingest failed for {len(errs)} partition(s): {detail}"
            ) from errs[0][1]

    def close(self) -> None:
        """Drain the queue, stop the background worker, surface errors."""
        self._pool.close()
        self.flush()

    def _present_ids(self, lo: int, hi: int) -> list[int]:
        """Present partition ids in ``[lo, hi]`` — O(log n + matches) via a
        sorted-ids cache keyed on the dict mutation counter, instead of an
        O(interval) membership scan per query (callers hold ``_lock``)."""
        summ = self.summaries
        if not isinstance(summ, _VersionedDict):  # summaries dict replaced
            return [i for i in range(lo, hi + 1) if i in summ]
        cache = self._ids_cache
        if cache is None or cache[0] != summ.mutations:
            arr = np.fromiter(summ.keys(), np.int64, len(summ))
            arr.sort()
            cache = (summ.mutations, arr)
            self._ids_cache = cache
        arr = cache[1]
        a = int(np.searchsorted(arr, lo, side="left"))
        b = int(np.searchsorted(arr, hi, side="right"))
        return arr[a:b].tolist()

    def _sync_tree(self, ids: list[int], lo: int, hi: int) -> list[tuple[int, int]]:
        """Re-sync after direct ``summaries`` dict mutation (the documented
        summary-loss idiom ``del store.summaries[pid]``, or outright row
        replacement).  Every tree leaf remembers the stored summary arrays
        it was copied from (``TreeNode.src``), and the dict counts its own
        mutations, so the pointer-identity staleness scan runs **once per
        (dict mutations, tree version) state**: the whole store is
        verified (and repaired — replaced leaves re-point level-batched,
        deletions rebuild), the token is cached, and every later query in
        the same state goes straight to the canonical decomposition —
        O(1) instead of O(interval) on the warm-miss serving path.
        Returns the (post-sync) decomposition of ``[lo, hi]`` so hot
        callers (the cross-tenant registry) don't decompose twice."""
        tree = self._tree
        summ = self.summaries
        versioned = isinstance(summ, _VersionedDict)
        if versioned:
            token = (summ.mutations, tree.version)
            if token == self._sync_token:
                return tree.decompose(lo, hi)
        items = summ.items() if versioned else [(i, summ[i]) for i in ids]
        stale = []
        for pid, s in items:
            node = None
            if tree.base is not None and 0 <= pid - tree.base < tree.capacity:
                node = tree.nodes.get((0, pid - tree.base))
            if (
                node is None
                or node.src is None
                or node.src[0] is not s.boundaries
                or node.src[1] is not s.sizes
            ):
                stale.append(pid)
        if stale:
            tree.set_leaves(
                {pid: (summ[pid].boundaries, summ[pid].sizes) for pid in stale}
            )
        if versioned:
            if tree.num_leaves() != len(summ):
                self.rebuild_tree()  # leaves were deleted from the dict
            self._sync_token = (summ.mutations, tree.version)
            return tree.decompose(lo, hi)
        sel = tree.decompose(lo, hi)
        if sum(tree.nodes[k].leaves for k in sel) != len(ids):
            self.rebuild_tree()
            sel = tree.decompose(lo, hi)
        return sel

    # --------------------------------------------------------------- Merger
    def query(
        self,
        lo: int,
        hi: int,
        beta: int,
        *,
        strict: bool = True,
        engine: str | None = None,
    ) -> tuple[Histogram, float]:
        """β-bucket histogram over partitions ``lo..hi`` (inclusive).

        Returns ``(histogram, eps_max)`` where ``eps_max`` is the guaranteed
        maximum bucket/range-size error of *this* answer: the segment-tree
        engine reports its composed per-level bound, the flat engine the
        paper's single-level ``2N/T + 2k``.  With ``strict=False`` missing
        partitions are skipped (summary-loss tolerance: a lost shard degrades
        the answer instead of failing it).  Safe under concurrent async
        ingest: the answer is a consistent whole-batch snapshot.
        """
        with self._lock:
            ids = self._present_ids(lo, hi)
            if strict and len(ids) != hi - lo + 1:
                missing = sorted(set(range(lo, hi + 1)) - set(ids))
                raise KeyError(f"missing partition summaries: {missing}")
            if not ids:
                raise KeyError("no partition summaries in requested interval")
            if (engine or self.engine) == "tree":
                self._sync_tree(ids, lo, hi)
                return self._tree.query(lo, hi, beta)
            hs = [self.summaries[i].to_histogram() for i in ids]
            merged = merge_list(hs, beta)
            n = sum(self.summaries[i].n for i in ids)
            eps = theoretical_eps_max(
                n, self.num_buckets, k=len(ids), exact_inputs=False
            )
            return merged, eps

    def query_many(
        self,
        intervals: Sequence[tuple[int, int]],
        beta: int,
        *,
        strict: bool = True,
    ) -> list[tuple[Histogram | None, float]]:
        """Answer a batch of interval queries with one jitted merge.

        The serving path for many concurrent users: every query's canonical
        node set is padded to one static shape, so the whole batch costs a
        single XLA dispatch regardless of the mix of window lengths (cached
        repeats cost none at all).  ``strict`` behaves exactly as in
        :meth:`query` (and defaults the same way): missing partitions raise
        unless ``strict=False``.  With ``strict=False`` an interval holding
        *zero* present summaries does not kill the batch (summary-loss
        tolerance): its slot in the returned list is the placeholder
        ``(None, float("inf"))`` — indexing is stable, result ``i`` always
        answers ``intervals[i]``.
        """
        with self._lock:
            results: list[tuple[Histogram | None, float]] = [None] * len(
                intervals
            )
            live: list[int] = []
            for qi, (lo, hi) in enumerate(intervals):
                ids = self._present_ids(lo, hi)
                if strict and len(ids) != hi - lo + 1:
                    missing = sorted(set(range(lo, hi + 1)) - set(ids))
                    raise KeyError(f"missing partition summaries: {missing}")
                self._sync_tree(ids, lo, hi)
                if ids:
                    live.append(qi)
                elif strict:  # degenerate strict span (hi < lo)
                    raise KeyError(
                        "no partition summaries in requested interval"
                    )
                else:
                    results[qi] = (None, float("inf"))
            answered = self._tree.query_many(
                [intervals[qi] for qi in live], beta
            )
            for qi, ans in zip(live, answered):
                results[qi] = ans
            return results

    def quantile_query(
        self, lo: int, hi: int, q, beta: int | None = None
    ) -> np.ndarray:
        """e.g. the paper's motivating '95th-percentile latency for any
        interval': ``store.quantile_query(day0, day1, 0.95)``."""
        beta = beta or min(self.num_buckets, 254)
        h, _ = self.query(lo, hi, beta, strict=False)
        return np.asarray(quantile(h, np.asarray(q)))

    # ---------------------------------------------------------- persistence
    def _state(
        self, prefix: str = "", tree_slot_map=None
    ) -> tuple[dict, dict[str, np.ndarray]]:
        """(json-able meta, array payload) of summaries + tree nodes.

        Array keys are ``prefix``-namespaced so many stores can share one
        npz (the ``TenantRegistry`` container format).  With
        ``tree_slot_map`` (the registry's shared-arena save) the tree's
        node records point into pools the registry exported once for all
        tenants, and no tree arrays are emitted here.  Callers must hold
        or not need ``_lock``.
        """
        tree_meta, tree_arrays = self._tree.state(slot_map=tree_slot_map)
        meta = {
            "ids": sorted(self.summaries),
            "n": {str(p): s.n for p, s in self.summaries.items()},
            "tree": tree_meta,
            # retention watermark (module docstring: persistence format) —
            # survives eviction of everything beneath it
            "watermark": self._watermark,
        }
        payload = {}
        for pid, s in self.summaries.items():
            payload[f"{prefix}b_{pid}"] = s.boundaries
            payload[f"{prefix}s_{pid}"] = s.sizes
        for key, arr in tree_arrays.items():
            payload[f"{prefix}{key}"] = arr
        return meta, payload

    def _restore(self, meta: dict, data, prefix: str = "", tree_arrays=None) -> None:
        """Rebuild summaries + tree from a :meth:`_state`-shaped payload.

        ``tree_arrays`` overrides where the tree's pool arrays are read
        from — the registry's shared-arena container stores them once,
        outside every tenant's prefix.
        """
        wm = meta.get("watermark")
        if wm is None and meta["ids"]:  # pre-watermark summary files
            wm = max(int(p) for p in meta["ids"])
        self._watermark = None if wm is None else int(wm)
        for pid in meta["ids"]:
            b = data[f"{prefix}b_{pid}"]
            s = data[f"{prefix}s_{pid}"]
            # re-stamp the integrity CRC over the loaded bytes: the
            # snapshot's own payload_crc map was (or can be) verified by
            # the scrubber; from here on these arrays are the baseline
            self.summaries[int(pid)] = _make_summary(
                int(pid), meta.get("n", {}).get(str(pid), s.sum()), b, s
            )
        if "tree" in meta:  # restore pre-merged nodes — no re-merge on load
            self._tree = IntervalTree.from_state(
                meta["tree"],
                tree_arrays
                if tree_arrays is not None
                else _PrefixedArrays(data, prefix),
                cache_size=self.cache_size,
                arena=self.arena,  # keep shared-arena stores shared
                collapse=self.collapse,
            )
            # share leaf storage with the summary rows so _sync_tree's
            # pointer-identity staleness scan passes without re-merging
            for pid, s in self.summaries.items():
                self._tree.adopt_leaf_arrays(pid, s.boundaries, s.sizes)
        else:  # summary file from an older layout: rebuild level-batched
            self.rebuild_tree()

    def save(self, path: str) -> None:
        """Atomic write (tmpfile + fsync + rename) — summary files survive
        crashes.

        Persists the pre-merged tree nodes next to the leaf summaries (so a
        reloaded store serves interval queries without re-merging anything)
        plus the store configuration (``T_node``, ``engine``,
        ``cache_size``) so a reload reconstructs the same Merger.

        With a WAL, this is the checkpoint half of the truncation-on-save
        invariant: the log's ``stable_lsn`` is captured *before* the state
        is read (everything ≤ it was applied before the snapshot, hence
        covered), persisted as ``meta["wal_stable_lsn"]``, and — only
        after the atomic rename succeeded — every log segment fully
        covered by the snapshot is deleted.
        """
        stable = None if self._wal is None else self._wal.stable_lsn
        with self._lock:
            state_meta, payload = self._state()
            meta = {
                "num_buckets": self.num_buckets,
                "engine": self.engine,
                "T_node": self.T_node,
                "cache_size": self.cache_size,
                "retention": (
                    None if self.retention is None else self.retention.spec()
                ),
                "collapse": self.collapse,
                "wal_stable_lsn": stable,
                **state_meta,
            }
        atomic_savez(path, meta, payload)
        if self._wal is not None:
            self._wal.truncate(stable)

    @classmethod
    def load(cls, path: str, wal_dir: str | None = None) -> "HistogramStore":
        """Restore from a summary file; with ``wal_dir``, also replay the
        log suffix the snapshot doesn't cover (crash-consistent restore —
        see :meth:`recover` for the missing-snapshot case)."""
        faults.hit("snapshot.load", path=path)
        # context-managed NpzFile: every array is materialized inside the
        # block, so the fd closes here instead of leaking for the store's
        # lifetime (an NpzFile holds its file handle open until closed)
        with np.load(path, allow_pickle=False) as data:
            meta = json.loads(str(data["meta"]))
            T_node = meta.get("T_node")
            store = cls(
                num_buckets=int(meta["num_buckets"]),
                engine=str(meta.get("engine", "tree")),
                T_node=(
                    T_node if T_node in (None, "geometric") else int(T_node)
                ),
                cache_size=int(meta.get("cache_size", 128)),
                retention=policy_from_spec(meta.get("retention")),
                collapse=str(meta.get("collapse", "canonical")),
            )
            store._restore(meta, data)
        if wal_dir is not None:
            store._attach_wal(wal_dir, meta.get("wal_stable_lsn"))
        return store

    @classmethod
    def recover(
        cls, path: str, wal_dir: str, **store_kwargs
    ) -> "HistogramStore":
        """Crash-consistent startup: snapshot + WAL → the acked state.

        If ``path`` exists it is loaded and the WAL's uncovered suffix
        replayed on top (``load``); if the crash happened before the
        first save, the store is rebuilt from the WAL alone using
        ``store_kwargs`` as its configuration.  Either way, every acked
        ingest is present and the store keeps logging to ``wal_dir``.
        """
        if os.path.exists(path):
            return cls.load(path, wal_dir=wal_dir)
        store = cls(**store_kwargs)
        store._attach_wal(wal_dir, None)
        return store

    # ------------------------------------------------------------- utility
    def ids(self) -> list[int]:
        return sorted(self.summaries)

    def total_n(self, ids: Iterable[int] | None = None) -> int:
        ids = list(ids) if ids is not None else self.ids()
        return sum(self.summaries[i].n for i in ids)

    def cache_stats(self) -> dict[str, int]:
        return {
            "hits": self._tree.cache_hits,
            "misses": self._tree.cache_misses,
            "version": self._tree.version,
        }
