"""Sharded checkpointing with atomic manifests and elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json   — step, tree structure, dtypes/shapes, config name
            arrays.npz      — one entry per flattened tree path
         <dir>/LATEST       — atomic pointer file (written via rename)

Restore re-sharding is *elastic*: arrays are loaded on host and
``device_put`` with whatever sharding the *current* mesh's Rules produce,
so a job can restart on a different mesh shape (scale up/down) — the
fault-tolerance contract of DESIGN.md §7.  On a real multi-host deployment
each host would write its address-chunks (à la Orbax/TensorStore); the
format here keeps the same manifest/atomicity semantics single-process.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any

import jax
import numpy as np

from repro.core import faults

SEP = "|"


def _fsync_dir(path: str) -> None:
    """fsync a directory fd so a just-renamed entry survives a crash."""
    dfd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz can't serialize ml_dtypes;
            arr = arr.astype(np.float32)  # f32 is a lossless container and
        out[jax.tree_util.keystr(path)] = arr  # restore re-casts via template
    return out


def save_checkpoint(
    ckpt_dir: str, step: int, params: Any, opt_state: Any | None = None,
    extra: dict | None = None,
) -> str:
    """Atomic save: write to tmp dir, fsync, rename, repoint LATEST."""
    faults.hit("checkpoint.save", step=step)
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        payload = {f"p{SEP}{k}": v for k, v in _flatten(params).items()}
        if opt_state is not None:
            payload.update(
                {f"o{SEP}{k}": v for k, v in _flatten(opt_state).items()}
            )
        arrays_path = os.path.join(tmp, "arrays.npz")
        np.savez(arrays_path, **payload)
        # np.savez closes the zip without fsync — a crash after the rename
        # below could publish a manifest pointing at torn array data.
        # Same discipline as stream.atomic_savez: payload fsync before the
        # rename, directory fsync after it.
        fd = os.open(arrays_path, os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
        manifest = {
            "step": int(step),
            "keys": sorted(payload),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _fsync_dir(ckpt_dir)  # make the rename itself durable
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer — fsynced before the rename (an un-synced
    # pointer can survive a crash as an empty file, orphaning the step
    # directory it was about to publish), directory fsync after
    fd, ptr_tmp = tempfile.mkstemp(dir=ckpt_dir)
    with os.fdopen(fd, "w") as f:
        f.write(os.path.basename(final))
        f.flush()
        os.fsync(f.fileno())
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    _fsync_dir(ckpt_dir)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[-1])


def restore_checkpoint(
    ckpt_dir: str,
    step: int | None,
    params_template: Any,
    opt_template: Any | None = None,
    shardings: Any | None = None,
    opt_shardings: Any | None = None,
) -> tuple[Any, Any | None, int]:
    """Restore onto the *current* mesh (templates give tree structure).

    ``shardings`` trees (same structure) trigger sharded device_put —
    restoring onto a different mesh than the one that saved is supported
    (elastic restart).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    faults.hit("checkpoint.restore", step=step)
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    # NpzFile holds the archive fd until closed — rebuild() materializes
    # every leaf, so context-manage instead of leaking one fd per restore
    with np.load(os.path.join(path, "arrays.npz")) as data:

        def rebuild(template, prefix, shard_tree):
            flat, treedef = jax.tree_util.tree_flatten_with_path(template)
            shard_flat = (
                jax.tree_util.tree_flatten(shard_tree)[0]
                if shard_tree is not None
                else [None] * len(flat)
            )
            leaves = []
            for (keypath, leaf), sh in zip(flat, shard_flat):
                arr = data[f"{prefix}{SEP}{jax.tree_util.keystr(keypath)}"]
                arr = arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr
                leaves.append(
                    jax.device_put(arr, sh)
                    if sh is not None
                    else jax.numpy.asarray(arr)
                )
            return jax.tree_util.tree_unflatten(treedef, leaves)

        params = rebuild(params_template, "p", shardings)
        opt = (
            rebuild(opt_template, "o", opt_shardings)
            if opt_template is not None
            else None
        )
    return params, opt, step


def gc_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    """Remove all but the newest ``keep`` checkpoints (never LATEST's)."""
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(
        int(d.split("_")[-1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_")
    )
    keep_set = set(steps[-keep:])
    latest = latest_step(ckpt_dir)
    if latest is not None:
        keep_set.add(latest)
    for s in steps:
        if s not in keep_set:
            shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
