"""Trainer: the fault-tolerant loop around make_train_step.

Production behaviours implemented (and exercised by tests/examples):
  * checkpoint/restart — atomic manifests, LATEST pointer, periodic +
    SIGTERM-triggered saves (preemption handling), elastic restore onto a
    different mesh (checkpoint/checkpoint.py).
  * deterministic resume — data batches are a pure function of
    ``(seed, step)`` (data/pipeline.py), so the only data state is the step.
  * straggler mitigation — per-host step times summarized with the paper's
    histograms; hosts beyond the merged p95 are flagged
    (core/telemetry.StragglerDetector) and reported each log interval.
  * gradient-distribution telemetry via mergeable histograms (optional).
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Any, Callable

import jax
import numpy as np

from repro.checkpoint import gc_checkpoints, latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import ModelConfig
from repro.core.telemetry import StragglerDetector, TelemetryLog
from repro.data import SyntheticLM
from repro.models.model import init_model
from repro.optim import CompressionConfig, OptimizerConfig
from repro.train.train_step import make_opt_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    seed: int = 0
    resume: bool = True


class Trainer:
    def __init__(
        self,
        cfg: ModelConfig,
        opt_cfg: OptimizerConfig,
        tcfg: TrainerConfig,
        *,
        seq_len: int,
        global_batch: int,
        mesh=None,
        rules=None,
        comp_cfg: CompressionConfig | None = None,
    ):
        self.cfg, self.opt_cfg, self.tcfg = cfg, opt_cfg, tcfg
        self.mesh, self.rules = mesh, rules
        self.data = SyntheticLM(
            vocab_size=cfg.vocab_size,
            seq_len=seq_len,
            global_batch=global_batch,
            seed=tcfg.seed,
        )
        self.telemetry = TelemetryLog()
        self.straggler = StragglerDetector()
        self._preempted = False

        step_fn = make_train_step(
            cfg, opt_cfg, rules, comp_cfg=comp_cfg, mesh=mesh
        )
        self.step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

        # --- init or resume -------------------------------------------------
        key = jax.random.PRNGKey(tcfg.seed)
        params, _ = init_model(cfg, key)
        opt_state = make_opt_state(params, opt_cfg, comp_cfg)
        self.start_step = 0
        if tcfg.resume and latest_step(tcfg.checkpoint_dir) is not None:
            params, opt_state, self.start_step = restore_checkpoint(
                tcfg.checkpoint_dir, None, params, opt_state
            )
            print(f"[trainer] resumed from step {self.start_step}")
        self.params, self.opt_state = params, opt_state

    # ---- preemption: checkpoint on SIGTERM then exit cleanly ---------------
    def install_signal_handler(self):
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    def _maybe_checkpoint(self, step: int, force: bool = False):
        if force or (step > 0 and step % self.tcfg.checkpoint_every == 0):
            save_checkpoint(
                self.tcfg.checkpoint_dir, step, self.params, self.opt_state
            )
            gc_checkpoints(self.tcfg.checkpoint_dir, self.tcfg.keep_checkpoints)

    def run(self, on_metrics: Callable[[int, dict], None] | None = None):
        t_loop = time.perf_counter()
        step = self.start_step
        while step < self.tcfg.total_steps:
            batch = {
                k: jax.numpy.asarray(v)
                for k, v in self.data.batch_at(step).items()
            }
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state, batch
            )
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.straggler.record(jax.process_index(), dt)
            step += 1

            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps:
                loss = float(metrics["loss"])
                self.telemetry.log_scalar("loss", step, loss)
                self.telemetry.log_scalar("step_time", step, dt)
                flagged, p95 = self.straggler.flag()
                msg = (
                    f"[trainer] step={step} loss={loss:.4f} "
                    f"step_time={dt*1e3:.1f}ms grad_norm="
                    f"{float(metrics.get('grad_norm', np.nan)):.3f}"
                )
                if flagged:
                    msg += f" STRAGGLERS={flagged} (p95={p95*1e3:.1f}ms)"
                print(msg, flush=True)
                if on_metrics:
                    on_metrics(step, {**metrics, "step_time": dt})

            self._maybe_checkpoint(step)
            if self._preempted:
                print("[trainer] SIGTERM received — checkpointing and exiting")
                self._maybe_checkpoint(step, force=True)
                return step
        self._maybe_checkpoint(step, force=True)
        print(
            f"[trainer] done: {step - self.start_step} steps in "
            f"{time.perf_counter() - t_loop:.1f}s"
        )
        return step
