from repro.train.train_step import make_train_step, make_opt_state
