"""Train step: value_and_grad + clip (+compress) + AdamW, microbatched.

Gradient accumulation runs as a ``lax.scan`` over microbatches with the
reduction deferred to the end (grads stay in their sharded layout; XLA
schedules the FSDP all-gathers of the next microbatch against the current
one's backward — the standard overlap).  Buffers are donated by the jit
wrapper in ``launch/train.py``.

``opt_state`` = {"m", "v", "step"} (+ "residual" when compression is on);
moments mirror parameter sharding (ZeRO).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import loss_fn
from repro.optim import (
    CompressionConfig,
    OptimizerConfig,
    adamw_update,
    clip_grads,
    compress_grads,
    init_opt_state,
    init_residual,
)


def make_opt_state(params, opt_cfg, comp_cfg: CompressionConfig | None = None):
    state = init_opt_state(params, opt_cfg)
    if comp_cfg is not None and comp_cfg.enabled:
        state["residual"] = init_residual(params)
    return state


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptimizerConfig,
    rules=None,
    comp_cfg: CompressionConfig | None = None,
    mesh=None,
    telemetry_axes: tuple[str, ...] = (),
) -> Callable:
    """Returns step(params, opt_state, batch) → (params', opt_state', metrics).

    ``batch`` leaves carry a leading (accum,) dim when grad_accum > 1.
    """

    compute_dt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else jnp.float32

    def forward(params, microbatch):
        # Cast matrices to the compute dtype while still FSDP-sharded, so
        # the partitioner's weight all-gathers move bf16 (not fp32) and the
        # backward's gradient reduction happens on bf16 cotangents before
        # the (local) cast-back to fp32.  Halves the dominant collective
        # term of the FSDP cells — §Perf iteration 1.  Norms/scalars (<2-D)
        # stay fp32.
        params_c = jax.tree.map(
            lambda p: p.astype(compute_dt)
            if (p.dtype == jnp.float32 and p.ndim > 1)
            else p,
            params,
        )
        return loss_fn(cfg, params_c, microbatch, rules)

    grad_fn = jax.value_and_grad(forward, has_aux=True)

    def step(params, opt_state, batch):
        if opt_cfg.grad_accum > 1:

            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, grads
                )
                return (g_acc, l_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0)), batch
            )
            grads = jax.tree.map(lambda g: g / opt_cfg.grad_accum, grads)
            metrics = {"loss": loss_sum / opt_cfg.grad_accum}
        else:
            (loss, m), grads = grad_fn(params, batch)
            metrics = {"loss": loss, **m}

        grads, clip_m = clip_grads(
            grads, opt_cfg, mesh=mesh, axis_names=telemetry_axes
        )
        metrics.update(clip_m)

        new_state = {}
        if comp_cfg is not None and comp_cfg.enabled:
            grads, new_state["residual"], cm = compress_grads(
                grads, opt_state["residual"], comp_cfg,
                mesh=mesh, axis_names=telemetry_axes,
            )
            metrics.update(cm)

        inner = {k: opt_state[k] for k in ("m", "v", "step")}
        new_params, new_inner, opt_m = adamw_update(grads, inner, params, opt_cfg)
        new_state.update(new_inner)
        metrics.update(opt_m)
        return new_params, new_state, metrics

    return step
