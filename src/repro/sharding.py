"""Logical-axis sharding rules: DP / FSDP / TP / EP / SP / KV-seq CP.

Every parameter/cache/activation leaf carries a tuple of *logical* axis
names; ``Rules`` maps them to mesh ``PartitionSpec`` per (mesh, shape-kind,
arch divisibility).  This is the single place the parallelism layout lives
(MaxText-style), so a layout experiment is a ~5-line diff here.

Layout summary (DESIGN.md §7):

  weights    TP over "model" on heads/mlp/experts/vocab/mamba/rwkv dims,
             ZeRO-3/FSDP over "data" on the embed dim (XLA all-gathers at
             use); replicated over "pod" (pure DP between pods — ICI-cheap
             gradient all-reduce crosses pods once per step).
  activations batch over ("pod","data"); residual stream sequence-sharded
             over "model" between blocks (Megatron sequence parallelism —
             XLA inserts the all-gather/reduce-scatter pair around each
             block).
  KV caches  decode: batch over ("pod","data"), *sequence* over "model"
             (flash-decoding style split-KV; XLA adds the softmax combine
             collectives).  long_500k (batch=1): sequence over
             ("data","model") — 500k KV splits 256-way; batch replicated.

Divisibility fallbacks are computed per arch: a logical axis whose size
does not divide its mesh axes degrades to replication (smollm's 9 heads) —
recorded in the dry-run output so the roofline table shows the cost.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _mesh_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclasses.dataclass
class Rules:
    """Callable: logical-axis tuple → PartitionSpec."""

    cfg: ModelConfig
    mesh: Mesh
    shape_kind: str  # train | prefill | decode | decode_long
    seq_len: int = 0
    fsdp: bool = True
    sequence_parallel: bool = True
    table: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        cfg, mesh = self.cfg, self.mesh
        has_pod = "pod" in mesh.axis_names
        model = "model" if "model" in mesh.axis_names else None
        data = "data" if "data" in mesh.axis_names else None
        dp = (("pod", "data") if has_pod else ("data",)) if data else None
        msize = _mesh_size(mesh, model)

        def tp_if(n: int):
            return model if model and n % max(msize, 1) == 0 else None

        decode = self.shape_kind in ("decode", "decode_long")
        long = self.shape_kind == "decode_long"

        # split-KV: the *sequence* dim of the KV cache carries the sharding
        # (decode AND prefill — a prefill otherwise materializes the whole
        # cache unsharded as the layer-scan output: §Perf iteration 2).
        kv_seq = ("data", "model") if long else (model,)
        batch_axes = None if long else dp

        t = {
            # ---- weights -------------------------------------------------
            "layers": None,
            "embed": (data if self.fsdp else None),
            "vocab": tp_if(cfg.vocab_size),
            "heads": tp_if(cfg.num_heads),
            "kv_heads": tp_if(cfg.num_kv_heads),
            "mlp": tp_if(cfg.d_ff),
            "experts": tp_if(max(cfg.num_experts, 1)),
            # Activation expert-dim pin (moe.py::_pin_experts): ONLY when
            # ≥2 experts land per device.  Measured (§Perf P4/P5): at
            # E_loc=8 (llama4) the pin removes catastrophic EP-axis weight
            # gathers (collective 91→16.5 s); at E_loc=1 (dbrx, jamba) the
            # partitioner's weight replication is the cheaper plan and the
            # pin inflates every term (dbrx compute 14→41 s) — refuted
            # there, so it is conditional.
            "experts_act": (
                model
                if model
                and cfg.num_experts >= 2 * max(msize, 1)
                and cfg.num_experts % max(msize, 1) == 0
                else None
            ),
            "expert_mlp": None,
            "mamba_inner": tp_if(cfg.mamba_expand * cfg.d_model),
            "rwkv_proj": tp_if(cfg.d_model),
            "rwkv_heads": tp_if(max(cfg.rwkv_heads, 1)),
            # ---- activations ----------------------------------------------
            "act_batch": batch_axes,
            "act_seq": (
                model
                if (
                    self.sequence_parallel
                    and not decode
                    and model
                    and self.seq_len % max(msize, 1) == 0
                )
                else None
            ),
            "enc_seq": None,  # whisper's 1500 frames: not 16-divisible
            # ---- decode caches ---------------------------------------------
            "batch_kv": batch_axes,
            "kv_seq": kv_seq,
            "kv_heads_cache": None,  # seq-sharding carries the memory
        }
        self.table = t

    def __call__(self, logical: tuple) -> P:
        entries = []
        for name in logical:
            if name is None:
                entries.append(None)
            else:
                entries.append(self.table.get(name))
        return P(*entries)

    def sharding(self, logical: tuple) -> NamedSharding:
        return NamedSharding(self.mesh, self(logical))

    def tree_pspecs(self, spec_tree: Any) -> Any:
        """Map a tree of logical tuples to PartitionSpecs."""
        return jax.tree.map(
            lambda s: self(s),
            spec_tree,
            is_leaf=lambda s: isinstance(s, tuple)
            and all(isinstance(e, (str, type(None))) for e in s),
        )

    def tree_shardings(self, spec_tree: Any) -> Any:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, self(s)),
            spec_tree,
            is_leaf=lambda s: isinstance(s, tuple)
            and all(isinstance(e, (str, type(None))) for e in s),
        )

    def degradations(self) -> list[str]:
        """Human-readable list of divisibility fallbacks (for the report)."""
        cfg = self.cfg
        msize = _mesh_size(self.mesh, "model" if "model" in self.mesh.axis_names else None)
        out = []
        for name, n in [
            ("heads", cfg.num_heads),
            ("kv_heads", cfg.num_kv_heads),
            ("vocab", cfg.vocab_size),
            ("mlp", cfg.d_ff),
        ]:
            if msize > 1 and n % msize != 0:
                out.append(f"{name}={n} !% model={msize} -> replicated")
        return out
