"""Quickstart: the paper's §4 worked example + the quality guarantee.

Reproduces the exact numbers from the paper:
  P1 = {2,4,5,6,7,10,13,16,18,20,21,25}   → H1 = {(2,4),(7,4),(18,4),(25,0)}
  P2 = {3,9,...,30}                        → H2 = {(3,5),(15,5),(24,5),(30,0)}
  merge(H1, H2, β=3)                       → H* = {(2,9),(7,9),(18,9),(30,0)}

then demonstrates the ε_max < 2β/T·(N/β) guarantee on a million-value
Gumbel stream and the paper's T ≥ 40β rule for ≤5 % bucket error.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    build_exact,
    merge_list,
    merge_histograms_sequential,
    quantile,
    theoretical_eps_max,
)


def main() -> None:
    # --- the worked example -------------------------------------------------
    P1 = jnp.asarray([2, 4, 5, 6, 7, 10, 13, 16, 18, 20, 21, 25], jnp.float32)
    P2 = jnp.asarray(
        [3, 9, 11, 12, 14, 15, 17, 19, 22, 23, 24, 26, 27, 29, 30], jnp.float32
    )
    H1, H2 = build_exact(P1, 3), build_exact(P2, 3)
    print("H1:", list(zip(np.asarray(H1.boundaries), np.r_[np.asarray(H1.sizes), 0])))
    print("H2:", list(zip(np.asarray(H2.boundaries), np.r_[np.asarray(H2.sizes), 0])))
    Hs = merge_list([H1, H2], 3)
    print("H* (vectorized):", np.asarray(Hs.boundaries), np.asarray(Hs.sizes))
    Hq = merge_histograms_sequential([H1, H2], 3)
    print("H* (Algorithm 1):", np.asarray(Hq.boundaries), np.asarray(Hq.sizes))
    assert np.allclose(np.asarray(Hs.boundaries), [2, 7, 18, 30])
    assert np.allclose(np.asarray(Hs.sizes), [9, 9, 9])

    # --- the guarantee at scale ----------------------------------------------
    rng = np.random.default_rng(0)
    k, n_per = 16, 65_536
    beta = 254                     # Oracle's default bucket count (paper §7)
    T = 40 * beta                  # paper's rule for ≤5 % bucket-size error
    parts = [rng.gumbel(size=n_per).astype(np.float32) for _ in range(k)]
    summaries = [build_exact(jnp.asarray(p), T) for p in parts]
    merged = merge_list(summaries, beta)
    N = k * n_per
    err = np.abs(np.asarray(merged.sizes) - N / beta).max()
    bound = theoretical_eps_max(N, T, k, exact_inputs=False)
    print(f"\nN={N:,}  T={T}  beta={beta}")
    print(f"max bucket-size error: {err:.1f}  (bound {bound:.1f}, "
          f"= {err/(N/beta)*100:.2f}% of ideal bucket; guarantee ≤5%)")
    assert err <= bound and err / (N / beta) <= 0.05
    print("p95 of the merged histogram:", float(quantile(merged, 0.95)))
    print("quickstart OK")


if __name__ == "__main__":
    main()
