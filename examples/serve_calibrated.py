"""Batched serving + histogram-calibrated int8 activation scales.

Loads a (reduced) qwen3-8b, serves a batch of prompts through the
prefill/decode engine, then calibrates int8 activation clip ranges from
merged equi-depth summaries of calibration batches — the quantization-
calibration integration of the paper (bounded-rank-error p99.9 instead of
an outlier-hostage max).

Run: PYTHONPATH=src python examples/serve_calibrated.py
"""
import jax
import numpy as np

from repro.configs import get_config, smoke
from repro.models import init_model
from repro.serve import Engine, ServeConfig


def main() -> None:
    cfg = smoke(get_config("qwen3-8b"))
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(
        cfg, params,
        ServeConfig(max_seq=64, max_new_tokens=12, temperature=0.0),
    )

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
        for n in (6, 11, 17, 9)
    ]
    outs = eng.generate(prompts)
    for i, o in enumerate(outs):
        print(f"req{i}: {len(prompts[i])} prompt → {len(o)} total tokens")

    print("\n== int8 calibration from merged histograms ==")
    key = jax.random.PRNGKey(7)
    batches = []
    for i in range(4):
        k = jax.random.fold_in(key, i)
        batches.append(
            {"tokens": jax.random.randint(k, (2, 32), 0, cfg.vocab_size)}
        )
    calib = eng.calibrate(batches, q=0.999, T=512)
    print(f"clip={calib['clip']:.4f}  int8_scale={calib['int8_scale']:.6f}")
    print(f"rank error bound: ±{calib['rank_error_bound']:.0f} of "
          f"{calib['n_calibration_values']:,} calibration values "
          f"({100*calib['rank_error_bound']/calib['n_calibration_values']:.2f}%)")
    print("serve_calibrated OK")


if __name__ == "__main__":
    main()
