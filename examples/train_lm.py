"""End-to-end LM training with the histogram plane switched on.

Trains smollm-135m (reduced config by default; --full for the real 135M)
for a few hundred steps with:
  * histogram-quantile gradient clipping (paper Theorem 1 as an optimizer
    feature),
  * histogram-threshold gradient compression with error feedback,
  * checkpoint every 50 steps + deterministic resume,
  * straggler monitoring via merged step-time summaries.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --steps 200 --resume-demo
"""
import argparse
import shutil

from repro.configs import get_config, smoke
from repro.optim import CompressionConfig, OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true",
                    help="real 135M config (slow on CPU)")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume-demo", action="store_true",
                    help="interrupt at half steps, restart, verify resume")
    args = ap.parse_args()

    cfg = get_config("smollm-135m")
    if not args.full:
        cfg = smoke(cfg)
    opt = OptimizerConfig(
        peak_lr=3e-3, warmup_steps=20, decay_steps=args.steps,
        clip_mode="quantile", clip_q=0.999,  # ← the paper as an optimizer
    )
    comp = CompressionConfig(enabled=args.compress, rho=0.01)

    def make(steps):
        return Trainer(
            cfg, opt,
            TrainerConfig(total_steps=steps, log_every=20,
                          checkpoint_every=50, checkpoint_dir=args.ckpt_dir),
            seq_len=args.seq_len, global_batch=args.global_batch,
            comp_cfg=comp,
        )

    shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    if args.resume_demo:
        half = args.steps // 2 - args.steps // 2 % 50 or 50
        print(f"== phase 1: train to step {half}, then 'preempt' ==")
        make(half).run()
        print("== phase 2: restart from latest checkpoint ==")
        tr = make(args.steps)
        assert tr.start_step == half, tr.start_step
        tr.run()
    else:
        tr = make(args.steps)
        tr.run()
        first = tr.telemetry.scalars["loss"][0][1]
        last = tr.telemetry.scalars["loss"][-1][1]
        print(f"loss: {first:.3f} → {last:.3f}")


if __name__ == "__main__":
    main()
