"""The paper's end-to-end deployment: daily log summarization + on-demand
interval histograms — Summarizer/Merger (paper §5, Fig. 13) on JAX.

A month of synthetic web-server latency logs is ingested day by day (the
scheduled Summarizer job — here through the *Pallas tile-sort path*, i.e.
exactly what runs per-device on TPU).  Then on-demand Merger queries answer
the paper's motivating questions:

  * histogram of any time interval (last week / Christmas season),
  * 95th-percentile latency over any interval,
  * range-count queries with the ε_max guarantee,

all without re-touching raw data.  The Merger runs on the segment-tree
interval engine (core/interval_tree.py): each query merges only the
``≤ 2·log2 W`` pre-merged canonical node summaries instead of the whole
window, repeated dashboard windows are served from the LRU answer cache,
and a batch of concurrent users' queries goes through ``query_many`` as a
single jitted merge.  Summaries AND tree nodes persist to disk (the HDFS
summary files) and the store answers from any subset if a day is lost.

Run: PYTHONPATH=src python examples/log_analytics.py
(``--smoke`` shrinks every size for CI: same pipeline, tiny data.)
"""
import argparse
import os
import tempfile

import jax.numpy as jnp
import numpy as np

from repro.core import HistogramStore, TenantRegistry, quantile, range_count
from repro.kernels import summarize_pallas


def synth_day(rng, day: int, base: int = 65_536) -> np.ndarray:
    """Log-normal latency with a weekly cycle and holiday surge.

    Days have ragged lengths (real traffic is never tile-aligned) — the
    Pallas Summarizer masks the sentinel-padded tail tile.
    """
    n = base + int(rng.integers(0, max(1, base // 16)))  # not tile-aligned
    scale = 1.0 + 0.25 * (day % 7 in (5, 6)) + 0.6 * (day >= 24)
    return (rng.lognormal(-1.8, 0.55, size=n) * scale).astype(np.float32)


def main(smoke: bool = False) -> None:
    rng = np.random.default_rng(0)
    T = 512 if smoke else 2048
    day_n = 8_192 if smoke else 65_536  # records per synthetic day
    svc_n, svc_step = (1_024, 16) if smoke else (8_192, 128)
    ret_n = 512 if smoke else 4_096
    store = HistogramStore(num_buckets=T)
    raw = {}

    print("== Summarizer (daily, offline — Pallas tile-sort path) ==")
    for day in range(31):
        v = synth_day(rng, day, day_n)
        raw[day] = v
        h = summarize_pallas(
            jnp.asarray(v), tile_len=4096, T_tile=512, T_out=T
        )
        store.ingest_summary(day, h)
    total = sum(len(v) for v in raw.values())
    print(f"ingested 31 ragged days ({total:,} records) "
          f"→ {31*(T*2+1)*4/1e6:.1f} MB of summaries (vs "
          f"{total*4/1e6:.0f} MB raw)")

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "summaries.npz")
        store.save(path)
        store = HistogramStore.load(path)
        print(f"summaries persisted+reloaded ({os.path.getsize(path)/1e6:.1f} MB)")

    print("\n== Merger (on-demand interval queries, segment-tree engine) ==")
    for (lo, hi, label) in [(0, 30, "whole month"), (21, 27, "last week"),
                            (24, 30, "holiday season")]:
        nodes = len(store._tree.decompose(lo, hi))
        h, eps = store.query(lo, hi, beta=254)
        p95 = store.quantile_query(lo, hi, 0.95)
        truth = np.quantile(np.concatenate([raw[i] for i in range(lo, hi + 1)]), 0.95)
        n = store.total_n(range(lo, hi + 1))
        print(f"{label:16s} days {lo:2d}-{hi:2d}: p95={float(p95)*1e3:7.2f} ms "
              f"(true {truth*1e3:7.2f} ms)  ε_max={eps:.0f} "
              f"({eps/(n/254)*100:.1f}% of bucket; merged {nodes} of "
              f"{hi-lo+1} summaries)")

    # range-count with guarantee: requests slower than 500 ms last week
    h, eps = store.query(21, 27, beta=254)
    cnt = float(range_count(h, jnp.float32(0.5), jnp.float32(1e9)))
    true_cnt = sum(int((raw[i] >= 0.5).sum()) for i in range(21, 28))
    print(f"\nrequests ≥ 500 ms in days 21-27: ≈{cnt:,.0f} "
          f"(true {true_cnt:,}; bound ±{eps:.0f})")

    # a burst of concurrent dashboard users: one jitted merge for the batch,
    # then the LRU serves the repeat windows without touching XLA at all
    windows = [(0, 30), (21, 27), (24, 30), (7, 13), (14, 20)]
    store.query_many(windows, beta=254)
    for _ in range(3):  # the same dashboards refresh
        for (lo, hi) in windows:
            store.query(lo, hi, beta=254)
    stats = store.cache_stats()
    print(f"\nbatched {len(windows)} concurrent windows in one merge; "
          f"refresh traffic: {stats['hits']} cache hits / "
          f"{stats['misses']} misses")

    # fault tolerance: lose a day, answer degrades instead of failing
    del store.summaries[25]
    h, _ = store.query(21, 27, beta=64, strict=False)
    print(f"day 25 summary lost → query still answers over "
          f"{float(np.asarray(h.sizes).sum()):,.0f} records (6/7 days)")

    # next month arrives while the dashboards stay live: async ingest —
    # the Summarizer runs on a background thread (batched, shape-stable
    # dispatches), dashboards keep querying consistent snapshots, and
    # flush() is the explicit freshness barrier (no sleeps, no races)
    print("\n== async ingest (the next month, dashboards stay live) ==")
    live = HistogramStore(num_buckets=T, T_node="geometric",
                          async_ingest=True)
    for day in range(31):
        live.ingest(day, raw[day])  # enqueue: returns immediately
    snapshots = 0
    try:
        h, _ = live.query(0, 30, beta=254, strict=False)
        snapshots = int(float(np.asarray(h.sizes).sum()))
    except KeyError:
        pass  # nothing applied yet — also a consistent answer
    live.flush()
    h, eps = live.query(0, 30, beta=254)
    n = float(np.asarray(h.sizes).sum())
    print(f"mid-ingest snapshot saw {snapshots:,} records; after flush the "
          f"geometric-T_node store answers over {n:,.0f} "
          f"(ε_max {eps/(n/254)*100:.1f}% of bucket, depth-independent)")
    live.close()

    # production doesn't track one metric: every service's latency is its
    # own tenant of one registry — shared config, a single background
    # ingest pool, and a whole dashboard refresh (one window per service)
    # answered with ONE cross-tenant merge dispatch instead of N
    print("\n== multi-tenant serving (one registry, many services) ==")
    services = [f"svc-{s:02d}" for s in range(24)]
    reg = TenantRegistry(num_buckets=256)
    svc_days = {name: {} for name in services}
    for s, name in enumerate(services):
        for day in range(7):
            svc_days[name][day] = synth_day(rng, day, day_n)[: svc_n + svc_step * s]
            reg.ingest_async(name, day, svc_days[name][day])
    reg.flush()  # the explicit freshness barrier, as for a single store
    refresh = [(name, 0, 6) for name in services]
    reg.merge_dispatches = 0
    answers = reg.query_many(refresh, beta=64)
    p95s = [float(quantile(h, jnp.float32(0.95))) for h, _ in answers]
    print(f"{len(services)} services × 7 days ingested through the shared "
          f"pool; dashboard refresh of {len(refresh)} windows answered in "
          f"{reg.merge_dispatches} merge dispatch "
          f"(p95 spread {min(p95s)*1e3:.1f}-{max(p95s)*1e3:.1f} ms)")
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "registry.npz")
        reg.save(path)  # every tenant in ONE atomic npz
        reloaded = TenantRegistry.load(path)
        h0, _ = reg.query(services[0], 0, 6, beta=64)
        h1, _ = reloaded.query(services[0], 0, 6, beta=64)
        same = bool(np.array_equal(np.asarray(h0.sizes), np.asarray(h1.sizes)))
        print(f"registry persisted+reloaded from one file "
              f"({os.path.getsize(path)/1e6:.1f} MB, answers identical: {same})")
    reg.close()

    # scale the registry up and the remaining per-tenant cost is storage:
    # every tree still owns its own little node arrays, so each dashboard
    # refresh re-packs its merge stack host-side, row by row.  A shared
    # NodeArena pools every service's nodes into one device-resident
    # (n_slots, T) pool — the refresh's whole merge stack is then
    # assembled with a single device gather (zero host row copies, the
    # counter proves it), the drained ingest batches pull up ALL touched
    # services with one merge dispatch per tree level, and save/load
    # writes the pool once per registry instead of per tenant
    print("\n== shared node-storage arena (one pool for every service) ==")
    arena_reg = TenantRegistry(num_buckets=256, shared_arena=True)
    for name in services:
        arena_reg.ingest_many(name, svc_days[name])
    arena_reg.merge_dispatches = 0
    arena_reg.reset_host_row_copies()
    answers2 = arena_reg.query_many(refresh, beta=64)
    same = all(
        np.array_equal(np.asarray(h0.sizes), np.asarray(h1.sizes))
        for (h0, _), (h1, _) in zip(answers, answers2)
    )
    print(f"{len(services)} services in ONE arena "
          f"({arena_reg.arena.allocated_floats():,} pooled floats, widths "
          f"{arena_reg.arena.widths()}); refresh answered in "
          f"{arena_reg.merge_dispatches} merge dispatch with "
          f"{arena_reg.host_row_copies} host row copies "
          f"(answers identical to per-tenant arrays: {same})")
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "arena_registry.npz")
        arena_reg.save(path)  # node pools written once, compacted
        with np.load(path) as npz:  # context-managed: no leaked archive fd
            pool_keys = [k for k in npz.files if k.startswith("arena_")]
        print(f"persisted: one shared pool ({pool_keys}) instead of "
              f"{len(services)} per-tenant array dicts")
    arena_reg.close()

    # the stream never ends, but memory must: a sliding window makes the
    # paper's "for a given time interval" first-class — each day ingested
    # evicts the day that left the window (set_leaf's pull-up in reverse,
    # lazy subtree collapse behind it), answers over the retained window
    # stay bit-exact vs a flat rebuild of just those days, and the
    # watermark persists so a reloaded store resumes aging where it
    # stopped instead of resurrecting expired days
    print("\n== windowed retention (infinite stream, bounded memory) ==")
    from repro.core import SlidingWindow, TTL

    win = HistogramStore(num_buckets=T, retention=SlidingWindow(7))
    for day in range(90):  # a quarter of traffic through a 7-day window
        win.ingest(day, synth_day(rng, day, day_n)[:ret_n])
    lo, hi = win.ids()[0], win.ids()[-1]
    h, eps = win.query(lo, hi, beta=64)
    print(f"90 days streamed, {len(win.ids())} retained "
          f"(days {lo}-{hi}), {win.node_floats():,} node floats steady "
          f"(unbounded would be ~{90 // 7}× that and growing); "
          f"p95 over the live window: "
          f"{float(quantile(h, jnp.float32(0.95)))*1e3:.2f} ms")

    # tenant quotas: thousands of services share ONE memory envelope —
    # per-tenant TTL ages old days out, the registry budget evicts from
    # the largest-over-quota tenant first, so one noisy service cannot
    # squeeze out the rest
    budget = 24 * win.node_floats()  # room for ~24 window-sized tenants
    quota_reg = TenantRegistry(num_buckets=T, retention=TTL(max_age=6),
                               budget=budget)
    for s, name in enumerate(services):
        for day in range(10):  # 10 days in, TTL keeps the last 7
            quota_reg.ingest_async(name, day,
                                   synth_day(rng, day, day_n)[: ret_n // 2 + 8 * s])
    quota_reg.flush()  # retention + budget swept on the pool workers
    sizes = quota_reg.node_floats()
    days_kept = {len(quota_reg[name].ids()) for name in services}
    print(f"{len(services)} tenants under one {budget:,}-float budget: "
          f"total {sum(sizes.values()):,} floats "
          f"(fits: {sum(sizes.values()) <= budget}), per-tenant days kept "
          f"{sorted(days_kept)} (TTL window, newest never evicted)")
    quota_reg.close()

    # durability: everything above assumed the process lives until save().
    # In production the Summarizer node gets kill -9'd between an acked
    # ingest and the next snapshot — without a log those acked days are
    # silently gone.  wal_dir= gives the registry a segmented write-ahead
    # log: every ingest is appended + fsynced BEFORE the call returns
    # (concurrent submits share one group-commit fsync), recover() replays
    # the log suffix the snapshot doesn't cover (idempotent: pid dedup +
    # watermark reconciliation, torn trailing records dropped), and save()
    # truncates the covered segments.  See the "Write-ahead log" design
    # note in core/workers.py for the record format and invariants.
    print("\n== durable ingest (write-ahead log + crash recovery) ==")
    with tempfile.TemporaryDirectory() as d:
        snap = os.path.join(d, "registry.npz")
        wal = os.path.join(d, "wal")
        dur = TenantRegistry(num_buckets=256, wal_dir=wal)
        dur.ingest_many("frontend", {dy: svc_days["svc-00"][dy]
                                     for dy in range(4)})
        dur.save(snap)  # atomic snapshot; WAL truncated to the suffix
        for day in (4, 5):  # acked after the snapshot — only the WAL
            dur.ingest("frontend", day, svc_days["svc-00"][day])
        stats = dur.wal_stats()
        del dur  # kill -9: no close(), no save — in-memory state is gone

        crashed = TenantRegistry.recover(snap, wal, num_buckets=256)
        days = crashed["frontend"].ids()
        print(f"crash with {stats['appends']} acked ingests logged "
              f"({stats['fsyncs']} group-commit fsyncs, "
              f"{stats['last_fsync_seconds']*1e3:.2f} ms last): recovery "
              f"replayed {crashed.last_recovery['replayed']} of "
              f"{crashed.last_recovery['records_scanned']} logged records "
              f"→ days {days[0]}-{days[-1]} all present "
              f"(acked loss: {6 - len(days)})")
        crashed.close()

    # failures aren't an exception, they're the workload: the serving
    # plane is threaded with named failpoints (core/faults.py) so chaos
    # drills run in-process.  Arm a fault schedule and the plane degrades
    # instead of failing — stale answers are served flagged, with an
    # honestly widened ε; a per-tenant circuit breaker quarantines a
    # poisoned service (probing it back after cooldown) while the rest
    # keep serving; the integrity scrubber rebuilds bit-rotted summaries
    # from the WAL.  health() is the one pane of glass over all of it.
    print("\n== chaos drill (failpoints, degraded serving, self-healing) ==")
    import dataclasses

    from repro.core import BreakerPolicy, TenantQuarantined, faults

    with tempfile.TemporaryDirectory() as d:
        chaos = TenantRegistry(
            num_buckets=256,
            wal_dir=os.path.join(d, "wal"),
            breaker=BreakerPolicy(threshold=2, cooldown=30.0),
        )
        week = {dy: svc_days["svc-00"][dy] for dy in range(6)}
        chaos.ingest_many("frontend", week)
        # degraded_ok opts this dashboard into stale-but-flagged serving:
        # fresh answers also record the membership snapshot that later
        # bounds how far a stale answer can have drifted
        [fresh] = chaos.query_many([("frontend", 0, 6)], 64,
                                   strict=False, degraded_ok=True)

        # the merge path goes down mid-refresh: the cached last-known-good
        # answer is served, flagged, its ε widened by the drift since
        chaos.ingest("frontend", 6, svc_days["svc-00"][6])
        with faults.inject("tenant.merge"):
            [ans] = chaos.query_many([("frontend", 0, 6)], 64,
                                     strict=False, degraded_ok=True)
        drift = len(svc_days["svc-00"][6])
        print(f"merge dispatch down → served last-known-good "
              f"(degraded={ans.degraded}, ε {fresh[1]:.0f} → {ans[1]:.0f}: "
              f"widened by the {drift:,} records of drift)")

        # a poisoned tenant trips its breaker and is quarantined at the
        # door; healthy tenants never notice
        with faults.inject("tenant.apply",
                           match=lambda ctx: ctx.get("tenant") == "mobile"):
            rejected = quarantined = 0
            for day in range(3):
                try:
                    chaos.ingest("mobile", day, week[day])
                except faults.FaultError:
                    rejected += 1
                except TenantQuarantined:
                    quarantined += 1
        chaos.ingest("frontend", 7, week[0])  # unaffected
        print(f"poisoned tenant: {rejected} failures tripped the breaker, "
              f"{quarantined} later ingest rejected at the door; "
              f"healthy tenants unaffected")

        # bit-rot on disk pages: the scrubber catches the bad checksum and
        # rebuilds the partition from its WAL records
        s = chaos["frontend"].summaries[3]
        bad = np.array(s.sizes)
        bad[0] += 1.0
        chaos["frontend"].summaries[3] = dataclasses.replace(s, sizes=bad)
        rep = chaos.scrub(repair=True)
        health = chaos.health()
        print(f"scrubber: {rep['checked']} summaries checked, corrupt "
              f"{rep['corrupt']} → repaired {rep['repaired']} by WAL "
              f"replay; health: status={health['status']}, "
              f"quarantined={health['quarantined']}, "
              f"degraded_served={health['degraded_served']}")
        chaos.close()

    # dashboards that poll re-ask unchanged questions forever.  A
    # standing subscription inverts it: register the window once, get an
    # Update pushed only when new data actually lands — subscribers
    # sharing a window share one evaluation, and everything stale on a
    # tick is answered with ONE cross-tenant merge dispatch
    # (serve/subscriptions.py)
    print("\n== standing dashboard (push subscriptions, no polling) ==")
    from repro.serve.subscriptions import SubscriptionPlane

    dash = TenantRegistry(num_buckets=256)
    dash.ingest_many("frontend", {dy: svc_days["svc-00"][dy]
                                  for dy in range(6)})
    plane = SubscriptionPlane(dash)
    panels = {"month": (0, 30), "week": (0, 6), "today": (6, 6)}
    subs = {label: plane.subscribe("frontend", lo, hi, 64)
            for label, (lo, hi) in panels.items()}
    wall = plane.subscribe("frontend", 0, 6, 64)  # shares the week window
    plane.flush()  # initial answers pushed
    for sub in [*subs.values(), wall]:
        sub.drain()
    dash.ingest("frontend", 6, svc_days["svc-00"][6])  # day 6 arrives...
    plane.flush()  # ...and every panel's update is already in its queue
    for label, sub in subs.items():
        up = sub.drain()[-1]
        p95 = float(quantile(up.hist, jnp.float32(0.95)))
        print(f"pushed {label:5s} (days {up.lo:2d}-{up.hi:2d}): "
              f"p95={p95*1e3:7.2f} ms  ε_max={up.eps:.0f}  "
              f"lag={up.lag_seconds*1e3:.1f} ms")
    stats = plane.stats()
    print(f"{stats['subscriptions']} standing panels, one ingest tick → "
          f"{stats['updates_delivered']} updates pushed, "
          f"{stats['windows_evaluated']} window evals "
          f"({stats['dedup_saved']} saved by sharing), "
          f"{stats['eval_batches']} merge dispatches total")
    plane.close()
    dash.close()
    print("\nlog_analytics OK")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI: same pipeline, minutes less data")
    main(ap.parse_args().smoke)
