"""Inject the current roofline tables into EXPERIMENTS.md (idempotent)."""
import sys, re
sys.path.insert(0, "src")
from benchmarks.roofline_report import render

marker = "<!-- ROOFLINE_TABLES -->"
txt = open("EXPERIMENTS.md").read()
head = txt.split(marker)[0]
open("EXPERIMENTS.md", "w").write(head + marker + "\n" + render() + "\n")
print("EXPERIMENTS.md roofline tables updated")
