#!/usr/bin/env python
"""Repo static-analysis gate: lint rules + lock-discipline graph.

Usage (the CI invocation)::

    PYTHONPATH=src python scripts/analyze.py src tests benchmarks \
        --baseline analysis_baseline.json

Exit codes: 0 — no findings outside the ratchet baseline; 1 — new
findings (printed, one per line); 2 — bad invocation/baseline.

``--update-baseline`` rewrites the baseline to the current finding set,
keeping justifications for fingerprints that survive; fresh entries get
a ``TODO`` justification the gate will reject until a human fills it in.
See ANALYSIS.md for the rule catalogue and the ratchet workflow.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(
    0,
    os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src"),
)

from repro.analysis.findings import (  # noqa: E402
    apply_baseline,
    load_baseline,
    save_baseline,
)
from repro.analysis.lint import SourceFile, run_lint  # noqa: E402
from repro.analysis.lockgraph import run_lockgraph  # noqa: E402

# fixture trees with *seeded* violations (the analyzer's own tests) and
# generated/vendored code never gate CI
EXCLUDE_DIR_NAMES = {
    "__pycache__", ".git", ".claude", "analysis_fixtures", ".pytest_cache",
}


def collect(paths: list[str], root: str) -> list[SourceFile]:
    out: list[SourceFile] = []
    for p in paths:
        ap = os.path.join(root, p) if not os.path.isabs(p) else p
        if os.path.isfile(ap):
            out.append(_parse(ap, root))
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = sorted(
                d for d in dirnames if d not in EXCLUDE_DIR_NAMES
            )
            for name in sorted(filenames):
                if name.endswith(".py"):
                    out.append(_parse(os.path.join(dirpath, name), root))
    return out


def _parse(path: str, root: str) -> SourceFile:
    with open(path) as f:
        source = f.read()
    rel = os.path.relpath(path, root)
    return SourceFile.parse(rel, source)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("paths", nargs="+", help="files or directories to scan")
    ap.add_argument("--baseline", default=None,
                    help="ratchet baseline JSON (see ANALYSIS.md)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline to the current finding set")
    ap.add_argument("--root", default=os.getcwd(),
                    help="repo root paths are resolved/reported against")
    args = ap.parse_args(argv)

    try:
        files = collect(args.paths, args.root)
    except (OSError, SyntaxError) as e:
        print(f"analyze: cannot parse inputs: {e}", file=sys.stderr)
        return 2

    findings = run_lint(files) + run_lockgraph(files)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))

    if args.update_baseline:
        if not args.baseline:
            print("analyze: --update-baseline needs --baseline",
                  file=sys.stderr)
            return 2
        try:
            old = load_baseline(args.baseline)
        except ValueError:
            old = {}
        save_baseline(args.baseline, findings, old)
        print(f"analyze: wrote {len(findings)} finding(s) to "
              f"{args.baseline}")
        return 0

    try:
        baseline = load_baseline(args.baseline) if args.baseline else {}
    except ValueError as e:
        print(f"analyze: {e}", file=sys.stderr)
        return 2

    res = apply_baseline(findings, baseline)
    for f in res.new:
        print(f.render())
    if res.suppressed:
        print(
            f"analyze: {len(res.suppressed)} baselined finding(s) "
            "suppressed (ratchet)"
        )
    for fp in res.stale:
        print(
            f"analyze: stale baseline entry (finding fixed — remove it): "
            f"{fp}"
        )
    n_files = len(files)
    if res.new:
        print(
            f"analyze: {len(res.new)} new finding(s) across {n_files} "
            "file(s) — fix them or (exceptionally) justify them in the "
            "baseline",
            file=sys.stderr,
        )
        return 1
    print(f"analyze: clean — {n_files} file(s), 0 new findings")
    return 0


if __name__ == "__main__":
    sys.exit(main())
