"""Flat vs segment-tree Merger: summaries merged, latency, throughput, error.

The serving-path benchmark for the interval engine (core/interval_tree.py):
for window sizes ``W = 16 … 4096`` partitions it reports

  * summaries merged per query — ``W`` for the flat Merger vs the tree's
    ``≤ 2·log2 W`` canonical nodes (the asymptotic win);
  * per-query latency of the flat path, the tree path (cold cache), and the
    tree path answered from its LRU (hot cache);
  * answered-queries/sec of ``query_many`` — a mixed batch of window
    lengths padded to one static shape and served by a single jitted merge
    (the millions-of-concurrent-users path);
  * reported ``ε_total`` vs the measured worst bucket deviation of the tree
    answer, as a fraction of the ideal bucket size ``N/β`` (the guarantee,
    and how much head-room it leaves in practice).

Run standalone: ``PYTHONPATH=src python benchmarks/interval_query.py``
or as a section of ``python -m benchmarks.run --only interval_query``.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import HistogramStore

WINDOWS = (16, 64, 256, 1024, 4096)
T = 256  # summary resolution
BETA = 64  # query resolution
N_PER = 2048  # values per partition (small: we benchmark the Merger)
BATCH = 64  # query_many batch size


def _timed(fn, reps: int) -> float:
    fn()  # warm (jit compile, cache fill excluded separately)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    if hasattr(out, "block_until_ready"):
        out.block_until_ready()
    return (time.perf_counter() - t0) / reps


def _make_store(W: int, rng) -> tuple[HistogramStore, np.ndarray]:
    store = HistogramStore(num_buckets=T)
    parts = {
        d: (rng.lognormal(-1.8, 0.55, size=N_PER).astype(np.float32))
        for d in range(W)
    }
    store.ingest_many(parts)  # level-batched tree build: log2 W dispatches
    pooled = np.sort(np.concatenate([parts[d] for d in range(W)]))
    return store, pooled


def _random_intervals(W: int, rng, k: int):
    out = []
    for _ in range(k):
        lo = int(rng.integers(0, W))
        hi = int(rng.integers(lo, W))
        out.append((lo, hi))
    return out


def main(emit) -> None:
    rng = np.random.default_rng(0)
    for W in WINDOWS:
        store, pooled = _make_store(W, rng)
        tree = store._tree
        full = (0, W - 1)

        # --- summaries merged per query (the asymptotic claim) -----------
        nodes_full = len(tree.decompose(*full))
        worst = max(
            len(tree.decompose(lo, hi))
            for lo, hi in _random_intervals(W, rng, 64) + [full]
        )
        emit(
            f"interval_w{W}_summaries_merged",
            float(worst),
            f"tree worst-case vs flat {W} (full-range {nodes_full}; "
            f"bound 2*log2={2 * max(1, (W - 1).bit_length())})",
        )

        # --- per-query latency -------------------------------------------
        reps = 5 if W >= 1024 else 20
        t_flat = _timed(
            lambda: store.query(*full, BETA, engine="flat")[0].sizes, reps
        )
        # cold tree: defeat the LRU by alternating distinct windows
        spans = _random_intervals(W, rng, 128)

        def tree_cold(it=iter(range(10**9))):
            lo, hi = spans[next(it) % len(spans)]
            store._tree._cache.clear()
            return store.query(lo, hi, BETA)[0].sizes

        for lo, hi in spans:  # pre-compile every padded node-set shape
            store.query(lo, hi, BETA)
        t_tree = _timed(tree_cold, reps)
        t_hot = _timed(lambda: store.query(*full, BETA)[0].sizes, 100)
        emit(f"interval_w{W}_flat_query", t_flat * 1e6, f"merges {W} summaries")
        emit(
            f"interval_w{W}_tree_query",
            t_tree * 1e6,
            f"merges <= {worst} node summaries, cache off",
        )
        emit(f"interval_w{W}_tree_query_cached", t_hot * 1e6, "LRU hit path")

        # --- batched throughput (answered queries / sec) ------------------
        batch = _random_intervals(W, rng, BATCH)
        store.query_many(batch, BETA)  # warm the static-shape compile
        t_batch = _timed(lambda: store.query_many(batch, BETA)[-1][0].sizes, 5)
        emit(
            f"interval_w{W}_query_many_qps",
            BATCH / t_batch,
            f"batch of {BATCH} mixed windows, one jitted merge",
        )

        # --- reported ε vs TRUE bucket occupancy error --------------------
        h, eps = store.query(*full, BETA)
        b = np.asarray(h.boundaries, np.float64)
        n = pooled.size
        true_sizes = (
            np.searchsorted(pooled, b[1:], side="left")
            - np.searchsorted(pooled, b[:-1], side="left")
        ).astype(np.float64)
        true_sizes[-1] += np.sum(pooled == b[-1])  # last bucket right-closed
        measured = float(np.abs(true_sizes - n / BETA).max())
        emit(
            f"interval_w{W}_eps_reported_vs_measured",
            eps / (n / BETA) * 100.0,
            f"measured {measured / (n / BETA) * 100.0:.2f}% of bucket "
            f"(guarantee honoured: {measured <= eps})",
        )


if __name__ == "__main__":
    print("name,us_per_call_or_value,derived")
    main(lambda name, v, derived="": print(f"{name},{v:.1f},{derived}", flush=True))
