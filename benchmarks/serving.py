"""Standing-query push plane vs naive dashboard re-pull.

The serving benchmark for ``serve/subscriptions.py``: N dashboard
clients hold standing queries over a multi-tenant registry and the
plane pushes updates only to the subscribers whose windows actually
went stale — all stale windows of a tick answered with ONE cross-tenant
``query_many`` merge dispatch, deduplicated across subscribers sharing
a window.  The baseline is what dashboards do without a push plane:
every refresh re-pulls **every** subscription with its own singleton
``query_many`` call.  Reported:

  * **push_tick** — mark-stale → flush barrier for one ingest tick
    (10 % of tenants move): update-latency p50/p99 from the per-update
    ``lag_seconds`` the plane stamps, plus the machine-checked
    one-merge-dispatch-per-tick assertion;
  * **pull_refresh** — a full naive re-pull of every subscription after
    an identical ingest tick (per-tenant LRUs serve the unchanged ones,
    exactly as a polling dashboard would see);
  * **dedup** — the plane's counters: windows evaluated vs subscriber
    deliveries, evals saved by window sharing.

Results print as CSV rows and are written to ``BENCH_serving.json``
(schema ``bench_serving/v1``; CI smoke-checks ``one_dispatch_per_tick``
and ``push_vs_pull_speedup >= 5`` at tiny sizes via ``--smoke``).
Every run appends a ``trajectory`` entry so the file carries its own
history.

Run standalone: ``PYTHONPATH=src python benchmarks/serving.py``
or as a section of ``python -m benchmarks.run --only serving``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import TenantRegistry
from repro.serve.subscriptions import SubscriptionPlane

SCHEMA = "bench_serving/v1"

T = 16  # summary resolution per window (serving regime: many small
BETA = 16  # per-metric summaries; dispatch + fan-out overhead dominates)
N_PER = 64
PARTS = 4  # partitions per tenant; window keys split them 2+2
WINDOWS = ((0, 1), (2, 3))


def _build(n_tenants: int, rng) -> TenantRegistry:
    reg = TenantRegistry(num_buckets=T, shared_arena=True)
    for t in range(n_tenants):
        # store-level ingest: prime without ticking the (future) plane
        reg.tenant(f"svc{t:04d}").ingest_many(
            {
                d: rng.lognormal(-1.8, 0.55, size=N_PER).astype(np.float32)
                for d in range(PARTS)
            }
        )
    return reg


def _subscribe_all(plane, names, subs_per_window):
    by_tenant: dict[str, list] = {}
    for name in names:
        for lo, hi in WINDOWS:
            for _ in range(subs_per_window):
                sub = plane.subscribe(name, lo, hi, BETA, queue_cap=4)
                by_tenant.setdefault(name, []).append(sub)
    return by_tenant


def _tick(reg, plane, subset, pid, rng):
    """One ingest tick: 10 % of tenants move, one mark, one flush."""
    for name in subset:
        reg.tenant(name).ingest(
            pid, rng.lognormal(-1.8, 0.55, size=N_PER).astype(np.float32)
        )
    d0 = reg.merge_dispatches
    t0 = time.perf_counter()
    plane.mark_stale(subset)
    plane.flush()
    seconds = time.perf_counter() - t0
    return seconds, reg.merge_dispatches - d0


def main(
    emit,
    *,
    n_tenants: int = 1000,
    subs_per_window: int = 5,
    n_ticks: int = 10,
    pull_cycles: int = 3,
    out_path: str = "BENCH_serving.json",
) -> dict:
    rng = np.random.default_rng(0)
    reg = _build(n_tenants, rng)
    plane = SubscriptionPlane(reg)
    names = reg.names()
    by_tenant = _subscribe_all(plane, names, subs_per_window)
    n_subs = len(plane)
    subset_n = max(1, n_tenants // 10)

    # initial answers (and the batched-merge compile) land here, untimed
    plane.flush()
    for subs in by_tenant.values():
        for sub in subs:
            sub.drain()

    # a tick packs only the subset's stale windows — a different stack
    # shape than the initial full flush — so warm that compile untimed
    _tick(reg, plane, names[:subset_n], 0, rng)
    for name in names[:subset_n]:
        for sub in by_tenant[name]:
            sub.drain()

    # ---- push: per-tick latency + the one-dispatch guarantee ----------
    lags: list[float] = []
    tick_seconds: list[float] = []
    one_dispatch = True
    updates = 0
    for tick in range(n_ticks):
        subset = names[(tick * subset_n) % n_tenants:][:subset_n]
        seconds, dispatches = _tick(
            reg, plane, subset, tick % PARTS, rng
        )
        one_dispatch = one_dispatch and dispatches == 1
        tick_seconds.append(seconds)
        for name in subset:
            for sub in by_tenant[name]:
                for up in sub.drain():
                    lags.append(up.lag_seconds)
                    updates += 1
    push_per_tick = float(np.mean(tick_seconds))
    p50_ms = float(np.percentile(lags, 50) * 1e3)
    p99_ms = float(np.percentile(lags, 99) * 1e3)

    # ---- pull baseline: naive full re-pull after an identical tick ----
    keys = [
        (name, lo, hi)
        for name in names
        for lo, hi in WINDOWS
        for _ in range(subs_per_window)
    ]
    for name, lo, hi in keys[: 2 * subs_per_window]:  # compile warmup
        reg.query_many([(name, lo, hi)], BETA, strict=False)
    pull_times = []
    for cycle in range(pull_cycles):
        subset = names[(cycle * subset_n) % n_tenants:][:subset_n]
        for name in subset:  # same staleness profile as a push tick
            reg.tenant(name).ingest(
                cycle % PARTS,
                rng.lognormal(-1.8, 0.55, size=N_PER).astype(np.float32),
            )
        t0 = time.perf_counter()
        for name, lo, hi in keys:
            reg.query_many([(name, lo, hi)], BETA, strict=False)
        pull_times.append(time.perf_counter() - t0)
    pull_per_refresh = float(np.mean(pull_times))
    speedup = pull_per_refresh / push_per_tick

    stats = plane.stats()
    plane.close()
    reg.close()

    # per-run history: carry the previous file's trajectory forward so
    # the json records how the headline numbers move across commits
    trajectory = []
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                trajectory = json.load(f).get("trajectory", [])
        except (json.JSONDecodeError, OSError):
            trajectory = []
    trajectory.append(
        {
            "subscribers": n_subs,
            "tenants": n_tenants,
            "update_p99_ms": p99_ms,
            "push_vs_pull_speedup": speedup,
            "one_dispatch_per_tick": one_dispatch,
        }
    )
    result = {
        "schema": SCHEMA,
        "tenants": n_tenants,
        "subscribers": n_subs,
        "windows": len(names) * len(WINDOWS),
        "subs_per_window": subs_per_window,
        "T": T,
        "beta": BETA,
        "ticks": n_ticks,
        "tenants_per_tick": subset_n,
        "push": {
            "seconds_per_tick": push_per_tick,
            "updates_per_tick": updates / n_ticks,
            "update_p50_ms": p50_ms,
            "update_p99_ms": p99_ms,
        },
        "pull": {
            "seconds_per_refresh": pull_per_refresh,
            "queries_per_refresh": len(keys),
        },
        "dedup": {
            "windows_evaluated": stats["windows_evaluated"],
            "updates_delivered": stats["updates_delivered"],
            "dedup_saved": stats["dedup_saved"],
            "eval_batches": stats["eval_batches"],
        },
        # headline claims hoisted for the CI schema check
        "update_p50_ms": p50_ms,
        "update_p99_ms": p99_ms,
        "push_vs_pull_speedup": speedup,
        "one_dispatch_per_tick": one_dispatch,
        "trajectory": trajectory,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    emit(
        "serving_push_tick_ms",
        push_per_tick * 1e3,
        f"ms/tick, {n_subs} subs, {subset_n} tenants move, "
        f"one_dispatch={one_dispatch}",
    )
    emit(
        "serving_update_p99_ms",
        p99_ms,
        f"p99 push latency (p50 {p50_ms:.2f} ms, {len(lags)} updates)",
    )
    emit(
        "serving_pull_refresh_ms",
        pull_per_refresh * 1e3,
        f"ms for a naive re-pull of all {len(keys)} subscriptions",
    )
    emit(
        "serving_push_vs_pull_speedup",
        speedup,
        f"x per refresh cycle (target >= 5x); dedup saved "
        f"{stats['dedup_saved']} evals",
    )
    emit("serving_json", 0.0, f"written to {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes for CI: validates the pipeline + JSON schema only",
    )
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--tenants", type=int, default=1000)
    args = ap.parse_args()
    kw = dict(out_path=args.out, n_tenants=args.tenants)
    if args.smoke:
        kw.update(n_tenants=24, subs_per_window=12, n_ticks=4,
                  pull_cycles=2)
    print("name,value,derived")
    main(
        lambda name, v, derived="": print(
            f"{name},{v:.1f},{derived}", flush=True
        ),
        **kw,
    )
