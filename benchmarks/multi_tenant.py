"""Cross-tenant Merger throughput: per-store loop vs registry-batched.

The serving-side benchmark for the multi-tenant registry (core/tenant.py):
with N tenants (per-service latency metrics, say) a dashboard refresh asks
one interval query per tenant.  Answering with a loop over per-tenant
stores costs N jitted merge dispatches; ``TenantRegistry.query_many``
packs every tenant's canonical node set into one static-shape block and
answers the whole refresh with **exactly one** dispatch.  Reported per
tenant count:

  * **per_store_loop**  — ``store.query`` per (tenant, window), cold LRU;
  * **registry_batched** — one ``query_many`` over the same queries, cold
    LRU, plus the machine-checked one-dispatch assertion (via the
    registry's ``merge_dispatches``/``merge_shapes`` counters — the
    summarize_shapes idiom of the ingest benchmark);
  * **registry_cached** — the same batch again, LRU warm: zero dispatches.

Results print as CSV rows and are written to ``BENCH_tenant.json``
(schema ``bench_tenant/v1``; CI smoke-checks it at tiny sizes via
``--smoke``).

Run standalone: ``PYTHONPATH=src python benchmarks/multi_tenant.py``
or as a section of ``python -m benchmarks.run --only tenant``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import TenantRegistry

SCHEMA = "bench_tenant/v1"

T = 32  # summary resolution per metric partition (serving regime: many
BETA = 16  # small per-metric summaries, cheap per-query merges — the
N_PER = 512  # dispatch overhead the registry amortizes is then the
PARTS = 4  # dominant per-query cost, as on a real accelerator)


def _build_registry(n_tenants: int, rng) -> TenantRegistry:
    reg = TenantRegistry(num_buckets=T)
    for t in range(n_tenants):
        reg.ingest_many(
            f"svc{t:04d}",
            {
                d: rng.lognormal(-1.8, 0.55, size=N_PER).astype(np.float32)
                for d in range(PARTS)
            },
        )
    return reg


def _queries(reg: TenantRegistry, rng) -> list[tuple[str, int, int]]:
    out = []
    for name in reg.names():
        lo = int(rng.integers(0, PARTS))
        hi = int(rng.integers(lo, PARTS))
        out.append((name, lo, hi))
    return out


def _clear_caches(reg: TenantRegistry) -> None:
    for name in reg.names():
        reg[name]._tree._cache.clear()


def _timed_cold(reg, fn, reps: int) -> float:
    """Average seconds/call with the per-tenant LRUs cleared before each
    call — both paths answer every query from node merges, not the cache."""
    best = []
    for _ in range(reps):
        _clear_caches(reg)
        t0 = time.perf_counter()
        fn()
        best.append(time.perf_counter() - t0)
    return float(np.mean(best))


def main(
    emit,
    *,
    n_tenants: int = 256,
    reps: int = 5,
    out_path: str = "BENCH_tenant.json",
) -> dict:
    rng = np.random.default_rng(0)
    reg = _build_registry(n_tenants, rng)
    qs = _queries(reg, rng)
    Q = len(qs)

    def loop():
        return [reg[name].query(lo, hi, BETA) for name, lo, hi in qs]

    def batched():
        return reg.query_many(qs, BETA)

    # warm every compile shape on both paths before timing
    loop()
    _clear_caches(reg)
    batched()

    t_loop = _timed_cold(reg, loop, reps)
    t_batch = _timed_cold(reg, batched, reps)

    # machine-checked: ONE merge dispatch serves the whole cold batch …
    _clear_caches(reg)
    reg.merge_dispatches = 0
    reg.merge_shapes.clear()
    batched()
    dispatches_per_batch = reg.merge_dispatches
    shapes = sorted(reg.merge_shapes)
    # … and a warm repeat of the same batch costs zero
    t0 = time.perf_counter()
    batched()
    t_cached = time.perf_counter() - t0
    dispatches_cached = reg.merge_dispatches - dispatches_per_batch

    speedup = t_loop / t_batch
    result = {
        "schema": SCHEMA,
        "tenants": n_tenants,
        "partitions_per_tenant": PARTS,
        "values_per_partition": N_PER,
        "T": T,
        "beta": BETA,
        "queries": Q,
        "per_store_loop": {
            "seconds": t_loop,
            "qps": Q / t_loop,
            "dispatches_per_batch": Q,
        },
        "registry_batched": {
            "seconds": t_batch,
            "qps": Q / t_batch,
            "dispatches_per_batch": dispatches_per_batch,
            "merge_shapes": [list(s) for s in shapes],
        },
        "registry_cached": {
            "seconds": t_cached,
            "qps": Q / t_cached,
            "dispatches_per_batch": dispatches_cached,
        },
        "speedup_registry_vs_loop": speedup,
        "one_dispatch": dispatches_per_batch == 1,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    emit(
        "tenant_per_store_loop_qps",
        Q / t_loop,
        f"queries/s, {Q} tenants, {Q} dispatches per refresh",
    )
    emit(
        "tenant_registry_batched_qps",
        Q / t_batch,
        f"queries/s, {dispatches_per_batch} dispatch(es) per refresh "
        f"(shapes {shapes})",
    )
    emit(
        "tenant_registry_cached_qps",
        Q / t_cached,
        f"queries/s from the per-tenant LRUs, "
        f"{dispatches_cached} dispatches",
    )
    emit(
        "tenant_speedup_batched_vs_loop",
        speedup,
        f"x at {n_tenants} tenants (target >= 5x at >= 100)",
    )
    emit("tenant_json", 0.0, f"written to {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes for CI: validates the pipeline + JSON schema only",
    )
    ap.add_argument("--out", default="BENCH_tenant.json")
    ap.add_argument("--tenants", type=int, default=256)
    args = ap.parse_args()
    kw = dict(out_path=args.out, n_tenants=args.tenants)
    if args.smoke:
        kw.update(n_tenants=12, reps=2)
    print("name,value,derived")
    main(
        lambda name, v, derived="": print(
            f"{name},{v:.1f},{derived}", flush=True
        ),
        **kw,
    )
