"""Render the dry-run JSONs into the §Dry-run / §Roofline tables.

Reads results/dryrun/*.json (written by repro.launch.dryrun) and emits a
markdown table per mesh plus per-cell one-liners on what would move the
dominant term.  Used both standalone and by benchmarks.run.
"""
from __future__ import annotations

import glob
import json
import os

GB = 1e9

ADVICE = {
    ("compute_s", "train"): "more chips or lower-precision matmuls; compute-bound is the goal state",
    ("memory_s", "train"): "fuse residual/norm chains & cast params pre-gather (bytes term counts bf16 use at fp32 today)",
    ("collective_s", "train"): "cast params to bf16 BEFORE the FSDP all-gather and shrink SP all-gathers (biggest single lever)",
    ("memory_s", "prefill"): "KV-cache write + attention reads dominate; flash-style fused attention kernel removes logit round-trips",
    ("collective_s", "prefill"): "sequence-parallel boundary all-gathers; overlap with per-layer compute",
    ("memory_s", "decode"): "decode is weight-streaming-bound by nature; int8/fp8 weights or wider batch raise arithmetic intensity",
    ("collective_s", "decode"): "TP all-reduces per token; fuse QKV/out projections or use 1D TP on d_ff only",
    ("memory_s", "decode_long"): "KV reads dominate; KV quantization (int8 KV) halves the term",
    ("collective_s", "decode_long"): "split-KV softmax combine collectives; tree-reduce over (data,model)",
}


def load(out_dir: str = "results/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    if r["status"] == "skip":
        return (
            f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | — | — | "
            f"{r['reason'][:60]} |"
        )
    if r["status"] != "ok":
        return (
            f"| {r['arch']} | {r['shape']} | ERROR | — | — | — | — | — | "
            f"{r.get('error','')[:60]} |"
        )
    t = r["terms"]
    dom = r["dominant"].replace("_s", "")
    mem = r.get("memory", {}).get("peak_bytes_per_device", 0) / GB
    advice = ADVICE.get((r["dominant"], r["kind"]), "")
    return (
        f"| {r['arch']} | {r['shape']} | ok | {t['compute_s']:.4f} | "
        f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | **{dom}** | "
        f"{r['useful_compute_ratio']:.2f} | {mem:.1f} GB | {advice} |"
    )


def render(out_dir: str = "results/dryrun") -> str:
    recs = load(out_dir)
    lines = []
    for mesh in ("16x16", "2x16x16"):
        sub = [r for r in recs if r["mesh"] == mesh]
        if not sub:
            continue
        lines.append(f"\n### Mesh {mesh} ({256 if mesh=='16x16' else 512} chips)\n")
        lines.append(
            "| arch | shape | status | compute (s) | memory (s) | "
            "collective (s) | dominant | useful-FLOP ratio | peak HBM/dev | "
            "what moves the dominant term |"
        )
        lines.append("|" + "---|" * 10)
        order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
        for r in sorted(sub, key=lambda r: (r["arch"], order.get(r["shape"], 9))):
            lines.append(fmt_row(r))
    ok = [r for r in recs if r["status"] == "ok"]
    skip = [r for r in recs if r["status"] == "skip"]
    err = [r for r in recs if r["status"] not in ("ok", "skip")]
    lines.append(
        f"\n{len(ok)} compiled ok, {len(skip)} assignment-skips, {len(err)} errors."
    )
    return "\n".join(lines)


def main(emit=None):
    txt = render()
    print(txt)
    if emit:
        for r in load():
            if r["status"] == "ok":
                emit(
                    f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                    r["roofline_step_s"] * 1e6,
                    f"dominant={r['dominant']} useful={r['useful_compute_ratio']:.2f}",
                )


if __name__ == "__main__":
    main()
