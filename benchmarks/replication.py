"""Hot-standby replication: ship overhead, replica lag, failover drill.

Three questions, machine-checked (the acceptance criteria of the
replication subsystem, see core/replication.py):

  * **What does ship-before-ack cost?**  The same batched stream is
    ingested through a WAL-only registry and a WAL + ``Replicator``
    (dir transport) registry.  The shipper moves the freshly committed
    bytes and rewrites the (un-fsynced) manifest per group commit, so
    its cost must stay marginal next to the fsync it rides behind:
    reported as ``overhead_ratio``, CI asserts ≤ 1.1×.
  * **How stale is a tailing replica?**  Over ingest→tail cycles the
    follower records its post-tail staleness (manifest age) and the
    tail-pass latency; reported as p50/p99 seconds.
  * **What does failover cost — and lose?**  The primary is killed
    (no close, no checkpoint) mid-stream, the follower promotes with
    the epoch fence, and the drill measures time-to-first-answer on the
    promoted registry.  ``acked_loss_count`` must be 0 and the promoted
    answers must bit-match a never-crashed replica (``bit_identical``);
    the deposed primary's next append must raise ``PrimaryFenced``.

Results print as CSV rows and are written to ``BENCH_replication.json``
(schema ``bench_replication/v1``; CI smoke-checks it at tiny sizes via
``--smoke``).

Run standalone: ``PYTHONPATH=src python benchmarks/replication.py``
or as a section of ``python -m benchmarks.run --only replication``.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import TenantRegistry
from repro.core.replication import DirTransport, Follower, Replicator
from repro.core.resilience import PrimaryFenced

SCHEMA = "bench_replication/v1"

T = 32
BETA = 16


def _batches(parts: dict[int, np.ndarray], size: int):
    pids = sorted(parts)
    for i in range(0, len(pids), size):
        yield {pid: parts[pid] for pid in pids[i : i + size]}


def _ingest_seconds(reg, parts, batch: int, reps: int) -> float:
    """Best-of-``reps`` wall time to ingest the whole stream in batches
    (fresh pids per rep keep everything append-only and jit-warm)."""
    out = []
    n = len(parts)
    for r in range(reps):
        shifted = {pid + r * 10 * n: v for pid, v in parts.items()}
        t0 = time.perf_counter()
        for b in _batches(shifted, batch):
            reg.ingest_many("svc", b)
        out.append(time.perf_counter() - t0)
    return float(min(out))


def _pctl(xs, q) -> float:
    return float(np.percentile(np.asarray(xs, dtype=np.float64), q))


def main(
    emit,
    *,
    partitions: int = 64,
    values: int = 8192,
    batch: int = 8,
    reps: int = 3,
    out_path: str = "BENCH_replication.json",
) -> dict:
    rng = np.random.default_rng(0)
    parts = {
        pid: rng.lognormal(-1.8, 0.55, size=values).astype(np.float32)
        for pid in range(partitions)
    }
    base = tempfile.mkdtemp(prefix="bench-replication-")
    try:
        # ---- ship overhead: WAL+ship vs WAL-only (group commit) ----
        warm = TenantRegistry(num_buckets=T)
        warm.ingest_many("svc", next(_batches(parts, batch)))  # jit warm
        warm.close()

        wal_only = TenantRegistry(
            num_buckets=T, wal_dir=os.path.join(base, "wal-base")
        )
        wal_seconds = _ingest_seconds(wal_only, parts, batch, reps)
        wal_only.close()

        shipped = TenantRegistry(
            num_buckets=T, wal_dir=os.path.join(base, "wal-ship")
        )
        repl = Replicator(
            shipped._wal, [DirTransport(os.path.join(base, "standby-ovh"))]
        ).attach(shipped)
        ship_seconds = _ingest_seconds(shipped, parts, batch, reps)
        ship_stats = repl.stats()
        shipped.close()
        overhead_ratio = ship_seconds / wal_seconds

        # ---- replica lag over ingest→tail cycles ----
        preg = TenantRegistry(
            num_buckets=T, wal_dir=os.path.join(base, "wal-lag")
        )
        standby = os.path.join(base, "standby-lag")
        Replicator(preg._wal, [DirTransport(standby)]).attach(preg)
        follower = Follower(standby, num_buckets=T)
        lag_seconds, tail_ms = [], []
        for i, b in enumerate(_batches(parts, batch)):
            preg.ingest_many("svc", {p + 10**6: v for p, v in b.items()})
            t0 = time.perf_counter()
            follower.tail()
            tail_ms.append(1e3 * (time.perf_counter() - t0))
            lag = follower.lag()
            assert lag["records"] == 0  # caught up after every tail
            lag_seconds.append(lag["seconds"])
        follower.close()
        preg.close()

        # ---- failover drill: kill -9 → promote → first answer ----
        d = os.path.join(base, "drill")
        reg = TenantRegistry(num_buckets=T, wal_dir=os.path.join(d, "wal"))
        drill_standby = os.path.join(d, "standby")
        drill_repl = Replicator(
            reg._wal, [DirTransport(drill_standby)]
        ).attach(reg)
        fol = Follower(drill_standby, num_buckets=T)
        n_acked = min(partitions, 16)
        acked = {pid: parts[pid][: min(values, 2048)] for pid in range(n_acked)}
        for pid, v in acked.items():
            reg.ingest("svc", pid, v)  # returned ⇒ durable AND shipped
        fol.tail()  # warm standby: tailing continuously, like production
        reg.ingest("svc", n_acked, acked[0])  # in-flight at the kill
        old_wal = reg._wal
        fence = drill_repl.fence
        del reg  # kill -9: no close, no checkpoint

        t0 = time.perf_counter()
        promoted = fol.promote(fence=fence)
        [first] = promoted.query_many(
            [("svc", 0, n_acked - 1)], BETA, strict=False
        )
        time_to_first_answer = time.perf_counter() - t0

        acked_loss = sum(
            1 for pid in acked if pid not in promoted["svc"].summaries
        )
        ref = TenantRegistry(num_buckets=T)
        ref.ingest_many(
            "svc",
            {
                pid: (acked[pid] if pid in acked else acked[0])
                for pid in promoted["svc"].ids()
            },
        )
        [(wh, we)] = ref.query_many(
            [("svc", 0, n_acked - 1)], BETA, strict=False
        )
        hist, eps = first
        bit_identical = (
            hist is not None
            and np.array_equal(
                np.asarray(hist.boundaries), np.asarray(wh.boundaries)
            )
            and np.array_equal(np.asarray(hist.sizes), np.asarray(wh.sizes))
            and eps == we
        )
        ref.close()
        try:
            old_wal.append("svc", 10**6, acked[0])
            fenced = False
        except PrimaryFenced:
            fenced = True
        old_wal.close()
        fol.close()

        result = {
            "schema": SCHEMA,
            "partitions": partitions,
            "values_per_partition": values,
            "batch": batch,
            "T": T,
            "beta": BETA,
            "ship": {
                "wal_seconds": wal_seconds,
                "replicated_seconds": ship_seconds,
                "overhead_ratio": overhead_ratio,
                "ships": ship_stats["ships"],
                "bytes_shipped": ship_stats["bytes_shipped"],
            },
            "lag": {
                "cycles": len(lag_seconds),
                "seconds_p50": _pctl(lag_seconds, 50),
                "seconds_p99": _pctl(lag_seconds, 99),
                "tail_ms_p50": _pctl(tail_ms, 50),
                "tail_ms_p99": _pctl(tail_ms, 99),
            },
            "failover": {
                "acked_records": n_acked,
                "time_to_first_answer_seconds": time_to_first_answer,
                "promoted_epoch": fol.promoted_epoch,
                "acked_loss_count": acked_loss,
                "bit_identical": bool(bit_identical),
                "old_primary_fenced": fenced,
            },
            "zero_acked_loss": acked_loss == 0,
            "failover_bit_identical": bool(bit_identical),
        }
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)

        emit(
            "replication_ship_overhead",
            overhead_ratio,
            f"WAL+ship {ship_seconds*1e3:.0f} ms vs WAL {wal_seconds*1e3:.0f} "
            f"ms for {partitions}×{values} f32 ({ship_stats['ships']} ships, "
            f"{ship_stats['bytes_shipped']} B)",
        )
        emit(
            "replication_lag_p99_seconds",
            _pctl(lag_seconds, 99),
            f"{len(lag_seconds)} ingest→tail cycles, tail p99 "
            f"{_pctl(tail_ms, 99):.2f} ms",
        )
        emit(
            "replication_failover_ttfa_seconds",
            time_to_first_answer,
            f"promote epoch {fol.promoted_epoch} + first answer over "
            f"{n_acked} acked records (loss {acked_loss}, "
            f"bit_identical {bit_identical}, fenced {fenced})",
        )
        emit("replication_json", 0.0, f"written to {out_path}")
        return result
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes for CI: validates the pipeline + JSON schema only",
    )
    ap.add_argument("--out", default="BENCH_replication.json")
    ap.add_argument("--partitions", type=int, default=64)
    args = ap.parse_args()
    kw = dict(out_path=args.out, partitions=args.partitions)
    if args.smoke:
        # values large enough that the per-batch fsync dominates — the
        # 1.1× ship-overhead gate is meaningful, not noise
        kw.update(partitions=12, values=8192, batch=6, reps=3)
    print("name,value,derived")
    main(
        lambda name, v, derived="": print(
            f"{name},{v:.3f},{derived}", flush=True
        ),
        **kw,
    )
