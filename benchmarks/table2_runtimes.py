"""Paper Table 2: mean running times of monthly histogram construction.

Rows mirror the paper: exact construction over the pooled month, offline
per-day summarization (Summarizer), merging of daily summaries (Merger),
offline per-day sampling, merge-of-samples — for both datasets.  Also
benchmarks the three merge implementations (Algorithm-1 sequential,
vectorized rank-select, fused Pallas kernel) head-to-head — the paper-
faithful baseline vs our TPU-shaped forms.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Histogram,
    build_exact,
    merge,
    merge_histograms_sequential,
    merge_list,
    sample_histogram,
)
from repro.kernels import merge_pallas
from benchmarks.paper_data import B_PAPER, month


def timed(fn, *args, reps=3):
    fn(*args)  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps, out


def run(kind: str, days: int = 16, per_day: int = 100_000, T_factor: int = 16):
    T = B_PAPER * T_factor
    data = month(kind, days=days, per_day=per_day)
    pooled = jnp.asarray(np.concatenate(data))
    rows = {}

    t, _ = timed(lambda: build_exact(pooled, B_PAPER))
    rows["exact_hist_construction"] = t

    t0 = time.perf_counter()
    summaries = [build_exact(jnp.asarray(d), T) for d in data]
    jax.block_until_ready(summaries[-1].sizes)
    rows["summarize_each_day"] = (time.perf_counter() - t0) / days

    stacked = Histogram(
        jnp.stack([h.boundaries for h in summaries]),
        jnp.stack([h.sizes for h in summaries]),
    )
    t, _ = timed(lambda: merge(stacked, B_PAPER))
    rows["merge_daily_summaries_vectorized"] = t
    t0 = time.perf_counter()
    merge_histograms_sequential(summaries, B_PAPER)
    rows["merge_daily_summaries_algorithm1"] = time.perf_counter() - t0
    t, _ = timed(
        lambda: merge_pallas(stacked.boundaries, stacked.sizes, B_PAPER)
    )
    rows["merge_daily_summaries_pallas"] = t

    t0 = time.perf_counter()
    samples = [
        sample_histogram(jnp.asarray(d), B_PAPER, T, jax.random.PRNGKey(i))
        for i, d in enumerate(data)
    ]
    jax.block_until_ready(samples[-1].sizes)
    rows["sample_each_day"] = (time.perf_counter() - t0) / days
    t, _ = timed(lambda: merge_list(samples, B_PAPER))
    rows["merge_daily_samplings"] = t
    return rows


def main(emit):
    for kind in ("real", "skewed"):
        for name, seconds in run(kind).items():
            emit(f"table2_{kind}_{name}", seconds * 1e6, "")


if __name__ == "__main__":
    main(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
