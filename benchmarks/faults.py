"""Chaos plane: disarmed-failpoint overhead + fault-schedule drill.

Two questions, machine-checked (the acceptance criteria of the
failpoint/self-healing subsystem, see core/faults.py):

  * **What does the chaos plane cost when nothing is armed?**  Whole-
    pipeline A/B timing cannot resolve a nanoseconds-per-site effect
    under jit-dispatch noise, so the overhead is bounded analytically
    from two low-noise measurements: the per-call cost of a disarmed
    ``faults.hit`` (tight-loop, min-of-reps) and the number of failpoint
    hits each workload actually performs (counted with a delegating
    wrapper).  ``overhead_ratio = 1 + hits × per_call / workload_time``
    — an upper bound, since it charges the full call cost on top of the
    measured end-to-end time.  CI asserts ``overhead_ok``: both the
    ingest and query ratios stay ≤ 1.01 (the ≤ 1 % design rule).

    The same analytic bound covers the **lock-discipline witness**
    (repro.analysis.witness): ns/acquire for a raw ``threading.Lock``
    vs a disarmed ``OrderedLock`` vs an armed one, plus the number of
    witnessed acquisitions the ingest workload performs
    (``witness.acquire_count()``).  The production claim is the
    *disarmed* delta — one module-global read per acquire — and CI
    gates ``1 + acquires × max(0, disarmed − raw) / time ≤ 1.01``.
  * **Does the plane actually heal?**  A fixed-seed fault drill — ENOSPC
    and torn WAL appends, flaky fsyncs, worker crashes, poisoned
    applies, failed merge dispatches — runs a multi-tenant script, then
    crashes and recovers.  Reported: ``degraded_rate`` (queries served
    degraded instead of failing while the merge failpoint was armed),
    ``recovery_seconds``, ``acked_loss`` (must be 0), and
    ``non_degraded_bit_identical`` (every fresh answer under chaos and
    every recovered partition bit-matches a fault-free replica).

Results print as CSV rows and are written to ``BENCH_faults.json``
(schema ``bench_faults/v1``; CI smoke-checks it at tiny sizes via
``--smoke``).

Run standalone: ``PYTHONPATH=src python benchmarks/faults.py``
or as a section of ``python -m benchmarks.run --only faults``.
"""
from __future__ import annotations

import argparse
import contextlib
import json
import os
import shutil
import tempfile
import threading
import time

import numpy as np

from repro.analysis import witness
from repro.core import IngestBackpressure, TenantRegistry, faults

SCHEMA = "bench_faults/v1"

T = 32
BETA = 16


def _hit_ns_per_call(reps: int, n: int = 200_000) -> float:
    """Min-of-reps per-call cost of a disarmed faults.hit — the one
    module-global boolean read every production site pays."""
    hit = faults.hit
    best = float("inf")
    for _ in range(reps + 1):  # first rep doubles as warm-up
        t0 = time.perf_counter()
        for _ in range(n):
            hit("bench.disarmed")
        best = min(best, (time.perf_counter() - t0) / n)
    return best * 1e9


def _lock_ns_per_acquire(make_lock, reps: int, n: int = 200_000) -> float:
    """Min-of-reps per-(acquire+release) cost of an uncontended lock —
    the tight-loop twin of _hit_ns_per_call, for the witness wrappers."""
    lk = make_lock()
    acquire, release = lk.acquire, lk.release
    best = float("inf")
    for _ in range(reps + 1):  # first rep doubles as warm-up
        t0 = time.perf_counter()
        for _ in range(n):
            acquire()
            release()
        best = min(best, (time.perf_counter() - t0) / n)
    return best * 1e9


@contextlib.contextmanager
def _counting_hit(counter: list):
    """Count how many failpoint sites a workload actually crosses,
    delegating to the real (disarmed) hit."""
    real = faults.hit

    def counting(name, default=None, **ctx):
        counter[0] += 1
        return real(name, default, **ctx)

    faults.hit = counting
    try:
        yield
    finally:
        faults.hit = real


def _time_min(fn, reps: int) -> float:
    fn()  # warm-up: jit caches, allocator
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _ingest_once(parts):
    """Sync-ingest the stream partition by partition (one tenant.apply
    failpoint site per call)."""
    reg = TenantRegistry(num_buckets=T)
    for pid, v in parts.items():
        reg.ingest("m", pid, v)
    reg.close()


def _query_once(reg, panels):
    """Cold dashboard batch: caches invalidated so the rep pays the
    merge dispatch — and its tenant.merge failpoint site."""
    for name in reg.names():
        with reg[name]._lock:
            reg[name]._tree._invalidate()
    reg.query_many(panels, BETA, strict=False)


def _chaos_drill(base: str, seed: int, n_ops: int) -> dict:
    """Fixed-seed fault schedule over ingest/query/checkpoint, then
    crash + recover.  Mirrors tests/test_chaos_props.py, sized for a
    benchmark row."""
    rng = np.random.default_rng(seed)
    tenants = ["svc-a", "svc-b"]
    snap = os.path.join(base, "reg.npz")
    wal_dir = os.path.join(base, "wal")
    reg = TenantRegistry(num_buckets=T, wal_dir=wal_dir)
    oracle: dict[tuple[str, int], np.ndarray] = {}
    must: set[tuple[str, int]] = set()
    next_pid = {t: 0 for t in tenants}
    queries = degraded = 0
    observed = []  # (tenant, ids, (hist, eps)) answered fresh under chaos

    def draw_item():
        t = tenants[int(rng.integers(0, len(tenants)))]
        next_pid[t] += int(rng.integers(1, 3))
        v = rng.normal(size=256).astype(np.float32)
        oracle[(t, next_pid[t])] = v
        return t, next_pid[t], v

    with contextlib.ExitStack() as stack:
        for name, kw in [
            ("wal.append", dict(exc=OSError(28, "ENOSPC"), prob=0.08)),
            ("wal.append.torn", dict(action=lambda **c: 9, prob=0.06)),
            ("wal.fsync", dict(exc=OSError(5, "EIO"), prob=0.08)),
            ("pool.batch", dict(prob=0.10)),
            ("tenant.apply", dict(prob=0.08)),
            ("tenant.merge", dict(prob=0.25)),
        ]:
            stack.enter_context(faults.inject(name, seed=seed, **kw))
        for i in range(n_ops):
            op = rng.integers(0, 10)
            if op < 4:
                t, pid, v = draw_item()
                try:
                    reg.ingest(t, pid, v)
                    must.add((t, pid))
                except (faults.FaultError, OSError):
                    pass
            elif op < 7:
                t, pid, v = draw_item()
                try:
                    reg.ingest_async(t, pid, v)
                    must.add((t, pid))
                except IngestBackpressure:
                    pass
            elif op < 8:
                for t, pid, _e in reg._pool.drain():
                    must.discard((t, pid))
                reg.save(snap)
            else:
                for t in tenants:
                    if t in reg and reg[t].ids():
                        ids = reg[t].ids()
                        [ans] = reg.query_many(
                            [(t, min(ids), max(ids))],
                            BETA,
                            strict=False,
                            degraded_ok=True,
                        )
                        queries += 1
                        if getattr(ans, "degraded", False):
                            degraded += 1
        for t, pid, _e in reg._pool.drain():
            must.discard((t, pid))
        for t in tenants:
            if t in reg and reg[t].ids():
                ids = reg[t].ids()
                [ans] = reg.query_many(
                    [(t, min(ids), max(ids))],
                    BETA,
                    strict=False,
                    degraded_ok=True,
                )
                queries += 1
                if getattr(ans, "degraded", False):
                    degraded += 1
                else:
                    observed.append((t, list(ids), ans))
    del reg  # crash: snapshot + log survive, memory does not

    t0 = time.perf_counter()
    rec = TenantRegistry.recover(snap, wal_dir, salvage=True, num_buckets=T)
    recovery_seconds = time.perf_counter() - t0

    acked_loss = sum(
        1
        for t, pid in must
        if t not in rec or pid not in rec[t].summaries
    )
    bit_identical = True
    for t, ids, (hist, eps) in observed:  # fresh answers under chaos
        ref = TenantRegistry(num_buckets=T)
        ref.ingest_many(t, {pid: oracle[(t, pid)] for pid in ids})
        [(wh, we)] = ref.query_many(
            [(t, min(ids), max(ids))], BETA, strict=False
        )
        bit_identical &= (
            np.array_equal(np.asarray(hist.boundaries), np.asarray(wh.boundaries))
            and np.array_equal(np.asarray(hist.sizes), np.asarray(wh.sizes))
            and eps == we
        )
        ref.close()
    for t in rec.names():  # recovered state vs fault-free replica
        ids = rec[t].ids()
        if not ids:
            continue
        ref = TenantRegistry(num_buckets=T)
        ref.ingest_many(t, {pid: oracle[(t, pid)] for pid in ids})
        a = rec.query_many([(t, min(ids), max(ids))], BETA, strict=False)[0]
        b = ref.query_many([(t, min(ids), max(ids))], BETA, strict=False)[0]
        bit_identical &= (
            np.array_equal(np.asarray(a[0].boundaries), np.asarray(b[0].boundaries))
            and np.array_equal(np.asarray(a[0].sizes), np.asarray(b[0].sizes))
            and a[1] == b[1]
        )
        ref.close()
    rec.close()
    return {
        "ops": n_ops,
        "queries": queries,
        "degraded_answers": degraded,
        "degraded_rate": degraded / max(1, queries),
        "acked": len(must),
        "acked_loss": acked_loss,
        "recovery_seconds": recovery_seconds,
        "non_degraded_bit_identical": bool(bit_identical),
    }


def main(
    emit,
    *,
    partitions: int = 48,
    values: int = 4096,
    reps: int = 5,
    chaos_ops: int = 48,
    out_path: str = "BENCH_faults.json",
) -> dict:
    rng = np.random.default_rng(0)
    parts = {
        pid: rng.lognormal(-1.8, 0.55, size=values).astype(np.float32)
        for pid in range(partitions)
    }
    base = tempfile.mkdtemp(prefix="bench-faults-")
    try:
        # ---- disarmed overhead: per-site cost × sites crossed ----
        hit_ns = _hit_ns_per_call(reps)

        ingest_hits = [0]
        with _counting_hit(ingest_hits):
            _ingest_once(parts)
        ingest_seconds = _time_min(lambda: _ingest_once(parts), reps)
        ingest_ratio = 1.0 + ingest_hits[0] * hit_ns * 1e-9 / ingest_seconds

        qreg = TenantRegistry(num_buckets=T)
        half = max(1, partitions // 2)
        qreg.ingest_many("m", {p: parts[p] for p in range(half)})
        qreg.ingest_many("n", {p: parts[p] for p in range(half, partitions)})
        panels = [("m", 0, half - 1), ("n", half, partitions - 1)]
        query_hits = [0]
        with _counting_hit(query_hits):
            _query_once(qreg, panels)
        query_seconds = _time_min(lambda: _query_once(qreg, panels), reps)
        qreg.close()
        query_ratio = 1.0 + query_hits[0] * hit_ns * 1e-9 / query_seconds

        # ---- lock-witness overhead: ns/acquire × acquires crossed ----
        was_armed = witness.armed()
        witness.disarm()
        raw_lock_ns = _lock_ns_per_acquire(threading.Lock, reps)
        disarmed_ns = _lock_ns_per_acquire(
            lambda: witness.OrderedLock("wal._lock"), reps
        )
        witness.arm()
        try:
            armed_ns = _lock_ns_per_acquire(
                lambda: witness.OrderedLock("wal._lock"), reps
            )
            witness.reset_acquire_count()
            _ingest_once(parts)  # same workload the failpoint bound uses
            lock_acquires = witness.acquire_count()
        finally:
            if not was_armed:
                witness.disarm()
        # production claim: the *disarmed* delta over a raw Lock (one
        # module-global read); clamp at 0 — timer noise can invert the
        # two sub-ns means
        disarmed_delta_ns = max(0.0, disarmed_ns - raw_lock_ns)
        lock_ratio = (
            1.0 + lock_acquires * disarmed_delta_ns * 1e-9 / ingest_seconds
        )
        overhead_ok = (
            ingest_ratio <= 1.01
            and query_ratio <= 1.01
            and lock_ratio <= 1.01
        )

        # ---- fixed-seed chaos drill ----
        chaos = _chaos_drill(os.path.join(base, "chaos"), 7, chaos_ops)

        result = {
            "schema": SCHEMA,
            "partitions": partitions,
            "values_per_partition": values,
            "T": T,
            "beta": BETA,
            "overhead": {
                "hit_ns_per_call": hit_ns,
                "ingest_seconds": ingest_seconds,
                "ingest_failpoint_hits": ingest_hits[0],
                "ingest_overhead_ratio": ingest_ratio,
                "query_seconds": query_seconds,
                "query_failpoint_hits": query_hits[0],
                "query_overhead_ratio": query_ratio,
            },
            "lock_witness": {
                "raw_lock_ns_per_acquire": raw_lock_ns,
                "disarmed_ns_per_acquire": disarmed_ns,
                "armed_ns_per_acquire": armed_ns,
                "disarmed_delta_ns": disarmed_delta_ns,
                "ingest_lock_acquires": lock_acquires,
                "ingest_overhead_ratio": lock_ratio,
            },
            "overhead_ok": overhead_ok,
            "chaos": chaos,
            "acked_loss": chaos["acked_loss"],
            "non_degraded_bit_identical": chaos["non_degraded_bit_identical"],
        }
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)

        emit(
            "faults_disarmed_overhead_ingest",
            ingest_ratio,
            f"{ingest_hits[0]} sites × {hit_ns:.0f} ns over "
            f"{partitions}×{values} f32 sync ingest "
            f"(gate ≤ 1.01: {'ok' if ingest_ratio <= 1.01 else 'FAIL'})",
        )
        emit(
            "faults_disarmed_overhead_query",
            query_ratio,
            f"{query_hits[0]} sites × {hit_ns:.0f} ns over a cold "
            "2-tenant dashboard "
            f"(gate ≤ 1.01: {'ok' if query_ratio <= 1.01 else 'FAIL'})",
        )
        emit(
            "witness_disarmed_overhead_ingest",
            lock_ratio,
            f"{lock_acquires} acquires × {disarmed_delta_ns:.0f} ns delta "
            f"(raw {raw_lock_ns:.0f} / disarmed {disarmed_ns:.0f} / armed "
            f"{armed_ns:.0f} ns) "
            f"(gate ≤ 1.01: {'ok' if lock_ratio <= 1.01 else 'FAIL'})",
        )
        emit(
            "faults_chaos_degraded_rate",
            chaos["degraded_rate"],
            f"{chaos['degraded_answers']}/{chaos['queries']} answers "
            "served degraded under the armed schedule "
            f"(acked loss {chaos['acked_loss']})",
        )
        emit(
            "faults_chaos_recovery_seconds",
            chaos["recovery_seconds"],
            f"{chaos['acked']} acked records, bit-identical="
            f"{chaos['non_degraded_bit_identical']}",
        )
        emit("faults_json", 0.0, f"written to {out_path}")
        return result
    finally:
        faults.reset()
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes for CI: validates the pipeline + JSON schema only",
    )
    ap.add_argument("--out", default="BENCH_faults.json")
    args = ap.parse_args()
    kw = dict(out_path=args.out)
    if args.smoke:
        kw.update(partitions=12, values=2048, reps=3, chaos_ops=24)
    print("name,value,derived")
    main(
        lambda name, v, derived="": print(
            f"{name},{v:.3f},{derived}", flush=True
        ),
        **kw,
    )
