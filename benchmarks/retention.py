"""Windowed retention: steady-state memory + query latency vs unbounded.

The retention benchmark for core/retention.py: an "infinite" stream (one
partition per day, ``--days`` of it) ingested twice —

  * **windowed**  — ``HistogramStore(retention=SlidingWindow(7))``: the
    watermark-driven sweeper evicts each day as it leaves the 7-day
    window and the tree lazily collapses behind it;
  * **unbounded** — the plain append-only store.

Reported per run:

  * node-float footprint over time: the windowed store's *peak* after
    warm-up (machine-checked ``bounded``: it never exceeds a small
    constant multiple of a fresh 7-partition build, however many days
    stream past) vs the unbounded store's ever-growing total;
  * query latency over the live 7-day window for both stores, LRU
    cleared per repetition — on this dispatch-dominated CPU regime the
    two are comparable (the windowed tree stays ≤ ~4 levels deep while
    the unbounded one keeps deepening, but both windows decompose into
    a handful of canonical nodes); the headline is the memory bound;
  * the acceptance criterion, machine-checked (``bitexact_vs_rebuild``,
    ``eps_ok``): every query over the retained window is bit-identical
    to a flat rebuild of only the retained partitions, and the measured
    bucket error stays within the reported ``eps_total``.

Results print as CSV rows and are written to ``BENCH_retention.json``
(schema ``bench_retention/v1``; CI smoke-checks it at tiny sizes via
``--smoke``).

Run standalone: ``PYTHONPATH=src python benchmarks/retention.py``
or as a section of ``python -m benchmarks.run --only retention``.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.core import HistogramStore, SlidingWindow

SCHEMA = "bench_retention/v1"

T = 32
BETA = 16
N_PER = 512
WINDOW = 7


def _timed_query(store: HistogramStore, lo: int, hi: int, reps: int) -> float:
    """Average seconds/query with the LRU cleared before each call —
    every repetition pays the real node-merge path, not the cache.  One
    unmeasured warm call first: the two stores decompose the same window
    into different canonical node counts, i.e. different jit shapes."""
    store._tree._cache.clear()
    store.query(lo, hi, BETA, strict=False)
    out = []
    for _ in range(reps):
        store._tree._cache.clear()
        t0 = time.perf_counter()
        store.query(lo, hi, BETA, strict=False)
        out.append(time.perf_counter() - t0)
    return float(np.mean(out))


def main(
    emit,
    *,
    days: int = 365,
    reps: int = 20,
    out_path: str = "BENCH_retention.json",
) -> dict:
    if days <= WINDOW:
        raise ValueError(
            f"--days must exceed the {WINDOW}-day window to measure a "
            f"steady state (got {days})"
        )
    rng = np.random.default_rng(0)
    windowed = HistogramStore(num_buckets=T, retention=SlidingWindow(WINDOW))
    unbounded = HistogramStore(num_buckets=T)
    raw: dict[int, np.ndarray] = {}
    floats_trace: list[int] = []
    t0 = time.perf_counter()
    for day in range(days):
        v = rng.lognormal(-1.8, 0.55, size=N_PER).astype(np.float32)
        raw[day] = v
        windowed.ingest(day, v)
        unbounded.ingest(day, v)
        floats_trace.append(windowed.node_floats())
    ingest_seconds = time.perf_counter() - t0

    lo, hi = days - WINDOW, days - 1
    assert windowed.ids() == list(range(lo, hi + 1))

    # steady-state bound: a fresh build over exactly one window is the
    # natural memory unit; the windowed store may transiently hold one
    # extra partition (sweep runs after apply) and a not-yet-collapsed
    # alignment, so "bounded" allows a small constant multiple of it
    fresh = HistogramStore(num_buckets=T)
    fresh.ingest_many({d: raw[d] for d in range(lo, hi + 1)})
    fresh_floats = fresh.node_floats()
    peak_steady = max(floats_trace[WINDOW:])
    final_floats = floats_trace[-1]
    unbounded_floats = unbounded.node_floats()
    bounded = peak_steady <= 4 * fresh_floats

    # acceptance criterion, machine-checked: retained-window queries are
    # bit-exact vs the flat rebuild, within the reported eps_total
    h_w, eps_w = windowed.query(lo, hi, BETA)
    h_f, eps_f = fresh.query(lo, hi, BETA)
    bitexact = (
        bool(
            np.array_equal(
                np.asarray(h_w.boundaries), np.asarray(h_f.boundaries)
            )
        )
        and bool(np.array_equal(np.asarray(h_w.sizes), np.asarray(h_f.sizes)))
        and eps_w == eps_f
    )
    pooled = np.sort(np.concatenate([raw[d] for d in range(lo, hi + 1)]))
    sizes = np.asarray(h_w.sizes, np.float64)
    eps_ok = bool(
        np.abs(sizes - pooled.size / BETA).max() <= eps_w + 1e-3
    )

    # query latency over the live window, compiled paths warmed above
    t_windowed = _timed_query(windowed, lo, hi, reps)
    t_unbounded = _timed_query(unbounded, lo, hi, reps)

    result = {
        "schema": SCHEMA,
        "days": days,
        "window": WINDOW,
        "values_per_partition": N_PER,
        "T": T,
        "beta": BETA,
        "ingest_seconds_both_stores": ingest_seconds,
        "windowed": {
            "final_node_floats": final_floats,
            "peak_node_floats_steady": peak_steady,
            "fresh_window_node_floats": fresh_floats,
            "tree_levels": windowed._tree.levels,
            "query_us": t_windowed * 1e6,
        },
        "unbounded": {
            "node_floats": unbounded_floats,
            "tree_levels": unbounded._tree.levels,
            "query_us": t_unbounded * 1e6,
        },
        "floats_ratio_unbounded_over_windowed": (
            unbounded_floats / final_floats
        ),
        "bounded": bounded,
        "bitexact_vs_rebuild": bitexact,
        "eps_ok": eps_ok,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    emit(
        "retention_windowed_node_floats",
        float(final_floats),
        f"steady-state floats, peak {peak_steady} "
        f"(≤4× fresh window {fresh_floats}: bounded={bounded})",
    )
    emit(
        "retention_unbounded_node_floats",
        float(unbounded_floats),
        f"{unbounded_floats / final_floats:.1f}× the windowed store "
        f"after {days} days and growing",
    )
    emit(
        "retention_windowed_query_us",
        t_windowed * 1e6,
        f"7-day window query, tree depth {windowed._tree.levels}",
    )
    emit(
        "retention_unbounded_query_us",
        t_unbounded * 1e6,
        f"same query, tree depth {unbounded._tree.levels}",
    )
    emit(
        "retention_bitexact_vs_rebuild",
        1.0 if bitexact else 0.0,
        f"retained-window answers ≡ flat rebuild (eps_ok={eps_ok})",
    )
    emit("retention_json", 0.0, f"written to {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes for CI: validates the pipeline + JSON schema only",
    )
    ap.add_argument("--out", default="BENCH_retention.json")
    ap.add_argument("--days", type=int, default=365)
    args = ap.parse_args()
    kw = dict(out_path=args.out, days=args.days)
    if args.smoke:
        kw.update(days=40, reps=5)
    print("name,value,derived")
    main(
        lambda name, v, derived="": print(
            f"{name},{v:.1f},{derived}", flush=True
        ),
        **kw,
    )
