"""Durable ingest: WAL overhead + crash-recovery fidelity.

Two questions, machine-checked (the acceptance criteria of the durable-
ingest subsystem, see the "Write-ahead log" design note in
core/workers.py):

  * **What does durability cost?**  The same partition stream is
    ingested through a plain store and a ``wal_dir=`` store (batched
    ``ingest_many`` — the WAL's intended group-commit mode: one fsync
    per batch, not per partition).  Reported as ``overhead_ratio``; CI
    asserts it stays ≤ 1.5×.
  * **Does recovery actually lose nothing?**  Three crash scenarios —
    right after a save (nothing to replay), between async submit and
    flush (everything still queued), and a torn trailing record — each
    recovered and compared against a never-crashed replica fed the same
    acked partitions: ``recovered_bit_identical`` (query_many answers
    bit-equal) and ``acked_loss_count`` (acked partitions missing after
    recovery; torn records a disk lost are dropped *and counted as
    detected*, not as silent loss).

Results print as CSV rows and are written to ``BENCH_durability.json``
(schema ``bench_durability/v1``; CI smoke-checks it at tiny sizes via
``--smoke``).

Run standalone: ``PYTHONPATH=src python benchmarks/durability.py``
or as a section of ``python -m benchmarks.run --only durability``.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

import numpy as np

from repro.core import HistogramStore, TenantRegistry

SCHEMA = "bench_durability/v1"

T = 32
BETA = 16


def _batches(parts: dict[int, np.ndarray], size: int):
    pids = sorted(parts)
    for i in range(0, len(pids), size):
        yield {pid: parts[pid] for pid in pids[i : i + size]}


def _ingest_seconds(store, parts, batch: int, reps: int) -> float:
    """Best-of-``reps`` wall time to ingest the whole stream in batches
    (fresh pids per rep keep the stores append-only and the jit shapes
    warm)."""
    out = []
    n = len(parts)
    for r in range(reps):
        shifted = {pid + r * 10 * n: v for pid, v in parts.items()}
        t0 = time.perf_counter()
        for b in _batches(shifted, batch):
            store.ingest_many(b)
        out.append(time.perf_counter() - t0)
    return float(min(out))


def _bit_identical(reg_a, reg_b, panels) -> bool:
    for (ha, ea), (hb, eb) in zip(
        reg_a.query_many(panels, BETA, strict=False),
        reg_b.query_many(panels, BETA, strict=False),
    ):
        if ha is None or hb is None:
            return False
        if not np.array_equal(np.asarray(ha.boundaries), np.asarray(hb.boundaries)):
            return False
        if not np.array_equal(np.asarray(ha.sizes), np.asarray(hb.sizes)):
            return False
        if ea != eb:
            return False
    return True


def main(
    emit,
    *,
    partitions: int = 64,
    values: int = 8192,
    batch: int = 8,
    reps: int = 3,
    out_path: str = "BENCH_durability.json",
) -> dict:
    rng = np.random.default_rng(0)
    parts = {
        pid: rng.lognormal(-1.8, 0.55, size=values).astype(np.float32)
        for pid in range(partitions)
    }
    base = tempfile.mkdtemp(prefix="bench-durability-")
    try:
        # ---- ingest overhead: WAL vs no WAL (batched group commit) ----
        warm = HistogramStore(num_buckets=T)
        warm.ingest_many(next(_batches(parts, batch)))  # jit warm-up

        plain = HistogramStore(num_buckets=T)
        nowal_seconds = _ingest_seconds(plain, parts, batch, reps)

        wal_store = HistogramStore(
            num_buckets=T, wal_dir=os.path.join(base, "wal-overhead")
        )
        wal_seconds = _ingest_seconds(wal_store, parts, batch, reps)
        wstats = wal_store.wal_stats()
        overhead_ratio = wal_seconds / nowal_seconds

        # ---- recovery scenarios vs a never-crashed replica ----
        data = {
            (t, pid): parts[pid][: min(values, 2048)]
            for t in ("svc-a", "svc-b")
            for pid in range(min(partitions, 16))
        }
        n_pids = min(partitions, 16)
        panels = [("svc-a", 0, n_pids - 1), ("svc-b", 0, n_pids - 1)]
        ref = TenantRegistry(num_buckets=T)
        for (t, pid), v in data.items():
            ref.ingest(t, pid, v)

        scenarios = {}
        t_recover = 0.0

        # 1. crash right after a save: the snapshot alone must suffice
        d1 = os.path.join(base, "s1")
        reg = TenantRegistry(num_buckets=T, wal_dir=os.path.join(d1, "wal"))
        for (t, pid), v in data.items():
            reg.ingest(t, pid, v)
        reg.save(os.path.join(d1, "reg.npz"))
        del reg
        t0 = time.perf_counter()
        rec = TenantRegistry.recover(
            os.path.join(d1, "reg.npz"), os.path.join(d1, "wal"), num_buckets=T
        )
        t_recover += time.perf_counter() - t0
        scenarios["after_save"] = {
            "bit_identical": _bit_identical(rec, ref, panels),
            "acked_loss": sum(
                n_pids - len(rec[t].ids()) for t in ("svc-a", "svc-b")
            ),
            "replayed": rec.last_recovery["replayed"],
        }
        rec.close()

        # 2. crash between async submit and flush: WAL-only restore
        d2 = os.path.join(base, "s2")
        reg = TenantRegistry(num_buckets=T, wal_dir=os.path.join(d2, "wal"))
        for (t, pid), v in data.items():
            reg.ingest_async(t, pid, v)  # acked ⇒ fsynced; never flushed
        del reg
        t0 = time.perf_counter()
        rec = TenantRegistry.recover(
            os.path.join(d2, "reg.npz"), os.path.join(d2, "wal"), num_buckets=T
        )
        t_recover += time.perf_counter() - t0
        scenarios["before_flush"] = {
            "bit_identical": _bit_identical(rec, ref, panels),
            "acked_loss": sum(
                n_pids - len(rec[t].ids()) for t in ("svc-a", "svc-b")
            ),
            "replayed": rec.last_recovery["replayed"],
        }
        rec.close()

        # 3. torn trailing record: dropped AND detected, prefix intact
        d3 = os.path.join(base, "s3")
        reg = TenantRegistry(num_buckets=T, wal_dir=os.path.join(d3, "wal"))
        for (t, pid), v in data.items():
            reg.ingest(t, pid, v)
        reg.ingest("svc-a", n_pids, data[("svc-a", 0)])  # the torn victim
        del reg
        segs = sorted(
            f
            for f in os.listdir(os.path.join(d3, "wal"))
            if f.startswith("wal-")
        )
        last = os.path.join(d3, "wal", segs[-1])
        with open(last, "r+b") as f:
            f.truncate(os.path.getsize(last) - 9)
        t0 = time.perf_counter()
        rec = TenantRegistry.recover(
            os.path.join(d3, "reg.npz"), os.path.join(d3, "wal"), num_buckets=T
        )
        t_recover += time.perf_counter() - t0
        scenarios["torn_tail"] = {
            "bit_identical": _bit_identical(rec, ref, panels),
            "acked_loss": sum(
                n_pids - len(rec[t].ids()) for t in ("svc-a", "svc-b")
            ),
            "torn_detected": rec.last_recovery["torn_records_dropped"] == 1,
        }
        rec.close()
        ref.close()

        recovered_bit_identical = all(
            s["bit_identical"] for s in scenarios.values()
        )
        acked_loss_count = sum(s["acked_loss"] for s in scenarios.values())

        result = {
            "schema": SCHEMA,
            "partitions": partitions,
            "values_per_partition": values,
            "batch": batch,
            "T": T,
            "beta": BETA,
            "ingest": {
                "nowal_seconds": nowal_seconds,
                "wal_seconds": wal_seconds,
                "overhead_ratio": overhead_ratio,
                "fsyncs": wstats["fsyncs"],
                "fsync_ms_mean": (
                    1e3 * wstats["fsync_seconds_total"] / max(1, wstats["fsyncs"])
                ),
                "wal_bytes_written": wstats["bytes_written"],
            },
            "recovery": {
                "scenarios": scenarios,
                "recovery_seconds_total": t_recover,
            },
            "recovered_bit_identical": recovered_bit_identical,
            "acked_loss_count": acked_loss_count,
            "torn_detected": scenarios["torn_tail"]["torn_detected"],
        }
        with open(out_path, "w") as f:
            json.dump(result, f, indent=2)

        emit(
            "durability_ingest_overhead",
            overhead_ratio,
            f"WAL {wal_seconds*1e3:.0f} ms vs plain {nowal_seconds*1e3:.0f} "
            f"ms for {partitions}×{values} f32 (batch {batch}: "
            f"{wstats['fsyncs']} group-commit fsyncs)",
        )
        emit(
            "durability_recovered_bit_identical",
            1.0 if recovered_bit_identical else 0.0,
            "after-save / before-flush / torn-tail all ≡ never-crashed "
            f"replica (acked loss {acked_loss_count})",
        )
        emit(
            "durability_recovery_seconds",
            t_recover,
            f"3 recoveries, {scenarios['before_flush']['replayed']} records "
            "replayed in the worst one",
        )
        emit("durability_json", 0.0, f"written to {out_path}")
        return result
    finally:
        shutil.rmtree(base, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes for CI: validates the pipeline + JSON schema only",
    )
    ap.add_argument("--out", default="BENCH_durability.json")
    ap.add_argument("--partitions", type=int, default=64)
    args = ap.parse_args()
    kw = dict(out_path=args.out, partitions=args.partitions)
    if args.smoke:
        # values large enough that one group-commit fsync per batch
        # amortizes — the 1.5× overhead gate is meaningful, not noise
        kw.update(partitions=12, values=8192, batch=6, reps=3)
    print("name,value,derived")
    main(
        lambda name, v, derived="": print(
            f"{name},{v:.3f},{derived}", flush=True
        ),
        **kw,
    )
