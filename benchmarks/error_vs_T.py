"""Paper Figures 14 & 15: μ_b and μ_s against T, merge vs tuple sampling.

T sweeps B·2^n summary buckets for the merge method; the tuple baseline
gets the *same budget* as its sample size (the paper's comparison).  Both
datasets (real-like, Gumbel-skewed), B = 254 output buckets.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    boundary_error,
    build_exact,
    merge_list,
    sample_histogram,
    empirical_size_error,
)
from benchmarks.paper_data import B_PAPER, month


def run(kind: str, days: int = 8, per_day: int = 100_000, n_exp: int = 7):
    data = month(kind, days=days, per_day=per_day)
    pooled = jnp.asarray(np.concatenate(data))
    exact = build_exact(pooled, B_PAPER)
    rows = []
    for n in range(n_exp):
        T = B_PAPER * (2**n)
        t0 = time.perf_counter()
        summaries = [build_exact(jnp.asarray(d), T) for d in data]
        t_summarize = time.perf_counter() - t0
        t0 = time.perf_counter()
        merged = merge_list(summaries, B_PAPER)
        jax.block_until_ready(merged.sizes)
        t_merge = time.perf_counter() - t0

        budget = min(T * days, pooled.shape[0])  # same stored-value budget
        t0 = time.perf_counter()
        tup = sample_histogram(pooled, B_PAPER, budget, jax.random.PRNGKey(n))
        jax.block_until_ready(tup.sizes)
        t_tuple = time.perf_counter() - t0

        rows.append({
            "kind": kind, "T": T,
            "mu_b_merge": float(boundary_error(merged, exact)),
            "mu_s_merge": float(empirical_size_error(merged, pooled)),
            "mu_b_tuple": float(boundary_error(tup, exact)),
            "mu_s_tuple": float(empirical_size_error(tup, pooled)),
            "t_summarize_s": t_summarize, "t_merge_s": t_merge,
            "t_tuple_s": t_tuple,
        })
    return rows


def main(emit):
    for kind, fig in (("real", "fig14"), ("skewed", "fig15")):
        for r in run(kind):
            emit(
                f"{fig}_{kind}_T{r['T']}",
                r["t_merge_s"] * 1e6,
                f"mu_b merge/tuple={r['mu_b_merge']:.4g}/{r['mu_b_tuple']:.4g} "
                f"mu_s={r['mu_s_merge']:.4g}/{r['mu_s_tuple']:.4g}",
            )


if __name__ == "__main__":
    main(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
