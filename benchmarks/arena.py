"""Shared node-storage arena: zero-copy pack vs per-tenant host packs.

The serving-side A/B for ``TenantRegistry(shared_arena=True)``
(core/arena.py): both layouts answer the same cold cross-tenant dashboard
refresh with ONE merge dispatch (that was PR 3), so what differs is how
the ``(Q, k_pad, T_pad)`` merge stack gets *assembled*:

  * **per_tenant_pack** — the non-shared layout: one stacked fancy-index
    copy per tenant, the host block fill, and the host→device transfer
    of the whole block;
  * **shared_arena** — a single device gather over the registry-wide
    pool: zero host row copies, machine-checked, bit-identical block.

Two levels of measurement, both reported:

  * **pack stage** (``query.pack``) — the stack assembly alone, on
    identical selections, including each side's path to device-resident
    merge inputs.  This is the cost the arena actually removes and the
    ≥1.5× acceptance claim: ~4× here, and the gap only widens on a real
    accelerator where the host→device block transfer crosses PCIe.
  * **end-to-end** (``query.per_tenant_pack``/``query.shared_arena``) —
    cold ``query_many`` wall time.  The merge dispatch itself (identical
    device-side sort work in both layouts) dominates wall time on this
    CPU backend, so the end-to-end ratio is structurally the smaller
    number (~1.1-1.3×); it is asserted ``>= 1.0`` and reported for
    honesty, not as the headline.

Reported sections:

  * **query**  — pack-stage + end-to-end A/B above, with the
    machine-checked counters (``merge_dispatches == 1``, shared
    ``host_row_copies == 0``) and bit-identity checks across layouts;
  * **ingest** — one steady-state drained batch (one new day for every
    tenant) applied per-tenant vs cross-tenant batched: merge dispatches
    drop from ``tenants × log W`` to ``log W`` (counted deterministically
    by driving the pool's apply callback with a known batch);
  * **slide**  — canonical vs amortized collapse under a sliding window:
    merged pairs per stream (the O(W) → O(log W) per-slide claim), with
    the amortized answers' measured error still within their reported
    ``eps_total``.

Results print as CSV rows and are written to ``BENCH_arena.json`` (schema
``bench_arena/v1``; CI smoke-checks it at small sizes via ``--smoke``).
Every run appends a ``trajectory`` entry (headline numbers per run) so the
file carries its own history.

Run standalone: ``PYTHONPATH=src python benchmarks/arena.py``
or as a section of ``python -m benchmarks.run --only arena``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core import HistogramStore, SlidingWindow, TenantRegistry
from repro.core import interval_tree as it_mod

SCHEMA = "bench_arena/v1"

T = 32  # summary resolution (serving regime: many small per-metric
BETA = 16  # summaries — the same sizing argument as BENCH_tenant)
N_PER = 128
PARTS = 48  # deep windows → k_pad = 16 canonical rows per query


def _collect_selections(reg, qs) -> list[list]:
    """Resolve each query's canonical node handles (the pack inputs),
    exactly as query_many does on a cold miss."""
    sels = []
    for name, lo, hi in qs:
        store = reg[name]
        with store._lock:
            keys = store._sync_tree([], lo, hi)
            sels.append([store._tree.nodes[k] for k in keys])
    return sels


def _build(shared: bool, n_tenants: int, parts: int, n_per: int) -> TenantRegistry:
    rng = np.random.default_rng(1)
    reg = TenantRegistry(num_buckets=T, shared_arena=shared)
    for t in range(n_tenants):
        reg.ingest_many(
            f"svc{t:04d}",
            {
                d: rng.lognormal(-1.8, 0.55, size=n_per).astype(np.float32)
                for d in range(parts)
            },
        )
    return reg


def _queries(reg: TenantRegistry, parts: int) -> list[tuple[str, int, int]]:
    rng = np.random.default_rng(2)
    out = []
    for name in reg.names():
        lo = int(rng.integers(0, parts // 2))
        hi = int(rng.integers(lo + parts // 3, parts))
        out.append((name, lo, hi))
    return out


def _clear_caches(reg: TenantRegistry) -> None:
    for name in reg.names():
        reg[name]._tree._cache.clear()


def _timed_cold_interleaved(variants: list[tuple], reps: int) -> list[float]:
    """Best-of-``reps`` cold timing with the variants interleaved round-
    robin, so slow machine phases (CPU contention, frequency drift) hit
    every variant equally instead of biasing whichever ran last."""
    best = [float("inf")] * len(variants)
    for _ in range(reps):
        for vi, (reg, fn) in enumerate(variants):
            _clear_caches(reg)
            t0 = time.perf_counter()
            fn()
            dt = time.perf_counter() - t0
            if dt < best[vi]:
                best[vi] = dt
    return best


def _bit_identical(a, b) -> bool:
    for (ha, ea), (hb, eb) in zip(a, b):
        if ea != eb:
            return False
        if not np.array_equal(np.asarray(ha.boundaries), np.asarray(hb.boundaries)):
            return False
        if not np.array_equal(np.asarray(ha.sizes), np.asarray(hb.sizes)):
            return False
    return True


def _query_section(n_tenants: int, parts: int, n_per: int, reps: int) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.core.interval_tree import pack_device_rows, pack_node_rows

    legacy = _build(False, n_tenants, parts, n_per)
    shared = _build(True, n_tenants, parts, n_per)
    qs = _queries(legacy, parts)
    Q = len(qs)
    # warm each registry's own compile shapes before timing (the layouts
    # share shapes here, but each pays its own first dispatch)
    for reg in (legacy, shared):
        reg.query_many(qs, BETA)
        _clear_caches(reg)
    t_legacy, t_shared = _timed_cold_interleaved(
        [
            (legacy, lambda: legacy.query_many(qs, BETA)),
            (shared, lambda: shared.query_many(qs, BETA)),
        ],
        reps,
    )

    # pack-stage A/B on identical selections: each side timed to device-
    # resident merge inputs (the host pack must also ship its block)
    sel_legacy = _collect_selections(legacy, qs)
    sel_shared = _collect_selections(shared, qs)
    T_pad = max(nd.width for sel in sel_legacy for nd in sel)

    def host_pack():
        b, s = pack_node_rows(sel_legacy, T_pad=T_pad, pad_row_copy=True)
        out = (jnp.asarray(b), jnp.asarray(s))
        jax.block_until_ready(out)
        return out

    def gather_pack():
        out = pack_device_rows(sel_shared)
        jax.block_until_ready(out)
        return out

    hb, hs = host_pack()
    gb, gs = gather_pack()
    blocks_identical = bool(jnp.array_equal(hb, gb)) and bool(
        jnp.array_equal(hs, gs)
    )
    t_host_pack = t_gather_pack = float("inf")
    for _ in range(max(reps, 5)):
        t0 = time.perf_counter()
        host_pack()
        t_host_pack = min(t_host_pack, time.perf_counter() - t0)
        t0 = time.perf_counter()
        gather_pack()
        t_gather_pack = min(t_gather_pack, time.perf_counter() - t0)

    # machine-checked cold batch: one dispatch, zero host row copies, and
    # answers bit-identical between the two layouts
    for reg in (legacy, shared):
        _clear_caches(reg)
        reg.merge_dispatches = 0
        reg.merge_shapes.clear()
        reg.reset_host_row_copies()
    ans_legacy = legacy.query_many(qs, BETA)
    ans_shared = shared.query_many(qs, BETA)
    out = {
        "queries": Q,
        "pack": {
            "host_pack_seconds": t_host_pack,
            "gather_pack_seconds": t_gather_pack,
            "pack_speedup": t_host_pack / t_gather_pack,
            "blocks_bit_identical": blocks_identical,
        },
        "per_tenant_pack": {
            "seconds": t_legacy,
            "qps": Q / t_legacy,
            "dispatches_per_batch": legacy.merge_dispatches,
            "host_row_copies": legacy.host_row_copies,
        },
        "shared_arena": {
            "seconds": t_shared,
            "qps": Q / t_shared,
            "dispatches_per_batch": shared.merge_dispatches,
            "host_row_copies": shared.host_row_copies,
            "merge_shapes": [list(s) for s in sorted(shared.merge_shapes)],
        },
        "speedup_vs_per_tenant_pack": t_legacy / t_shared,
        "bit_identical": _bit_identical(ans_legacy, ans_shared),
    }
    legacy.close()
    shared.close()
    return out


def _ingest_section(n_tenants: int, parts: int, n_per: int) -> dict:
    """One steady-state drained batch — one new day per tenant — applied
    through the pool callback of each layout (deterministic composition,
    unlike racing the real workers)."""
    rng = np.random.default_rng(3)
    day = parts
    batch = [
        (
            f"svc{t:04d}",
            day,
            rng.lognormal(-1.8, 0.55, size=n_per).astype(np.float32),
        )
        for t in range(n_tenants)
    ]
    out = {}
    for tag, shared in (("per_tenant_pullups", False), ("shared_batched_pullups", True)):
        reg = _build(shared, n_tenants, parts, n_per)
        it_mod.reset_pullup_stats()
        t0 = time.perf_counter()
        reg._apply_worker_batch(batch)
        seconds = time.perf_counter() - t0
        stats = it_mod.reset_pullup_stats()
        out[tag] = {
            "seconds": seconds,
            "dispatches": stats["dispatches"],
            "pair_merges": stats["pair_merges"],
        }
        reg.close()
    out["dispatch_reduction"] = (
        out["per_tenant_pullups"]["dispatches"]
        / max(1, out["shared_batched_pullups"]["dispatches"])
    )
    return out


def _slide_section(window: int, days: int) -> dict:
    rng = np.random.default_rng(4)
    parts = {d: rng.normal(size=256).astype(np.float32) for d in range(days)}
    counts = {}
    stores = {}
    for mode in ("canonical", "amortized"):
        store = HistogramStore(
            num_buckets=32, retention=SlidingWindow(window), collapse=mode
        )
        it_mod.reset_pullup_stats()
        t0 = time.perf_counter()
        for d in range(days):
            store.ingest(d, parts[d])
        seconds = time.perf_counter() - t0
        counts[mode] = {
            "seconds": seconds,
            **{k: v for k, v in it_mod.reset_pullup_stats().items()},
        }
        stores[mode] = store
    # amortized answers still within their reported eps over the window
    store = stores["amortized"]
    lo, hi = store.ids()[0], store.ids()[-1]
    h, eps = store.query(lo, hi, BETA)
    pooled = np.sort(np.concatenate([parts[d] for d in range(lo, hi + 1)]))
    err = float(
        np.abs(np.asarray(h.sizes, np.float64) - pooled.size / BETA).max()
    )
    return {
        "window": window,
        "days": days,
        "canonical": counts["canonical"],
        "amortized": counts["amortized"],
        "merge_work_reduction": (
            counts["canonical"]["pair_merges"]
            / max(1, counts["amortized"]["pair_merges"])
        ),
        "amortized_measured_err": err,
        "amortized_eps_total": eps,
        "amortized_eps_ok": err <= eps + 1e-3,
    }


def main(
    emit,
    *,
    n_tenants: int = 256,
    parts: int = PARTS,
    n_per: int = N_PER,
    reps: int = 5,
    slide_window: int = 32,
    slide_days: int = 200,
    out_path: str = "BENCH_arena.json",
) -> dict:
    query = _query_section(n_tenants, parts, n_per, reps)
    ingest = _ingest_section(n_tenants, parts, n_per)
    slide = _slide_section(slide_window, slide_days)

    # per-run history: carry the previous file's trajectory forward so the
    # json records how the headline numbers move across commits
    trajectory = []
    if os.path.exists(out_path):
        try:
            with open(out_path) as f:
                trajectory = json.load(f).get("trajectory", [])
        except (json.JSONDecodeError, OSError):
            trajectory = []
    trajectory.append(
        {
            "tenants": n_tenants,
            "pack_speedup": query["pack"]["pack_speedup"],
            "speedup_vs_per_tenant_pack": query["speedup_vs_per_tenant_pack"],
            "ingest_dispatch_reduction": ingest["dispatch_reduction"],
            "slide_merge_work_reduction": slide["merge_work_reduction"],
        }
    )

    result = {
        "schema": SCHEMA,
        "tenants": n_tenants,
        "partitions_per_tenant": parts,
        "values_per_partition": n_per,
        "T": T,
        "beta": BETA,
        "query": query,
        "ingest": ingest,
        "slide": slide,
        # headline claims hoisted for the CI schema check
        "pack_speedup": query["pack"]["pack_speedup"],
        "speedup_vs_per_tenant_pack": query["speedup_vs_per_tenant_pack"],
        "host_row_copies": query["shared_arena"]["host_row_copies"],
        "merge_dispatches": query["shared_arena"]["dispatches_per_batch"],
        "bit_identical": query["bit_identical"],
        "trajectory": trajectory,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    Q = query["queries"]
    emit(
        "arena_per_tenant_pack_qps",
        Q / query["per_tenant_pack"]["seconds"],
        f"queries/s, {query['per_tenant_pack']['host_row_copies']} host row "
        f"copies per cold refresh",
    )
    emit(
        "arena_shared_gather_qps",
        Q / query["shared_arena"]["seconds"],
        f"queries/s, {query['shared_arena']['dispatches_per_batch']} "
        f"dispatch, {query['shared_arena']['host_row_copies']} host row "
        f"copies (bit_identical={query['bit_identical']})",
    )
    emit(
        "arena_pack_speedup",
        query["pack"]["pack_speedup"],
        f"x pack stage at {n_tenants} tenants: host pack+transfer "
        f"{query['pack']['host_pack_seconds']*1e3:.1f}ms -> gather "
        f"{query['pack']['gather_pack_seconds']*1e3:.1f}ms, blocks "
        f"bit-identical={query['pack']['blocks_bit_identical']} "
        f"(target >= 1.5x at >= 256)",
    )
    emit(
        "arena_speedup_vs_per_tenant_pack",
        query["speedup_vs_per_tenant_pack"],
        f"x end-to-end at {n_tenants} tenants (merge compute dominates "
        f"and is identical in both layouts — see module docstring)",
    )
    emit(
        "arena_ingest_dispatch_reduction",
        ingest["dispatch_reduction"],
        f"x: {ingest['per_tenant_pullups']['dispatches']} -> "
        f"{ingest['shared_batched_pullups']['dispatches']} merge dispatches "
        f"per drained {n_tenants}-tenant batch",
    )
    emit(
        "arena_slide_merge_work_reduction",
        slide["merge_work_reduction"],
        f"x fewer merged pairs, amortized vs canonical collapse at "
        f"W={slide_window} over {slide_days} days "
        f"(eps_ok={slide['amortized_eps_ok']})",
    )
    emit("arena_json", 0.0, f"written to {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="small sizes for CI: validates the pipeline + JSON schema only",
    )
    ap.add_argument("--out", default="BENCH_arena.json")
    ap.add_argument("--tenants", type=int, default=256)
    args = ap.parse_args()
    kw = dict(out_path=args.out, n_tenants=args.tenants)
    if args.smoke:
        # small but not tiny: below ~64 tenants the per-query python
        # bookkeeping (shared by both layouts) hides the pack difference
        # and the speedup assert would be pure noise; best-of-5
        # interleaved reps keep the CI timing floors off the noise floor
        kw.update(
            n_tenants=96, parts=32, n_per=64, reps=5,
            slide_window=8, slide_days=40,
        )
    print("name,value,derived")
    main(
        lambda name, v, derived="": print(
            f"{name},{v:.2f},{derived}", flush=True
        ),
        **kw,
    )
