"""Workload generators mirroring the paper's two datasets (§7, scaled).

  * "real"   — heavy-tailed page-size-like distribution (the Wikipedia
               hourly pageview `pagesize` column): log-normal body with a
               Zipf tail.
  * "skewed" — Gumbel, exactly as the paper's synthetic skewed workload.

Scaled to CPU: ``days × per_day`` tuples instead of 5 B; every comparison
(merge vs corrected tuple sampling, B=254 Oracle-default buckets) and both
error metrics (Eq. 9, Eq. 10) match the paper's methodology.
"""
from __future__ import annotations

import numpy as np

B_PAPER = 254  # Oracle's default histogram bucket count (paper §7)


def day_values(kind: str, day: int, per_day: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(np.random.SeedSequence([seed, day, hash(kind) & 0xFFFF]))
    if kind == "real":
        body = rng.lognormal(mean=8.0, sigma=1.2, size=per_day)
        tail = (rng.zipf(1.5, size=per_day) * 1000.0) * (
            rng.random(per_day) < 0.02
        )
        return (body + tail).astype(np.float32)
    if kind == "skewed":
        return rng.gumbel(loc=0.0, scale=1.0, size=per_day).astype(np.float32)
    raise ValueError(kind)


def month(kind: str, days: int = 31, per_day: int = 100_000, seed: int = 0):
    return [day_values(kind, d, per_day, seed) for d in range(days)]
