"""Benchmark entry point: one section per paper table/figure.

``python -m benchmarks.run [--only fig14,...]`` prints
``name,us_per_call,derived`` CSV rows for:
  * error_vs_T        — paper Figures 14 & 15 (mu_b, mu_s vs T; merge vs tuple)
  * error_vs_days     — paper Figures 16 & 17 (error vs merged interval)
  * table2_runtimes   — paper Table 2 (summarize/merge/sample timings)
  * core_micro        — core-primitive microbenchmarks
  * interval_query    — flat vs segment-tree Merger (latency, qps, ε bound)
  * ingest            — per-partition vs batched vs async Summarizer
                        throughput + compile counts (writes BENCH_ingest.json)
  * tenant            — per-store loop vs registry-batched cross-tenant
                        query_many (writes BENCH_tenant.json)
  * retention         — 7-day sliding window vs unbounded store: steady-
                        state memory + query latency, bit-exactness vs a
                        flat rebuild (writes BENCH_retention.json)
  * arena             — shared node-storage arena: zero-copy cross-tenant
                        pack vs per-tenant host pack, batched pull-up
                        dispatches, amortized window slides
                        (writes BENCH_arena.json)
  * durability        — write-ahead-log ingest overhead vs no-WAL +
                        crash-recovery fidelity across three kill points
                        (writes BENCH_durability.json)
  * faults            — disarmed-failpoint overhead bound (≤ 1 % gate on
                        ingest + query) + fixed-seed chaos drill: degraded
                        rate, recovery time, zero acked loss
                        (writes BENCH_faults.json)
  * serving           — standing-query push plane vs naive dashboard
                        re-pull: update-latency p50/p99, one merge
                        dispatch per tick, dedup counters
                        (writes BENCH_serving.json)
  * replication       — hot-standby WAL shipping: ship-before-ack
                        overhead (≤ 1.1× gate), replica lag p50/p99,
                        kill -9 → promote failover drill with zero
                        acked loss (writes BENCH_replication.json)
  * roofline          — dry-run derived roofline rows (if results exist)
"""
import argparse
import sys

from benchmarks import core_micro, error_vs_T, error_vs_days, table2_runtimes
from benchmarks import ingest_throughput, interval_query, multi_tenant
from benchmarks import arena as arena_bench
from benchmarks import durability as durability_bench
from benchmarks import faults as faults_bench
from benchmarks import replication as replication_bench
from benchmarks import retention as retention_bench
from benchmarks import roofline_report
from benchmarks import serving as serving_bench


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all")
    args = ap.parse_args()
    chosen = set(args.only.split(",")) if args.only != "all" else None

    def emit(name: str, us_per_call: float, derived: str = "") -> None:
        print(f"{name},{us_per_call:.1f},{derived}", flush=True)

    print("name,us_per_call,derived")
    sections = {
        "error_vs_T": error_vs_T.main,
        "error_vs_days": error_vs_days.main,
        "table2": table2_runtimes.main,
        "core_micro": core_micro.main,
        "interval_query": interval_query.main,
        "ingest": ingest_throughput.main,
        "tenant": multi_tenant.main,
        "retention": retention_bench.main,
        "arena": arena_bench.main,
        "durability": durability_bench.main,
        "faults": faults_bench.main,
        "serving": serving_bench.main,
        "replication": replication_bench.main,
    }
    for key, fn in sections.items():
        if chosen is None or key in chosen:
            fn(emit)
    if chosen is None or "roofline" in chosen:
        try:
            roofline_report.main(emit)
        except Exception as e:  # dry-run results may not exist yet
            print(f"roofline,0.0,unavailable: {e}", file=sys.stderr)


if __name__ == "__main__":
    main()
