"""Paper Figures 16 & 17: error against the merged time interval.

Intervals of 1 day / 1 / 2 / 3 weeks / 1 month, T fixed (the paper used
B·254·2^12 for real data; scaled here), merge vs tuple at equal budget.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    boundary_error,
    build_exact,
    merge_list,
    sample_histogram,
    empirical_size_error,
)
from benchmarks.paper_data import B_PAPER, month

INTERVALS = [1, 7, 14, 21, 31]


def run(kind: str, per_day: int = 100_000, T_factor: int = 32):
    T = B_PAPER * T_factor
    data = month(kind, days=31, per_day=per_day)
    summaries = [build_exact(jnp.asarray(d), T) for d in data]
    rows = []
    for days in INTERVALS:
        pooled = jnp.asarray(np.concatenate(data[:days]))
        exact = build_exact(pooled, B_PAPER)
        t0 = time.perf_counter()
        merged = merge_list(summaries[:days], B_PAPER)
        jax.block_until_ready(merged.sizes)
        t_merge = time.perf_counter() - t0
        budget = min(T * days, pooled.shape[0])
        tup = sample_histogram(pooled, B_PAPER, budget, jax.random.PRNGKey(days))
        rows.append({
            "kind": kind, "days": days,
            "mu_b_merge": float(boundary_error(merged, exact)),
            "mu_s_merge": float(empirical_size_error(merged, pooled)),
            "mu_b_tuple": float(boundary_error(tup, exact)),
            "mu_s_tuple": float(empirical_size_error(tup, pooled)),
            "t_merge_s": t_merge,
        })
    return rows


def main(emit):
    for kind, fig in (("real", "fig16"), ("skewed", "fig17")):
        for r in run(kind):
            emit(
                f"{fig}_{kind}_days{r['days']}",
                r["t_merge_s"] * 1e6,
                f"mu_b merge/tuple={r['mu_b_merge']:.4g}/{r['mu_b_tuple']:.4g} "
                f"mu_s={r['mu_s_merge']:.4g}/{r['mu_s_tuple']:.4g}",
            )


if __name__ == "__main__":
    main(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
