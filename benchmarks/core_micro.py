"""Microbenchmarks of the core primitives (summarize / merge / kernels).

Not a paper table; used by the §Perf loop to track the histogram plane's
own cost (it must stay negligible next to a training step).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Histogram, build_exact, merge
from repro.kernels import (
    bucket_sizes_pallas,
    merge_pallas,
    sort_tiles_pallas,
    summarize_pallas,
)


def timed(fn, reps=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def main(emit):
    rng = np.random.default_rng(0)
    x1m = jnp.asarray(rng.normal(size=1 << 20).astype(np.float32))

    emit("build_exact_1M_T1024", timed(lambda: build_exact(x1m, 1024)) * 1e6, "sort-based")
    emit(
        "summarize_pallas_1M",
        timed(lambda: summarize_pallas(x1m, tile_len=8192, T_tile=512, T_out=1024)) * 1e6,
        "tile-sort + fused merge (interpret)",
    )
    emit(
        "bucket_count_1M_T256",
        timed(lambda: bucket_sizes_pallas(x1m, build_exact(x1m, 256).boundaries)) * 1e6,
        "",
    )
    hs = [build_exact(jnp.asarray(rng.normal(size=50_000).astype(np.float32)), 1024)
          for _ in range(32)]
    stacked = Histogram(
        jnp.stack([h.boundaries for h in hs]), jnp.stack([h.sizes for h in hs])
    )
    emit("merge_32x1024_to_254", timed(lambda: merge(stacked, 254)) * 1e6, "vectorized")
    emit(
        "merge_pallas_32x1024_to_254",
        timed(lambda: merge_pallas(stacked.boundaries, stacked.sizes, 254)) * 1e6,
        "fused kernel (interpret)",
    )
    xt = jnp.asarray(rng.normal(size=(64, 4096)).astype(np.float32))
    emit("tile_sort_64x4096", timed(lambda: sort_tiles_pallas(xt)) * 1e6, "bitonic (interpret)")


if __name__ == "__main__":
    main(lambda n, us, d: print(f"{n},{us:.1f},{d}"))
