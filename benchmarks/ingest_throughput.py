"""Ingest-path throughput: per-partition dispatch vs batched vs async.

The Summarizer-side benchmark for the shape-stable batched ingest pipeline
(core/histogram.py::build_exact_padded*, core/stream.py):

  * **per_partition** — the pre-batching baseline: one jitted ``build_exact``
    per partition, shape-keyed, so every distinct partition length pays a
    fresh XLA compile (measured on a subsample and reported as a rate,
    because running it over the full ragged set is exactly the pathology
    this PR removes);
  * **batched** — ``HistogramStore.ingest_many``: partitions grouped by
    power-of-two padded shape, one vmapped dispatch per group, one
    level-batched tree maintenance pass;
  * **async** — ``ingest_async`` + ``flush``: the background worker drains
    the queue in batches (same grouped summarizer) while the caller is free;
  * **compile counts** for each path, with the O(log max_n) bound asserted
    machine-readably;
  * **t_node trade-off** — geometric vs uniform ``T_node``: build time,
    node-storage floats, and the reported full-window ε of each.

Results print as CSV rows and are written to ``BENCH_ingest.json`` so the
perf trajectory is machine-readable from this PR onward (schema
``bench_ingest/v1``; CI smoke-checks it on tiny sizes via ``--smoke``).

Run standalone: ``PYTHONPATH=src python benchmarks/ingest_throughput.py``
or as a section of ``python -m benchmarks.run --only ingest``.
"""
from __future__ import annotations

import argparse
import json
import time

import jax.numpy as jnp
import numpy as np

from repro.core import HistogramStore, build_exact
from repro.core.histogram import build_exact_padded_batched

SCHEMA = "bench_ingest/v1"


def _jit_cache_size(fn) -> int | None:
    try:
        return fn._cache_size()
    except AttributeError:
        return None


def _compiles(fn, before: int | None) -> int | None:
    after = _jit_cache_size(fn)
    if before is None or after is None:
        return None
    return after - before


def _rates(parts: dict[int, np.ndarray], seconds: float) -> dict:
    values = int(sum(v.size for v in parts.values()))
    return {
        "seconds": seconds,
        "partitions_per_sec": len(parts) / seconds,
        "values_per_sec": values / seconds,
    }


def run_per_partition(parts, T, sample: int) -> dict:
    """Legacy Summarizer: one shape-keyed ``build_exact`` per partition."""
    sub = dict(list(parts.items())[:sample])
    store = HistogramStore(num_buckets=T)
    before = _jit_cache_size(build_exact)
    t0 = time.perf_counter()
    for pid, v in sub.items():
        h = build_exact(jnp.asarray(v), min(T, v.shape[0]))
        h.sizes.block_until_ready()
        store.ingest_summary(pid, h)
    out = _rates(sub, time.perf_counter() - t0)
    out["compiles"] = _compiles(build_exact, before)
    out["measured_partitions"] = len(sub)
    return out


def run_batched(parts, T) -> tuple[dict, HistogramStore]:
    store = HistogramStore(num_buckets=T)
    before = _jit_cache_size(build_exact_padded_batched)
    t0 = time.perf_counter()
    store.ingest_many(parts)
    out = _rates(parts, time.perf_counter() - t0)
    out["compiles"] = _compiles(build_exact_padded_batched, before)
    out["dispatch_shapes"] = len(store.summarize_shapes)
    return out, store


def run_async(parts, T) -> dict:
    store = HistogramStore(num_buckets=T, async_ingest=True)
    t0 = time.perf_counter()
    for pid, v in parts.items():
        store.ingest_async(pid, v)
    t_enqueue = time.perf_counter() - t0
    store.flush()
    out = _rates(parts, time.perf_counter() - t0)
    out["enqueue_seconds"] = t_enqueue  # caller-visible Summarizer latency
    store.close()
    return out


def run_t_node_tradeoff(parts, T) -> dict:
    out = {}
    w = len(parts)
    for mode, label in ((None, "uniform"), ("geometric", "geometric")):
        store = HistogramStore(num_buckets=T, T_node=mode)
        t0 = time.perf_counter()
        store.ingest_many(parts)
        build_s = time.perf_counter() - t0
        node_floats = int(
            sum(
                nd.boundaries.size + nd.sizes.size
                for nd in store._tree.nodes.values()
            )
        )
        t0 = time.perf_counter()
        h, eps = store.query(0, w - 1, 64 if T >= 64 else T)
        np.asarray(h.sizes)
        query_s = time.perf_counter() - t0
        out[label] = {
            "build_seconds": build_s,
            "node_storage_floats": node_floats,
            "full_window_eps": float(eps),
            "full_window_query_seconds": query_s,
        }
    out["eps_ratio_uniform_over_geometric"] = (
        out["uniform"]["full_window_eps"]
        / out["geometric"]["full_window_eps"]
    )
    return out


def make_partitions(n_partitions, lo, hi, seed=0) -> dict[int, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        d: rng.lognormal(-1.8, 0.55, size=int(rng.integers(lo, hi))).astype(
            np.float32
        )
        for d in range(n_partitions)
    }


def main(
    emit,
    *,
    n_partitions: int = 1000,
    len_lo: int = 2048,
    len_hi: int = 16384,
    baseline_sample: int = 128,
    out_path: str = "BENCH_ingest.json",
) -> dict:
    T = 256
    parts = make_partitions(n_partitions, len_lo, len_hi)
    max_n = max(v.size for v in parts.values())
    compile_bound = int(np.log2(max_n)) + 3

    per_part = run_per_partition(parts, T, baseline_sample)
    # cold = first-ever run (includes the O(log max_n) one-time compiles);
    # warm = steady state, the fair throughput comparison: the per-partition
    # baseline can never amortize its compiles (every new partition length
    # is a new executable) while the batched path's O(log) programs cover
    # every future ingest.
    batched_cold, _ = run_batched(parts, T)
    batched, _ = run_batched(parts, T)
    batched["cold_seconds"] = batched_cold["seconds"]
    batched["compiles"] = batched_cold["compiles"]
    batched["dispatch_shapes"] = batched_cold["dispatch_shapes"]
    asynced = run_async(parts, T)
    tnode = run_t_node_tradeoff(
        {d: parts[d] for d in range(min(256, n_partitions))}, T
    )

    speedup_batched = (
        batched["partitions_per_sec"] / per_part["partitions_per_sec"]
    )
    speedup_async = (
        asynced["partitions_per_sec"] / per_part["partitions_per_sec"]
    )
    result = {
        "schema": SCHEMA,
        "partitions": n_partitions,
        "total_values": int(sum(v.size for v in parts.values())),
        "T": T,
        "per_partition": per_part,
        "batched": batched,
        "async": asynced,
        "speedup_batched_vs_per_partition": speedup_batched,
        "speedup_async_vs_per_partition": speedup_async,
        "compile_bound": {
            "max_n": int(max_n),
            "bound": compile_bound,
            "batched_compiles": batched["compiles"],
            "bounded": (
                batched["compiles"] is None
                or batched["compiles"] <= compile_bound
            ),
        },
        "t_node": tnode,
    }
    with open(out_path, "w") as f:
        json.dump(result, f, indent=2)

    emit(
        "ingest_per_partition_rate",
        per_part["partitions_per_sec"],
        f"parts/s, {per_part['measured_partitions']} sampled, "
        f"{per_part['compiles']} compiles",
    )
    emit(
        "ingest_batched_rate",
        batched["partitions_per_sec"],
        f"parts/s over {n_partitions} ragged partitions, "
        f"{batched['dispatch_shapes']} dispatch shapes, "
        f"{batched['compiles']} compiles (bound {compile_bound})",
    )
    emit(
        "ingest_async_rate",
        asynced["partitions_per_sec"],
        f"parts/s incl. flush; enqueue only "
        f"{asynced['enqueue_seconds'] * 1e3:.1f} ms",
    )
    emit(
        "ingest_speedup_batched",
        speedup_batched,
        f"x vs per-partition dispatch (target >= 10x)",
    )
    emit(
        "ingest_tnode_eps_ratio",
        tnode["eps_ratio_uniform_over_geometric"],
        f"uniform/geometric full-window eps; geometric stores "
        f"{tnode['geometric']['node_storage_floats'] / max(1, tnode['uniform']['node_storage_floats']):.1f}x the node floats",
    )
    emit("ingest_json", 0.0, f"written to {out_path}")
    return result


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="tiny sizes for CI: validates the pipeline + JSON schema only",
    )
    ap.add_argument("--out", default="BENCH_ingest.json")
    ap.add_argument("--partitions", type=int, default=1000)
    args = ap.parse_args()
    kw = dict(out_path=args.out, n_partitions=args.partitions)
    if args.smoke:
        kw.update(
            n_partitions=48, len_lo=256, len_hi=2048, baseline_sample=16
        )
    print("name,value,derived")
    main(
        lambda name, v, derived="": print(f"{name},{v:.1f},{derived}", flush=True),
        **kw,
    )
