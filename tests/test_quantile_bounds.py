"""Rank-error guarantees of quantile / CDF queries over merged summaries.

Theorem-2 corollary used by every framework integration (quantile clip,
straggler p95, calibration): the value returned for quantile q has true
rank within ``q·N ± (2N/T + slack)``.
"""
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import build_exact, cdf_interp, merge_list, quantile

settings.register_profile("ci", deadline=None, max_examples=40)
settings.load_profile("ci")


@st.composite
def merged_case(draw):
    k = draw(st.integers(1, 6))
    T = draw(st.integers(8, 64))
    seed = draw(st.integers(0, 2**31 - 1))
    q = draw(st.floats(0.05, 0.95))
    rng = np.random.default_rng(seed)
    parts = [
        (rng.gumbel(size=int(rng.integers(T, 800))) * rng.uniform(0.5, 3)).astype(
            np.float32
        )
        for _ in range(k)
    ]
    return parts, T, q


@given(merged_case())
def test_quantile_rank_error(args):
    parts, T, q = args
    hs = [build_exact(jnp.asarray(p), T) for p in parts]
    merged = merge_list(hs, min(T, 32))
    pooled = np.sort(np.concatenate(parts))
    n = len(pooled)
    v = float(quantile(merged, jnp.float32(q)))
    rank = np.searchsorted(pooled, v)
    bound = 2 * n / T + 2 * len(parts) + 1
    assert abs(rank - q * n) <= bound, (rank, q * n, bound)


@given(merged_case())
def test_cdf_interp_rank_error(args):
    parts, T, q = args
    hs = [build_exact(jnp.asarray(p), T) for p in parts]
    merged = merge_list(hs, min(T, 32))
    pooled = np.sort(np.concatenate(parts))
    n = len(pooled)
    # probe the CDF at an actual data value
    x = pooled[int(q * (n - 1))]
    est = float(cdf_interp(merged, jnp.float32(x)))
    true = np.searchsorted(pooled, x, side="left")
    bound = 2 * n / T + 2 * len(parts) + 1
    # interpolation can only help vs the left-collapse bound at boundaries;
    # allow the same slack
    assert abs(est - true) <= bound + np.sum(pooled == x), (est, true, bound)
