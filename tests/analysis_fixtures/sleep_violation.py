"""Seeded violation (parsed as a test file): time.sleep in a test
(test-sleep ×1)."""
import time


def test_eventually_consistent(store):
    store.kick()
    time.sleep(0.2)  # timing-based interleaving — the banned pattern
    assert store.done()
