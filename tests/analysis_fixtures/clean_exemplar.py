"""Known-clean exemplar: every rule's discipline done right — the
no-false-positive half of the analyzer's own tests."""
import os
import tempfile
import threading

import numpy as np


def read_summaries(path):
    with np.load(path) as data:  # context-managed NpzFile
        return {k: data[k] for k in data.files}


def publish(payload: bytes, path: str) -> None:
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
    with os.fdopen(fd, "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())     # payload durable before the rename
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(path), os.O_RDONLY)
    try:
        os.fsync(dfd)            # the rename durable too
    finally:
        os.close(dfd)


class Drainer:
    def __init__(self):
        self.cv = threading.Condition()
        self.closing = threading.Event()
        self.pending = 0

    def drain(self):
        with self.cv:
            while self.pending:  # predicate loop around wait
                self.cv.wait()

    def pause(self):
        self.closing.wait(0.01)  # Event.wait — not a Condition


def start_worker(fn):
    t = threading.Thread(target=fn, name="worker", daemon=True)
    t.start()
    return t


def careful_cleanup(path):
    try:
        os.unlink(path)
    except FileNotFoundError:
        raise RuntimeError(f"{path} vanished mid-cleanup") from None
