"""Seeded violation: os.replace of a temp-built file with no fsync
before the rename and no directory fsync after (fsync-order ×2)."""
import os
import tempfile


def publish(payload: bytes, path: str) -> None:
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path))
    with os.fdopen(fd, "wb") as f:
        f.write(payload)
    os.replace(tmp, path)  # neither payload nor directory ever fsynced
