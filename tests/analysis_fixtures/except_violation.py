"""Seeded violations (parsed under a durability basename): one bare
except (bare-except ×1) and one swallowed OSError (swallowed-oserror ×1).
"""
import os


def cleanup(path):
    try:
        os.unlink(path)
    except OSError:
        pass  # swallowed disk error in a durability path


def ignore_everything(fn):
    try:
        fn()
    except:  # noqa: E722 — seeded bare except
        return None
