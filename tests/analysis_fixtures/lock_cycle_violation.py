"""Seeded violation: registry/store locks nested in both orders —
one rank inversion (lock-order ×1) closing a cycle (lock-cycle ×1)."""


def forward(reg, store):
    with reg._lock:          # rank 10
        with store._lock:    # rank 20 — documented order
            pass


def backward(reg, store):
    with store._lock:        # rank 20
        with reg._lock:      # rank 10 — inversion, closes the cycle
            pass
