"""Seeded violation: np.load handle never managed (resource-leak ×1)."""
import numpy as np


def read_summaries(path):
    data = np.load(path)  # leaks the NpzFile fd
    return dict(data)
