"""Seeded violation: serving-plane Thread without daemon=True
(thread-daemon ×1)."""
import threading


def start_worker(fn):
    t = threading.Thread(target=fn, name="worker")
    t.start()
    return t
