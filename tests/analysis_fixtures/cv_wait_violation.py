"""Seeded violation: Condition.wait outside a while loop (cv-wait ×1)."""
import threading


class Drainer:
    def __init__(self):
        self.cv = threading.Condition()
        self.pending = 0

    def drain(self):
        with self.cv:
            if self.pending:   # should be `while self.pending:`
                self.cv.wait()
