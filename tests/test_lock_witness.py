"""Runtime lock-discipline witness (repro.analysis.witness).

Unit half: the wrapper semantics — rank inversions raise at the
acquisition site, same-rank store locks require ascending keys, RLocks
re-enter, Conditions wait/notify through the wrapper, and the disarmed
path checks nothing.

Integration half: the two inversions this PR fixed stay fixed — witness
armed, the exact pre-fix interleavings run clean:

1. ``IngestPool._run_batch`` building the error record *under* ``cv``
   (``wrap_error`` → circuit breaker → ``registry._lock`` under rank-34).
2. ``TenantRegistry._apply_groups_batched`` acking breakers *inside* the
   sorted store-lock scope (``registry._lock`` under rank-20 — the
   latent ABBA against ``save()``/``query_many()``).
"""
import threading

import numpy as np
import pytest

from repro.analysis import witness
from repro.analysis.witness import (
    LockOrderError,
    OrderedLock,
    OrderedRLock,
)
from repro.core import faults
from repro.core.resilience import BreakerPolicy, RetryPolicy
from repro.core.tenant import TenantRegistry


@pytest.fixture
def armed():
    was = witness.armed()
    witness.arm()
    try:
        yield
    finally:
        if not was:
            witness.disarm()


# ------------------------------------------------------------------- unit


def test_misordered_acquisition_raises(armed):
    """The acceptance criterion: a deliberately inverted pair raises."""
    wal = OrderedLock("wal._lock")       # rank 42
    store = OrderedRLock("store._lock")  # rank 20
    with wal:
        with pytest.raises(LockOrderError, match="inversion"):
            store.acquire()
    # and the correct order is silent
    with store:
        with wal:
            pass


def test_error_names_both_locks(armed):
    reg = OrderedRLock("registry._lock")
    arena = OrderedRLock("arena._lock")
    with arena:
        with pytest.raises(LockOrderError) as ei:
            reg.acquire()
    msg = str(ei.value)
    assert "registry._lock" in msg and "arena._lock" in msg


def test_same_rank_requires_ascending_keys(armed):
    a = OrderedRLock("store._lock", key="a")
    b = OrderedRLock("store._lock", key="b")
    with a:
        with b:  # ascending — the sorted-acquisition contract
            pass
    with b:
        with pytest.raises(LockOrderError, match="same-rank"):
            a.acquire()


def test_same_rank_unkeyed_is_rejected(armed):
    a = OrderedRLock("store._lock")
    b = OrderedRLock("store._lock")
    with a:
        with pytest.raises(LockOrderError, match="same-rank"):
            b.acquire()


def test_rlock_reentry_and_nonreentrant_self_deadlock(armed):
    r = OrderedRLock("registry._lock")
    with r:
        with r:  # RLock re-entry is always legal
            pass
    lk = OrderedLock("wal._lock")
    with lk:
        with pytest.raises(LockOrderError, match="self-deadlock"):
            lk.acquire()


def test_release_pops_only_that_lock(armed):
    reg = OrderedRLock("registry._lock")
    store = OrderedRLock("store._lock", key="t")
    reg.acquire()
    store.acquire()
    reg.release()  # out-of-order release is legal; stack stays coherent
    assert witness.held_locks() == ["store._lock"]
    store.release()
    assert witness.held_locks() == []


def test_condition_over_ordered_rlock_waits_and_rechecks(armed):
    cv = threading.Condition(OrderedRLock("pool.cv"))
    state = {"ready": False}

    def signal():
        with cv:
            state["ready"] = True
            cv.notify_all()

    t = threading.Thread(target=signal, daemon=True)
    with cv:
        t.start()
        while not state["ready"]:
            cv.wait(timeout=5.0)
    t.join(timeout=5.0)
    assert state["ready"]
    assert witness.held_locks() == []  # wait's release/restore balanced


def test_disarmed_checks_nothing():
    was = witness.armed()  # REPRO_LOCK_WITNESS=1 arms the whole suite
    witness.disarm()
    try:
        wal = OrderedLock("wal._lock")
        store = OrderedRLock("store._lock")
        with wal:
            with store:  # inverted, but the witness is disarmed
                pass
        assert witness.held_locks() == []
    finally:
        if was:
            witness.arm()


def test_acquire_counter_counts_only_armed(armed):
    witness.reset_acquire_count()
    lk = OrderedLock("wal._lock")
    with lk:
        pass
    witness.disarm()
    try:
        with lk:
            pass
    finally:
        witness.arm()
    assert witness.acquire_count() == 1


# ---------------------------------------------------------- integration


def _vals(rng):
    return rng.normal(size=64)


def test_pool_error_path_builds_record_outside_cv(armed):
    """Regression: wrap_error (→ breaker → registry._lock) must run
    before cv is taken — pre-fix this raised LockOrderError in the
    worker and wedged the error report."""
    rng = np.random.default_rng(0)
    reg = TenantRegistry(
        num_buckets=8,
        breaker=BreakerPolicy(threshold=100, cooldown=1e9),
    )
    reg._pool.retry = RetryPolicy(attempts=2, base=0.0, jitter=0.0)
    try:
        bad_only = {"match": lambda ctx: ctx.get("tenant") == "bad"}
        with faults.inject("tenant.apply", **bad_only):
            reg.ingest_async("ok", 0, _vals(rng))
            reg.ingest_async("bad", 0, _vals(rng))
            with pytest.raises(RuntimeError, match="async ingest failed"):
                reg.flush()
        # the error record was built and surfaced; the healthy tenant
        # applied; no LockOrderError killed the worker
        assert len(reg.tenant("ok").summaries) == 1
    finally:
        reg.close()


def test_batched_apply_acks_breaker_after_store_locks(armed):
    """Regression: _apply_groups_batched's breaker acks run after the
    sorted store-lock scope — pre-fix, breaker + shared arena took
    registry._lock under store locks (latent ABBA vs save())."""
    rng = np.random.default_rng(1)
    reg = TenantRegistry(
        num_buckets=8,
        shared_arena=True,  # → the _apply_groups_batched path
        breaker=BreakerPolicy(threshold=2, cooldown=10.0),
    )
    assert reg.arena is not None
    try:
        for t in ("b", "a", "c"):
            for pid in range(2):
                reg.ingest_async(t, pid, _vals(rng))
        reg.flush()
        for t in ("a", "b", "c"):
            assert len(reg.tenant(t).summaries) == 2
    finally:
        reg.close()
