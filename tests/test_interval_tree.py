"""Property tests for the segment-tree interval engine (core/interval_tree).

Two families of guarantees (module docstring of interval_tree.py):

* bit-exactness — the tree's ``query`` (and the batched, shape-padded
  ``query_many``) answers are bit-identical to ``merge_list`` over the
  selected canonical node summaries; when the canonical cover happens to be
  all leaves, that *is* the flat merge over the raw per-partition summaries;
* the composed error bound — the engine's reported ``ε_total`` dominates the
  measured bucket error and every contiguous bucket-range error, both for
  the *reported* sizes and the *true* pooled-value occupancy, across
  randomized ingest orders, gap patterns, and window sizes including
  single-partition and full-range queries.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import HistogramStore, merge_list
from repro.core.interval_tree import canonical_decomposition

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


@st.composite
def store_case(draw):
    W = draw(st.sampled_from([1, 2, 3, 5, 8, 13, 16, 33]))
    # T and n are drawn from small quantized sets so jitted build/merge
    # shapes repeat across cases (bounded compile time, same coverage)
    T = draw(st.sampled_from([8, 32]))
    beta = min(T, draw(st.sampled_from([1, 8, 31])))
    seed = draw(st.integers(0, 2**31 - 1))
    gappy = draw(st.booleans())
    rng = np.random.default_rng(seed)
    pids = list(range(W))
    if gappy and W > 2:  # knock out up to W//3 partitions
        keep = rng.choice(W, size=W - int(rng.integers(1, W // 3 + 1)),
                          replace=False)
        pids = sorted(int(i) for i in keep)
    rng.shuffle(order := list(pids))
    store = HistogramStore(num_buckets=T)
    raw = {}
    has_dups = False
    for pid in order:  # randomized ingest order
        n = 64 * int(rng.integers(1, 7))
        kind = int(rng.integers(0, 3))
        if kind == 0:
            v = rng.normal(size=n)
        elif kind == 1:
            v = rng.gumbel(size=n) * rng.uniform(0.1, 10)
        else:
            v = rng.integers(0, 50, size=n).astype(float)
            has_dups = True
        raw[pid] = v.astype(np.float32)
        store.ingest(pid, raw[pid])
    lo = int(rng.integers(pids[0], pids[-1] + 1))
    hi = int(rng.integers(lo, pids[-1] + 1))
    while not any(lo <= p <= hi for p in pids):  # interval must be non-empty
        lo = int(rng.integers(pids[0], pids[-1] + 1))
        hi = int(rng.integers(lo, pids[-1] + 1))
    return store, raw, lo, hi, beta, has_dups


def _present(raw, lo, hi):
    return [p for p in sorted(raw) if lo <= p <= hi]


@given(store_case())
def test_tree_query_bitexact_vs_flat_merge_of_canonical_nodes(args):
    """query ≡ merge_list over the canonical node summaries, bit for bit —
    including the power-of-two k padding of the static-shape merge path."""
    store, raw, lo, hi, beta, _ = args
    tree = store._tree
    h, eps = store.query(lo, hi, beta, strict=False)
    sel = [tree.nodes[k] for k in tree.decompose(lo, hi)]
    want = merge_list([nd.to_histogram() for nd in sel], beta)
    np.testing.assert_array_equal(
        np.asarray(h.boundaries), np.asarray(want.boundaries)
    )
    np.testing.assert_array_equal(np.asarray(h.sizes), np.asarray(want.sizes))
    # the tentpole claim: O(log W) summaries per query, not O(window)
    span = hi - lo + 1
    assert len(sel) <= 2 * max(1, (span - 1).bit_length()) + 1


@given(store_case())
def test_leaf_only_covers_equal_flat_merge_over_partitions(args):
    """Single-partition and pair-boundary-crossing spans decompose into raw
    leaves, so the tree answer IS the flat merge over partition summaries."""
    store, raw, lo, hi, beta, _ = args
    tree = store._tree
    pids = _present(raw, lo, hi)
    for a, b in [(pids[0], pids[0]), (pids[-1], pids[-1])]:
        keys = tree.decompose(a, b)
        if any(lvl != 0 for lvl, _ in keys):
            continue
        h, _ = store.query(a, b, beta, strict=False)
        flat = merge_list(
            [store.summaries[p].to_histogram() for p in _present(raw, a, b)],
            beta,
        )
        np.testing.assert_array_equal(
            np.asarray(h.boundaries), np.asarray(flat.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h.sizes), np.asarray(flat.sizes)
        )


@given(store_case())
def test_query_many_bitexact_vs_query(args):
    """The batched single-dispatch path pads every query's node set to one
    static shape — padding must not change a single bit of any answer."""
    store, raw, lo, hi, beta, _ = args
    pids = _present(raw, sorted(raw)[0], sorted(raw)[-1])
    intervals = [
        (lo, hi),
        (pids[0], pids[-1]),  # full range
        (pids[0], pids[0]),  # single partition
    ]
    batched = store.query_many(intervals, beta, strict=False)
    for (a, b), (hm, em) in zip(intervals, batched):
        h1, e1 = store.query(a, b, beta, strict=False)
        np.testing.assert_array_equal(
            np.asarray(h1.boundaries), np.asarray(hm.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(hm.sizes)
        )
        assert e1 == em


@given(store_case())
def test_reported_eps_dominates_measured_error(args):
    """Theorem 1/2, composed per level: reported sizes, true pooled-value
    occupancy, and every contiguous bucket range stay within ε_total."""
    store, raw, lo, hi, beta, has_dups = args
    h, eps = store.query(lo, hi, beta, strict=False)
    pids = _present(raw, lo, hi)
    pooled = np.sort(np.concatenate([raw[p] for p in pids]))
    n = pooled.size
    sizes = np.asarray(h.sizes, np.float64)
    assert float(sizes.sum()) == pytest.approx(n, abs=0.5)
    # Theorem 1 on reported sizes
    assert np.abs(sizes - n / beta).max() <= eps + 1e-3
    # Theorem 2 on every contiguous range of reported sizes
    cum = np.concatenate([[0.0], np.cumsum(sizes)])
    dev = np.abs(
        cum[:, None] - cum[None, :]
        - (np.arange(beta + 1)[:, None] - np.arange(beta + 1)[None, :])
        * n
        / beta
    )
    assert dev.max() <= eps + 1e-3
    if has_dups:
        return  # tied boundaries make true counts ambiguous by the tie mass
    # Theorem 1 on TRUE occupancy of the answer's buckets
    b = np.asarray(h.boundaries, np.float64)
    lo_i = np.searchsorted(pooled, b[:-1], side="left")
    hi_i = np.searchsorted(pooled, b[1:], side="left")
    true_sizes = (hi_i - lo_i).astype(np.float64)
    true_sizes[-1] += np.sum(pooled == b[-1])  # last bucket right-closed
    assert np.abs(true_sizes - n / beta).max() <= eps + 1e-3


@given(st.integers(0, 2**16), st.integers(1, 4096))
def test_canonical_decomposition_covers_exactly(lo_seed, span):
    """The cover partitions [lo, hi] exactly: disjoint, complete, ≤2/level."""
    lo = lo_seed % 512
    hi = lo + span % 512
    keys = canonical_decomposition(lo, hi)
    slots = []
    for lvl, idx in keys:
        slots.extend(range(idx << lvl, (idx + 1) << lvl))
    assert sorted(slots) == list(range(lo, hi + 1))
    levels = [lvl for lvl, _ in keys]
    assert all(levels.count(l) <= 2 for l in set(levels))
    assert len(keys) <= 2 * max(1, (hi - lo).bit_length()) + 1


def test_cache_serves_repeats_and_invalidates_on_ingest():
    rng = np.random.default_rng(0)
    store = HistogramStore(num_buckets=32)
    for d in range(8):
        store.ingest(d, rng.normal(size=200).astype(np.float32))
    v0 = store.version
    h1, _ = store.query(0, 7, beta=8)
    h2, _ = store.query(0, 7, beta=8)
    stats = store.cache_stats()
    assert stats["hits"] >= 1
    np.testing.assert_array_equal(np.asarray(h1.sizes), np.asarray(h2.sizes))
    store.ingest(8, rng.normal(size=200).astype(np.float32))
    assert store.version > v0  # mutation bumps version → stale keys dead
    h3, _ = store.query(0, 8, beta=8)
    assert float(np.asarray(h3.sizes).sum()) == 9 * 200


def test_tree_survives_direct_summary_deletion():
    """The documented summary-loss idiom mutates the dict directly; the
    engine must detect the desync and re-answer from surviving leaves."""
    rng = np.random.default_rng(1)
    store = HistogramStore(num_buckets=32)
    for d in range(6):
        store.ingest(d, rng.normal(size=300).astype(np.float32))
    del store.summaries[3]
    h, eps = store.query(0, 5, beta=8, strict=False)
    assert float(np.asarray(h.sizes).sum()) == 5 * 300
    with pytest.raises(KeyError):
        store.query(0, 5, beta=8, strict=True)


def test_tree_detects_same_count_summary_replacement():
    """Replacing a summary row in place (same n, different values) must not
    serve a stale cached/pre-merged answer — the identity scan catches it."""
    import jax.numpy as jnp

    from repro.core import StoredSummary, build_exact

    rng = np.random.default_rng(4)
    store = HistogramStore(num_buckets=32)
    for d in range(4):
        store.ingest(d, rng.normal(size=250).astype(np.float32))
    shifted = (rng.normal(size=250) * 50 + 1000).astype(np.float32)
    h = build_exact(jnp.asarray(shifted), 32)
    store.summaries[1] = StoredSummary(
        1, 250, np.asarray(h.boundaries), np.asarray(h.sizes)
    )
    ht, _ = store.query(0, 3, beta=8)
    hf, _ = store.query(0, 3, beta=8, engine="flat")
    assert float(np.asarray(ht.boundaries).max()) == float(
        np.asarray(hf.boundaries).max()
    )
    assert float(np.asarray(ht.boundaries).max()) > 100  # sees the new data


def test_persistence_roundtrip_preserves_tree_answers():
    import os
    import tempfile

    rng = np.random.default_rng(2)
    store = HistogramStore(num_buckets=64)
    for d in range(12):
        store.ingest(d, rng.gumbel(size=400).astype(np.float32))
    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "summaries.npz")
        store.save(path)
        loaded = HistogramStore.load(path)
    assert loaded._tree.nodes.keys() == store._tree.nodes.keys()
    for (a, b) in [(0, 11), (3, 9), (5, 5)]:
        h1, e1 = store.query(a, b, beta=16)
        h2, e2 = loaded.query(a, b, beta=16)
        np.testing.assert_array_equal(
            np.asarray(h1.boundaries), np.asarray(h2.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(h2.sizes)
        )
        assert e1 == e2
