"""Unit tests for the logical-axis sharding rules (no devices needed)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.sharding import Rules


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    # Rules only reads mesh.shape / axis_names — an abstract mesh suffices.
    try:  # jax ≥ 0.5: AbstractMesh(shape, axis_names)
        return jax.sharding.AbstractMesh(shape, axes)
    except TypeError:  # jax 0.4.x: AbstractMesh(((name, size), ...))
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def test_train_rules_dense():
    cfg = get_config("qwen3-8b")
    r = Rules(cfg, fake_mesh(), "train", seq_len=4096)
    assert r(("vocab", "embed")) == P("model", "data")
    assert r(("embed", "mlp")) == P("data", "model")
    assert r(("layers", "embed", "heads", None)) == P(None, "data", "model", None)
    # kv=8 does not divide model=16 → replicated kv heads
    assert r(("embed", "kv_heads", None)) == P("data", None, None)
    assert r(("act_batch", "act_seq", None)) == P(("data",), "model", None)


def test_multi_pod_batch_axes():
    cfg = get_config("deepseek-7b")
    mesh = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    r = Rules(cfg, mesh, "train", seq_len=4096)
    assert r(("act_batch", None)) == P(("pod", "data"), None)
    # weights replicate over pod (pure DP between pods)
    assert r(("embed", "mlp")) == P("data", "model")


def test_smollm_attention_replication_fallback():
    cfg = get_config("smollm-135m")
    r = Rules(cfg, fake_mesh(), "train", seq_len=4096)
    assert r(("embed", "heads", None)) == P("data", None, None)  # 9 !% 16
    assert r(("embed", "mlp")) == P("data", "model")  # 1536 % 16 == 0
    assert any("heads" in d for d in r.degradations())


def test_decode_kv_seq_sharding():
    cfg = get_config("qwen3-8b")
    r = Rules(cfg, fake_mesh(), "decode", seq_len=32768)
    assert r(("batch_kv", "kv_seq", "kv_heads_cache", None)) == P(
        ("data",), ("model",), None, None
    )
    # decode: no sequence parallelism on the (length-1) activation seq
    assert r(("act_batch", "act_seq", None)) == P(("data",), None, None)


def test_long_context_rules():
    cfg = get_config("jamba-v0.1-52b")
    r = Rules(cfg, fake_mesh(), "decode_long", seq_len=524288)
    # batch=1 → replicated; KV sequence spreads over data AND model
    assert r(("batch_kv", "kv_seq", "kv_heads_cache", None)) == P(
        None, ("data", "model"), None, None
    )


def test_prefill_kv_seq_now_sharded():
    """§Perf P2: prefill caches must not materialize unsharded."""
    cfg = get_config("deepseek-7b")
    r = Rules(cfg, fake_mesh(), "prefill", seq_len=32768)
    spec = r(("batch_kv", "kv_seq", "kv_heads_cache", None))
    assert spec[1] in ("model", ("model",))  # P() normalizes 1-tuples


def test_expert_sharding():
    for arch, divisible in [("dbrx-132b", True), ("llama4-maverick-400b-a17b", True)]:
        cfg = get_config(arch)
        r = Rules(cfg, fake_mesh(), "train", seq_len=4096)
        spec = r(("experts", "embed", "expert_mlp"))
        assert spec == P("model", "data", None)


def test_seq_parallel_divisibility_guard():
    cfg = get_config("qwen3-8b")
    r = Rules(cfg, fake_mesh(), "train", seq_len=100)  # 100 !% 16
    assert r(("act_batch", "act_seq", None)) == P(("data",), None, None)


def test_vocab_padding_whisper():
    cfg = get_config("whisper-medium")
    assert cfg.vocab_size % 16 == 0  # padded 51865 → 51872
    r = Rules(cfg, fake_mesh(), "train", seq_len=4096)
    assert r(("vocab", "embed")) == P("model", "data")
