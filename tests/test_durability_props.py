"""Kill-at-arbitrary-point property test for durable ingest.

Each drawn case builds a random multi-tenant ingest script, picks a
crash point *anywhere* in it — before the first save, right after a
save, or mid-stream with a snapshot somewhere behind — optionally tears
the WAL's trailing record (a partially-flushed disk block), then drops
the live registry without ``flush``/``close``/``save``.  The recovered
registry must bit-match a never-crashed replica fed exactly the acked
records (minus a torn trailing record not covered by the snapshot — its
durability was lost *by the disk*, but its loss must be detected, not
silently half-applied).  Zero acked-partition loss otherwise.

Runs in the fast lane (no ``slow`` mark): 12 drawn cases, tiny arrays,
one jit shape.
"""
import os
import shutil
import tempfile

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import TenantRegistry

settings.register_profile("ci", deadline=None, max_examples=12)
settings.load_profile("ci")

T = 8
BETA = 16
N_VALUES = 32  # one shape → one jit compile across all cases


@st.composite
def crash_case(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    n_tenants = draw(st.integers(1, 2))
    n_records = draw(st.integers(3, 8))
    # crash after `save_point` records were snapshotted (n_records+1 ⇒
    # never saved); torn tail only meaningful when the last acked record
    # is NOT covered by the snapshot
    save_point = draw(st.integers(0, n_records + 1))
    torn = draw(st.booleans())
    return seed, n_tenants, n_records, save_point, torn


@given(crash_case())
def test_recovery_bit_matches_acked_state(case):
    seed, n_tenants, n_records, save_point, torn = case
    rng = np.random.default_rng(seed)
    tenants = [f"t{i}" for i in range(n_tenants)]
    base = tempfile.mkdtemp(prefix="durprops-")
    try:
        snap = os.path.join(base, "reg.npz")
        wal_dir = os.path.join(base, "wal")
        reg = TenantRegistry(num_buckets=T, wal_dir=wal_dir)
        acked: list[tuple[str, int, np.ndarray]] = []
        next_pid = {t: 0 for t in tenants}
        saved = False
        for i in range(n_records):
            if i == save_point:
                reg.save(snap)  # snapshot mid-stream: truncates the log
                saved = True
            t = tenants[int(rng.integers(0, n_tenants))]
            next_pid[t] += int(rng.integers(1, 3))  # gappy monotone pids
            v = rng.normal(size=N_VALUES).astype(np.float32)
            reg.ingest(t, next_pid[t], v)  # fsynced before this returns
            acked.append((t, next_pid[t], v))
        if save_point == n_records:
            reg.save(snap)
            saved = True
        del reg  # kill -9: in-memory state is gone, the log survives

        # tear the trailing record only when the snapshot doesn't cover
        # it — that models the disk losing a block the process already
        # acked; recovery must drop exactly that record, nothing else
        expected = list(acked)
        covered = save_point if save_point <= n_records else 0
        uncovered = n_records - covered
        if torn and uncovered > 0 and acked:
            segs = sorted(
                f for f in os.listdir(wal_dir) if f.startswith("wal-")
            )
            last = os.path.join(wal_dir, segs[-1])
            sz = os.path.getsize(last)
            with open(last, "r+b") as f:
                f.truncate(sz - 9)  # cut into the last record's payload
            expected = acked[:-1]

        rec = TenantRegistry.recover(snap, wal_dir, num_buckets=T)
        ref = TenantRegistry(num_buckets=T)
        want: dict[str, dict[int, np.ndarray]] = {}
        for t, pid, v in expected:
            want.setdefault(t, {})[pid] = v
        for t, parts in want.items():
            ref.ingest_many(t, parts)

        assert sorted(rec.names()) == sorted(want)  # zero acked loss
        for t, parts in want.items():
            assert rec[t].ids() == sorted(parts)
            assert rec[t]._watermark == ref[t]._watermark
        # gappy pids ⇒ strict=False (both replicas have identical gaps)
        panels = [(t, min(p), max(p)) for t, p in sorted(want.items())]
        for (gh, ge), (wh, we) in zip(
            rec.query_many(panels, BETA, strict=False),
            ref.query_many(panels, BETA, strict=False),
        ):
            assert np.array_equal(
                np.asarray(gh.boundaries), np.asarray(wh.boundaries)
            )
            assert np.array_equal(
                np.asarray(gh.sizes), np.asarray(wh.sizes)
            )
            assert ge == we
        rec.close()
        ref.close()
    finally:
        shutil.rmtree(base, ignore_errors=True)
