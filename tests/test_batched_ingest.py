"""Shape-stable batched Summarizer: bit-exactness and compile stability.

Two guarantees of the padded ingest pipeline (core/histogram.py,
core/stream.py):

* **bit-exactness** — ``build_exact_padded`` (and its vmapped batched form,
  and therefore every summary the store writes) is bit-identical to
  ``build_exact`` on the unpadded values: the +inf sentinel sorts past every
  real value and the masked cut indices never reach it;
* **compile stability** — summarizing any mix of partition lengths costs
  O(log max_n) compiled executables (one per power-of-two shape bucket), not
  one per distinct length, asserted both on the store's dispatch-shape log
  and on the actual jit cache.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core import (
    HistogramStore,
    build_exact,
    build_exact_padded,
    build_exact_padded_batched,
    pad_pow2,
)

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")


@st.composite
def padded_case(draw):
    # n and T drawn from quantized sets so jitted shapes repeat across cases
    n = draw(st.sampled_from([1, 2, 7, 64, 65, 200, 513]))
    T = draw(st.sampled_from([1, 4, 32]))
    seed = draw(st.integers(0, 2**31 - 1))
    kind = draw(st.sampled_from(["normal", "dups", "sorted"]))
    rng = np.random.default_rng(seed)
    if kind == "normal":
        v = rng.normal(size=n) * rng.uniform(0.1, 100)
    elif kind == "dups":
        v = rng.integers(0, max(2, n // 4), size=n).astype(float)
    else:
        v = np.sort(rng.gumbel(size=n))
    return v.astype(np.float32), T


@given(padded_case())
def test_build_exact_padded_bitexact(case):
    """Padding + masked cuts reproduce build_exact bit for bit — including
    duplicate-heavy and pre-sorted inputs, and T > n."""
    v, T = case
    padded, n = pad_pow2(v)
    h0 = build_exact(jnp.asarray(v), T)
    h1 = build_exact_padded(jnp.asarray(padded), n, T)
    np.testing.assert_array_equal(
        np.asarray(h0.boundaries), np.asarray(h1.boundaries)
    )
    np.testing.assert_array_equal(np.asarray(h0.sizes), np.asarray(h1.sizes))


@given(st.integers(0, 2**31 - 1))
def test_batched_rows_equal_single_padded(seed):
    """The one-dispatch (k, n_pad) stack gives each row exactly the result
    of summarizing that row alone."""
    rng = np.random.default_rng(seed)
    T = 16
    vs = [
        rng.normal(size=int(rng.integers(T, 512))).astype(np.float32)
        for _ in range(4)
    ]
    pads = [pad_pow2(v, min_len=512) for v in vs]
    stack = np.stack([p[0] for p in pads])
    ns = np.asarray([p[1] for p in pads], np.int32)
    hb = build_exact_padded_batched(jnp.asarray(stack), ns, T)
    for i, v in enumerate(vs):
        h0 = build_exact(jnp.asarray(v), T)
        np.testing.assert_array_equal(
            np.asarray(hb.boundaries[i]), np.asarray(h0.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(hb.sizes[i]), np.asarray(h0.sizes)
        )


def test_store_summaries_bitexact_vs_legacy_build():
    """Every summary the store writes through the padded pipeline equals the
    legacy per-partition ``build_exact(values, min(T, n))`` bit for bit."""
    rng = np.random.default_rng(7)
    T = 64
    store = HistogramStore(num_buckets=T)
    for pid, n in enumerate([3, 63, 64, 65, 900, 4096, 5000]):
        v = rng.gumbel(size=n).astype(np.float32)
        store.ingest(pid, v)
        want = build_exact(jnp.asarray(v), min(T, n))
        s = store.summaries[pid]
        np.testing.assert_array_equal(s.boundaries, np.asarray(want.boundaries))
        np.testing.assert_array_equal(s.sizes, np.asarray(want.sizes))
        assert s.n == n


def test_compile_stability_50_random_length_ingests():
    """50 ingests of random lengths compile O(log max_n) executables, not
    O(#distinct lengths)."""
    rng = np.random.default_rng(11)
    T = 64
    max_n = 8192
    store = HistogramStore(num_buckets=T)
    try:
        cache_before = build_exact_padded_batched._cache_size()
    except AttributeError:  # jax without the introspection hook
        cache_before = None
    lengths = rng.integers(T, max_n + 1, size=50)
    assert len(set(lengths)) > 20  # the mix really is ragged
    for pid, n in enumerate(lengths):
        store.ingest(pid, rng.normal(size=int(n)).astype(np.float32))
    bound = int(np.log2(max_n)) + 2
    # every dispatch was a (1, n_pad, T) shape with n_pad a power of two
    assert len(store.summarize_shapes) <= bound
    assert all(
        n_pad & (n_pad - 1) == 0 for (_, n_pad, _) in store.summarize_shapes
    )
    if cache_before is not None:
        compiled = build_exact_padded_batched._cache_size() - cache_before
        assert compiled <= bound
    # and the store still answers correctly over the ragged mix
    h, eps = store.query(0, 49, beta=16)
    assert float(np.asarray(h.sizes).sum()) == pytest.approx(lengths.sum())


def test_ingest_many_groups_shapes_and_matches_sequential():
    """ingest_many groups partitions into one dispatch per shape bucket and
    produces a store indistinguishable from sequential ingest."""
    rng = np.random.default_rng(3)
    T = 32
    parts = {
        d: rng.normal(size=int(rng.integers(T, 3000))).astype(np.float32)
        for d in range(40)
    }
    s_bulk = HistogramStore(num_buckets=T)
    s_bulk.ingest_many(parts)
    n_pads = {1 << (len(v) - 1).bit_length() for v in parts.values()}
    assert len(s_bulk.summarize_shapes) <= len(n_pads) + 1
    s_seq = HistogramStore(num_buckets=T)
    for d in sorted(parts):
        s_seq.ingest(d, parts[d])
    for (a, b) in [(0, 39), (5, 17), (12, 12)]:
        h1, e1 = s_bulk.query(a, b, beta=8)
        h2, e2 = s_seq.query(a, b, beta=8)
        np.testing.assert_array_equal(
            np.asarray(h1.boundaries), np.asarray(h2.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(h2.sizes)
        )
        assert e1 == e2


def test_empty_partition_rejected():
    store = HistogramStore(num_buckets=8)
    with pytest.raises(ValueError):
        store.ingest(0, np.asarray([], np.float32))


def _check_ragged_summarize(n, tile_len, T, rng):
    from repro.kernels import summarize_pallas

    x = rng.gumbel(size=n).astype(np.float32)
    h = summarize_pallas(jnp.asarray(x), tile_len=tile_len, T_tile=T, T_out=T)
    k = -(-n // tile_len)
    assert float(np.asarray(h.sizes).sum()) == pytest.approx(n)
    assert np.abs(np.asarray(h.sizes) - n / T).max() <= 2 * n / T + 2 * k
    b = np.asarray(h.boundaries)
    assert np.all(np.isfinite(b))  # the +inf sentinel never leaks
    assert b[-1] == pytest.approx(x.max())
    assert b[0] == pytest.approx(x.min())


def test_summarize_pallas_ragged_tail():
    """The Pallas tile-sort Summarizer accepts lengths that are not a
    multiple of tile_len: the sentinel-padded tail tile is masked out."""
    rng = np.random.default_rng(5)
    _check_ragged_summarize(2 * 512 + 117, 512, 32, rng)


@pytest.mark.slow
@pytest.mark.parametrize("n", [517, 1024, 3 * 1024 + 517, 2 * 1024 + 1])
def test_summarize_pallas_ragged_sweep(n):
    """Tail shapes across the tile grid: sub-tile, exact, mid, off-by-one —
    divisible lengths take the exact same path as before the padding."""
    rng = np.random.default_rng(5)
    _check_ragged_summarize(n, 1024, 64, rng)
