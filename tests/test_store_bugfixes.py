"""Regression tests for verified HistogramStore/IntervalTree bugs.

Each test here failed on the pre-fix code:

* ``query_many(strict=False)`` raised ``KeyError`` out of
  ``IntervalTree._selected`` when any interval in the batch held zero
  present summaries — one empty query killed the whole batch, violating
  the documented summary-loss tolerance;
* ``_async_errors`` was appended from the worker thread and swap-read by
  ``flush()`` with no common lock — a flush concurrent with a failing
  batch could drop or double-report errors;
* ``IntervalTree.query_many`` bypassed the LRU answer cache entirely, so
  repeated dashboard batches re-merged every window and ``cache_stats``
  under-counted;
* ``HistogramStore.load`` never closed its ``NpzFile`` — the fd leaked
  for the store's lifetime;
* ``ingest_many`` under ``async_ingest=True`` silently bypassed the queue
  and applied synchronously, breaking FIFO prefix visibility with respect
  to concurrently enqueued partitions.
"""
import threading

import numpy as np
import pytest

from repro.core import HistogramStore
from repro.core.interval_tree import pack_node_rows

T = 32
BETA = 8
N_PER = 200


def _store(days=6, seed=0, **kw):
    rng = np.random.default_rng(seed)
    store = HistogramStore(num_buckets=T, **kw)
    parts = {d: rng.gumbel(size=N_PER).astype(np.float32) for d in range(days)}
    if kw.get("async_ingest"):
        return store, parts
    store.ingest_many(parts)
    return store, parts


# ------------------------------------------------- strict=False empty query
def test_query_many_tolerates_fully_empty_interval():
    """An interval with ZERO present summaries must not kill the batch:
    its slot is the documented (None, inf) placeholder, with stable
    indexing for every other answer."""
    store, _ = _store(days=6)
    intervals = [(0, 5), (100, 200), (2, 4)]  # middle one: nothing present
    res = store.query_many(intervals, BETA, strict=False)
    assert len(res) == 3
    h0, e0 = res[0]
    assert float(np.asarray(h0.sizes).sum()) == 6 * N_PER
    assert res[1] == (None, float("inf"))
    h2, e2 = res[2]
    assert float(np.asarray(h2.sizes).sum()) == 3 * N_PER
    # stable indexing: answers bit-match the single-query path
    h, e = store.query(2, 4, BETA)
    np.testing.assert_array_equal(np.asarray(h.sizes), np.asarray(h2.sizes))
    assert e == e2


def test_query_many_all_empty_and_strict_still_raises():
    store, _ = _store(days=4)
    res = store.query_many([(50, 60), (70, 80)], BETA, strict=False)
    assert res == [(None, float("inf"))] * 2
    with pytest.raises(KeyError):
        store.query_many([(0, 3), (50, 60)], BETA, strict=True)


def test_query_many_empty_after_summary_loss():
    """The documented loss idiom: delete every summary of one window —
    the batch keeps answering the surviving windows."""
    store, _ = _store(days=8)
    for pid in (4, 5):
        del store.summaries[pid]
    res = store.query_many([(0, 3), (4, 5), (6, 7)], BETA, strict=False)
    assert float(np.asarray(res[0][0].sizes).sum()) == 4 * N_PER
    assert res[1] == (None, float("inf"))
    assert float(np.asarray(res[2][0].sizes).sum()) == 2 * N_PER


def test_pack_node_rows_guards_empty_rows():
    """pack_node_rows used to index r[-1] on an empty row (IndexError);
    now an empty row packs to a zero-mass block and an all-empty pack
    raises a clear ValueError."""
    store, _ = _store(days=4)
    tree = store._tree
    sel = [tree.nodes[k] for k in tree.decompose(0, 3)]
    bounds, sizes = pack_node_rows([sel, []])
    assert bounds.shape[0] == 2 and sizes[1].sum() == 0.0
    with pytest.raises(ValueError):
        pack_node_rows([[], []])


# ------------------------------------------------------- async error race
def test_async_error_appends_hold_the_flush_lock():
    """The worker's error append and flush()'s swap-read must synchronize
    on the same condition: pre-fix the append ran lock-free, so a flush
    racing a failing batch could lose the error into the swapped-out list.
    A non-reentrant lock makes the invariant deterministic to check."""
    store = HistogramStore(num_buckets=T, async_ingest=True)
    store._cv = threading.Condition(threading.Lock())  # non-reentrant
    unlocked_appends = []

    class Guarded(list):
        def append(self, item):
            if store._cv._lock.acquire(blocking=False):
                store._cv._lock.release()
                unlocked_appends.append(item)
            super().append(item)

    store._async_errors = Guarded()
    store._summarize_batch = lambda parts: (_ for _ in ()).throw(
        RuntimeError("boom")
    )
    rng = np.random.default_rng(0)
    for d in range(4):
        store.ingest_async(d, rng.normal(size=16).astype(np.float32))
    with pytest.raises(RuntimeError):
        store.flush()
    assert unlocked_appends == []  # every append held _cv
    store.close()


def test_async_error_conservation_under_concurrent_flush():
    """Stress the flush-vs-failing-batch interleaving: every failed
    partition is reported by exactly one flush — none dropped, none
    doubled — while a second thread keeps enqueueing poison."""
    store = HistogramStore(num_buckets=T, async_ingest=True, queue_size=8192)
    orig = store._summarize_batch

    def failing(parts):
        bad = [pid for pid in parts if pid % 2 == 1]
        if bad:
            raise RuntimeError(f"poison {bad}")
        return orig(parts)

    store._summarize_batch = failing
    total = 300  # odd pids fail; even pids are tiny but valid
    rng = np.random.default_rng(1)
    rows = {pid: rng.normal(size=16).astype(np.float32) for pid in range(total)}

    def produce():
        for pid in range(total):
            store.ingest_async(pid, rows[pid])

    producer = threading.Thread(target=produce)
    producer.start()
    reported: list[str] = []
    while True:
        try:
            store.flush()
        except RuntimeError as e:
            reported.append(str(e))
        if not producer.is_alive():
            break
    producer.join()
    try:
        store.flush()  # final drain of any errors raised after the loop
    except RuntimeError as e:
        reported.append(str(e))
    seen = []
    for msg in reported:
        for pid in range(total):
            if f"partition {pid}:" in msg:
                seen.append(pid)
    expect = [pid for pid in range(total) if pid % 2 == 1]
    assert sorted(seen) == expect  # exactly-once error reporting
    store._summarize_batch = orig
    store.close()
    assert sorted(store.ids()) == [pid for pid in range(total) if pid % 2 == 0]


# ------------------------------------------------- query_many cache reuse
def test_query_many_serves_and_populates_the_lru():
    """query_many must consult the same LRU as query: a warm window is a
    hit (no re-merge), a cold one populates the cache for later queries."""
    store, _ = _store(days=8)
    store.query(0, 7, BETA)  # warm one window
    tree = store._tree
    hits0, disp0 = tree.cache_hits, tree.merge_dispatches
    res = store.query_many([(0, 7), (2, 5)], BETA)
    assert tree.cache_hits == hits0 + 1  # (0,7) came from the LRU
    assert tree.merge_dispatches == disp0 + 1  # one dispatch for the miss
    # and the miss is now cached: a repeat batch costs zero dispatches
    res2 = store.query_many([(0, 7), (2, 5)], BETA)
    assert tree.merge_dispatches == disp0 + 1
    assert tree.cache_hits == hits0 + 3
    for (h1, e1), (h2, e2) in zip(res, res2):
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(h2.sizes)
        )
        assert e1 == e2


def test_query_many_dedupes_repeated_windows_within_a_batch():
    store, _ = _store(days=8)
    tree = store._tree
    disp0, miss0 = tree.merge_dispatches, tree.cache_misses
    tree.merge_shapes.clear()
    res = store.query_many([(1, 6), (1, 6), (1, 6)], BETA)
    assert tree.merge_dispatches == disp0 + 1
    assert tree.cache_misses == miss0 + 1  # ONE miss, not one per duplicate
    ((Q, _, _, _),) = tree.merge_shapes  # and the dispatch packed ONE row
    assert Q == 1
    for h, e in res:
        np.testing.assert_array_equal(
            np.asarray(h.sizes), np.asarray(res[0][0].sizes)
        )


def test_query_many_cache_respects_version():
    """Cached batch answers must die with the next mutation."""
    store, _ = _store(days=8)
    store.query_many([(0, 7)], BETA)
    rng = np.random.default_rng(7)
    store.ingest(8, rng.gumbel(size=N_PER).astype(np.float32))
    (h, e), = store.query_many([(0, 8)], BETA)
    assert float(np.asarray(h.sizes).sum()) == 9 * N_PER


# ----------------------------------------------------- npz handle leak
def test_load_closes_the_npz_file(tmp_path, monkeypatch):
    """HistogramStore.load kept the NpzFile (and its fd) open forever;
    it must be closed by the time load returns, with every array
    materialized."""
    store, _ = _store(days=4)
    path = str(tmp_path / "s.npz")
    store.save(path)
    opened = []
    orig = np.load

    def spy(*a, **k):
        f = orig(*a, **k)
        opened.append(f)
        return f

    monkeypatch.setattr(np, "load", spy)
    loaded = HistogramStore.load(path)
    assert opened, "np.load was not used"
    for f in opened:
        assert f.zip is None and f.fid is None  # NpzFile.close() ran
    h1, _ = store.query(0, 3, BETA)
    h2, _ = loaded.query(0, 3, BETA)
    np.testing.assert_array_equal(np.asarray(h1.sizes), np.asarray(h2.sizes))


# ------------------------------------- ingest_many under async_ingest=True
def _gate_worker(store):
    """Block the background worker's summarization until the gate opens —
    deterministic visibility probes without sleeping.  Only the worker
    thread is gated, so a (buggy) synchronous apply on the caller thread
    runs straight through and is caught by the assertions."""
    gate = threading.Event()
    orig = store._summarize_batch

    def gated(parts):
        if threading.current_thread() is not threading.main_thread():
            gate.wait(timeout=30)
        return orig(parts)

    store._summarize_batch = gated
    return gate


def test_ingest_many_routes_through_the_async_queue():
    """With async_ingest=True, ingest_many must enqueue (nothing visible
    until flush) instead of silently applying synchronously."""
    store, parts = _store(days=6, async_ingest=True)
    gate = _gate_worker(store)
    store.ingest_many(parts)
    # not applied in-line: visibility only comes with flush()
    assert store.ids() == []
    gate.set()
    store.flush()
    assert store.ids() == sorted(parts)
    h, _ = store.query(0, 5, BETA)
    assert float(np.asarray(h.sizes).sum()) == 6 * N_PER
    store.close()


def test_ingest_many_async_preserves_fifo_with_ingest_async():
    """Interleaved ingest_async + ingest_many enqueue in caller order, so
    no snapshot can show ingest_many's partitions while an earlier
    enqueued partition is invisible (the non-prefix view the old
    sync-apply fast path produced)."""
    rng = np.random.default_rng(3)
    store = HistogramStore(num_buckets=T, async_ingest=True)
    gate = _gate_worker(store)
    store.ingest_async(0, rng.normal(size=N_PER).astype(np.float32))
    store.ingest_many(
        {1: rng.normal(size=N_PER).astype(np.float32),
         2: rng.normal(size=N_PER).astype(np.float32)}
    )
    store.ingest_async(3, rng.normal(size=N_PER).astype(np.float32))
    assert store.ids() == []  # in particular: 1, 2 are NOT visible early
    gate.set()
    store.flush()
    assert store.ids() == [0, 1, 2, 3]
    store.close()


def test_ingest_many_async_validates_all_before_enqueueing_any():
    """Validation is synchronous AND all-or-nothing: a bad partition
    mid-dict must not leave its valid neighbours half-enqueued (the
    sync path applies nothing on failure; async must match)."""
    store = HistogramStore(num_buckets=T, async_ingest=True)
    rng = np.random.default_rng(4)
    with pytest.raises(ValueError):
        store.ingest_many(
            {
                0: rng.normal(size=50).astype(np.float32),
                1: np.asarray([], np.float32),
            }
        )
    store.flush()
    assert store.ids() == []  # pid 0 was not enqueued either
    store.close()
