"""Concurrent cross-tenant ``query_many`` vs retention/budget eviction.

The registry packs its merge stack OUTSIDE the per-store locks (that is
the point — one gather serves every tenant), so an eviction sweep can land
*mid-pack*: between a store's node selection and the merge dispatch.  The
snapshot contract says each answer must reflect a consistent whole-batch
state of its tenant — never a torn mix, and never a freed-and-reused arena
row (the arena's write-once rows + handle-lifetime reclamation are what
guarantee the latter; see core/arena.py).

The pin, in the style of test_store_bugfixes' error races: every partition
carries a distinct known mass and all mutations (atomic evict-oldest /
re-ingest batches, plus registry budget sweeps which also evict
oldest-first) move each tenant through *suffix* states only — so the total
mass of any legal snapshot lives in a small precomputed set.  A torn pack
or a recycled row would produce an off-set mass.  Run against both the
shared-arena gather path and the per-tenant host-pack path.
"""
import threading

import numpy as np
import pytest

from repro.core import TenantRegistry

T = 16
W = 10
BETA = 8
TENANTS = [f"svc{i}" for i in range(4)]


def _masses(parts):
    """All legal snapshot masses of one tenant: suffix states only."""
    ids = sorted(parts)
    sizes = [parts[p].size for p in ids]
    return {float(sum(sizes[j:])) for j in range(len(ids))}


@pytest.mark.parametrize("shared", [True, False])
def test_query_many_races_eviction_and_budget_sweeps(shared):
    rng = np.random.default_rng(11)
    parts = {
        name: {
            pid: rng.normal(size=150 + 17 * pid).astype(np.float32)
            for pid in range(W)
        }
        for name in TENANTS
    }
    full_floats = None
    reg = TenantRegistry(num_buckets=T, shared_arena=shared)
    for name in TENANTS:
        reg.ingest_many(name, parts[name])
    full_floats = sum(reg.node_floats().values())
    reg.budget = int(full_floats * 0.9)  # sweeps occasionally bite
    legal = {name: _masses(parts[name]) for name in TENANTS}
    queries = [(name, 0, W - 1) for name in TENANTS]

    errors: list[BaseException] = []
    observed: list[tuple[str, float]] = []
    stop = threading.Event()

    def querier():
        try:
            local = []
            while not stop.is_set():
                for (name, _, _), (h, eps) in zip(
                    queries, reg.query_many(queries, BETA, strict=False)
                ):
                    assert h is not None  # newest is never evicted
                    local.append(
                        (name, float(np.asarray(h.sizes, np.float64).sum()))
                    )
            observed.extend(local)
        except BaseException as e:  # surfaces in the main thread
            errors.append(e)
            stop.set()

    def mutator():
        try:
            mrng = np.random.default_rng(12)
            for _ in range(60):
                name = TENANTS[int(mrng.integers(0, len(TENANTS)))]
                store = reg[name]
                ids = store.ids()
                if len(ids) > 1:
                    k = int(mrng.integers(1, len(ids)))
                    store.evict(ids[:k])  # oldest prefix, atomic
                # restore to the full window (atomic batch, may re-grow
                # below base → rebuild, maximum slot-reuse pressure)
                missing = {
                    pid: parts[name][pid]
                    for pid in range(W)
                    if pid not in store.summaries
                }
                if missing:
                    reg.ingest_many(name, missing)
                if mrng.integers(0, 3) == 0:
                    reg.enforce_budget()  # eviction mid-pack, cross-tenant
        except BaseException as e:
            errors.append(e)
        finally:
            stop.set()

    threads = [threading.Thread(target=querier) for _ in range(2)]
    threads.append(threading.Thread(target=mutator))
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors
    assert observed, "queriers never completed a batch"
    for name, mass in observed:
        gap = min(abs(mass - m) for m in legal[name])
        assert gap < 0.5, (
            f"{name}: observed mass {mass} matches no legal snapshot "
            f"(torn pack or recycled arena row)"
        )

    # quiesced: restore every tenant and compare against a fresh registry —
    # canonical collapse + base-shift rebuilds make this bit-exact
    reg.budget = None  # stop the sweeper from re-evicting the restores
    for name in TENANTS:
        missing = {
            pid: parts[name][pid]
            for pid in range(W)
            if pid not in reg[name].summaries
        }
        if missing:
            reg.ingest_many(name, missing)
    fresh = TenantRegistry(num_buckets=T, shared_arena=shared)
    for name in TENANTS:
        fresh.ingest_many(name, parts[name])
    for (h0, e0), (h1, e1) in zip(
        reg.query_many(queries, BETA), fresh.query_many(queries, BETA)
    ):
        np.testing.assert_array_equal(
            np.asarray(h0.sizes), np.asarray(h1.sizes)
        )
        assert e0 == e1
    reg.close()
    fresh.close()
