"""Tests for the static-analysis toolkit (src/repro/analysis).

Two halves, per the analyzer's own acceptance bar:

1. **Seeded violations** — each fixture module under
   ``tests/analysis_fixtures/`` plants exactly the violations its name
   says, and each rule fires exactly that often (a rule that silently
   stops firing is worse than no rule).
2. **No false positives** — the clean exemplar (every discipline done
   right) and the real, post-fix repo produce zero findings outside the
   ratcheted baseline; the CI gate invocation itself exits 0.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.analysis.findings import (
    Finding,
    apply_baseline,
    load_baseline,
    save_baseline,
)
from repro.analysis.lint import SourceFile, run_failpoint_rule, run_lint
from repro.analysis.lockgraph import run_lockgraph

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join(REPO, "tests", "analysis_fixtures")


def fixture(name: str, label: str, is_test: bool = False) -> SourceFile:
    """Parse a fixture under a chosen path label (rules key off paths —
    durability basenames, tests/ — so the label, not the real location,
    decides which rules apply)."""
    with open(os.path.join(FIXTURES, name)) as f:
        return SourceFile.parse(label, f.read(), is_test=is_test)


def rule_counts(findings):
    out = {}
    for f in findings:
        out[f.rule] = out.get(f.rule, 0) + 1
    return out


# ------------------------------------------------------- seeded violations


def test_resource_leak_fires_exactly_once():
    sf = fixture("leak_violation.py", "src/fake/loader.py")
    counts = rule_counts(run_lint([sf]))
    assert counts == {"resource-leak": 1}


def test_fsync_order_fires_for_both_halves_of_the_contract():
    sf = fixture("fsync_violation.py", "src/fake/publish.py")
    found = [f for f in run_lint([sf]) if f.rule == "fsync-order"]
    assert sorted(f.token for f in found) == [
        "replace#0:dir-fsync",
        "replace#0:pre-fsync",
    ]


def test_cv_wait_fires_exactly_once():
    sf = fixture("cv_wait_violation.py", "src/fake/drainer.py")
    counts = rule_counts(run_lint([sf]))
    assert counts == {"cv-wait": 1}


def test_thread_daemon_fires_exactly_once():
    sf = fixture("thread_violation.py", "src/fake/spawn.py")
    counts = rule_counts(run_lint([sf]))
    assert counts == {"thread-daemon": 1}


def test_thread_daemon_skips_tests():
    sf = fixture("thread_violation.py", "tests/test_fake.py", is_test=True)
    assert rule_counts(run_lint([sf])) == {}


def test_test_sleep_fires_exactly_once_and_only_in_tests():
    as_test = fixture("sleep_violation.py", "tests/test_fake.py",
                      is_test=True)
    assert rule_counts(run_lint([as_test])) == {"test-sleep": 1}
    as_src = fixture("sleep_violation.py", "src/fake/poller.py")
    assert rule_counts(run_lint([as_src])) == {}


def test_except_rules_fire_once_each_in_durability_modules():
    sf = fixture("except_violation.py", "src/fake/workers.py")
    counts = rule_counts(run_lint([sf]))
    assert counts == {"bare-except": 1, "swallowed-oserror": 1}
    # outside a durability basename only the bare except remains
    sf2 = fixture("except_violation.py", "src/fake/util.py")
    assert rule_counts(run_lint([sf2])) == {"bare-except": 1}


def test_lock_cycle_fixture_fires_inversion_and_cycle_once_each():
    sf = fixture("lock_cycle_violation.py", "src/fake/locks.py")
    counts = rule_counts(run_lockgraph([sf]))
    assert counts == {"lock-order": 1, "lock-cycle": 1}
    inversion = [f for f in run_lockgraph([sf]) if f.rule == "lock-order"][0]
    assert inversion.token == "store._lock->registry._lock"
    assert inversion.scope == "backward"


# ---------------------------------------------------------- no false positives


def test_clean_exemplar_is_clean_under_every_rule():
    # run it under the strictest labels: a durability basename AND again
    # as a test file — zero findings both ways
    as_src = fixture("clean_exemplar.py", "src/fake/stream.py")
    assert run_lint([as_src]) == []
    assert run_lockgraph([as_src]) == []
    as_test = fixture("clean_exemplar.py", "tests/test_fake.py",
                      is_test=True)
    assert run_lint([as_test]) == []


def test_repo_core_lock_graph_is_clean():
    files = []
    core = os.path.join(REPO, "src", "repro", "core")
    for name in sorted(os.listdir(core)):
        if name.endswith(".py"):
            with open(os.path.join(core, name)) as f:
                files.append(
                    SourceFile.parse(f"src/repro/core/{name}", f.read())
                )
    assert run_lockgraph(files) == []


# ------------------------------------------------------------ failpoint rule


def _mk(path, source, is_test=False):
    return SourceFile.parse(path, source, is_test=is_test)


def test_failpoint_rule_undeclared_unused_untested():
    registry = _mk(
        "src/fake/faults.py",
        "SITES = frozenset({'wal.append', 'pool.batch', 'arena.alloc'})\n",
    )
    src = _mk(
        "src/fake/workers.py",
        "from repro.core import faults\n"
        "def f():\n"
        "    faults.hit('wal.append')\n"
        "    faults.hit('wal.apend')\n",  # typo → undeclared
    )
    test = _mk(
        "tests/test_fake.py",
        "from repro.core import faults\n"
        "def test_f():\n"
        "    with faults.inject('wal.append', exc=RuntimeError()):\n"
        "        pass\n",
        is_test=True,
    )
    counts = rule_counts(run_failpoint_rule([registry, src, test]))
    # wal.apend → undeclared; pool.batch + arena.alloc → unused
    assert counts == {"failpoint-undeclared": 1, "failpoint-unused": 2}


def test_failpoint_rule_untested_site():
    registry = _mk("src/fake/faults.py", "SITES = frozenset({'a.b'})\n")
    src = _mk(
        "src/fake/m.py",
        "from repro.core import faults\nfaults.hit('a.b')\n",
    )
    counts = rule_counts(run_failpoint_rule([registry, src]))
    assert counts == {"failpoint-untested": 1}


def test_failpoint_rule_declared_twice():
    registry = _mk(
        "src/fake/faults.py",
        "SITES = frozenset({'a.b'})\nSITES = frozenset({'a.b'})\n",
    )
    src = _mk("src/fake/m.py",
              "from repro.core import faults\nfaults.hit('a.b')\n")
    test = _mk("tests/test_fake.py", "x = 'a.b'\n", is_test=True)
    counts = rule_counts(run_failpoint_rule([registry, src, test]))
    assert counts == {"failpoint-declared-once": 1}


def test_repo_failpoint_sites_all_declared_used_and_tested():
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "analyze.py"),
         "src", "tests", "benchmarks"],
        cwd=REPO, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert "failpoint" not in out.stdout, out.stdout


# -------------------------------------------------------------- ratchet


def test_baseline_ratchet_suppresses_old_flags_new_reports_stale(tmp_path):
    old = Finding("r", "p.py", 3, "f", "m", token="x")
    new = Finding("r", "p.py", 9, "g", "m", token="y")
    path = str(tmp_path / "baseline.json")
    save_baseline(path, [old], {old.fingerprint: "known cleanup site"})
    baseline = load_baseline(path)

    res = apply_baseline([old, new], baseline)
    assert [f.fingerprint for f in res.new] == [new.fingerprint]
    assert [f.fingerprint for f in res.suppressed] == [old.fingerprint]
    assert res.stale == []

    res2 = apply_baseline([new], baseline)  # old finding got fixed
    assert res2.stale == [old.fingerprint]


def test_baseline_requires_justifications(tmp_path):
    path = str(tmp_path / "baseline.json")
    with open(path, "w") as f:
        json.dump(
            {
                "schema": "analysis_baseline/v1",
                "findings": [{"fingerprint": "r|p|f|x"}],
            },
            f,
        )
    with pytest.raises(ValueError, match="justification"):
        load_baseline(path)


def test_fingerprints_are_line_number_independent():
    a = Finding("r", "p.py", 10, "f", "m", token="x")
    b = Finding("r", "p.py", 99, "f", "m", token="x")
    assert a.fingerprint == b.fingerprint


# --------------------------------------------------------------- CI gate


def test_cli_gate_exits_zero_on_the_repo():
    """The acceptance criterion: the exact CI invocation is clean."""
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "analyze.py"),
         "src", "tests", "benchmarks",
         "--baseline", "analysis_baseline.json"],
        cwd=REPO, capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "0 new findings" in out.stdout


def test_cli_gate_fails_on_seeded_violation(tmp_path):
    bad = tmp_path / "src" / "leaky.py"
    bad.parent.mkdir()
    bad.write_text("import numpy as np\n\ndef f(p):\n    return np.load(p)\n")
    out = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "analyze.py"),
         str(bad)],
        cwd=str(tmp_path), capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")},
    )
    assert out.returncode == 1
    assert "resource-leak" in out.stdout
