"""Per-arch smoke tests + decode-vs-prefill consistency (cache correctness)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_archs, shape_applicable, smoke
from repro.models import decode_step, init_cache, init_model, loss_fn, prefill

pytestmark = pytest.mark.slow  # multi-minute lane; fast lane: -m "not slow"

ARCHS = list_archs()


def make_batch(cfg, B, S, key):
    s_text = S - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    batch = {
        "tokens": jax.random.randint(key, (B, s_text), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "mask": jnp.ones((B, S), jnp.float32),
    }
    if cfg.frontend == "vision":
        batch["patch_embeds"] = jax.random.normal(
            key, (B, cfg.frontend_tokens, cfg.d_model), jnp.float32
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, specs = init_model(cfg, key)
    batch = make_batch(cfg, 2, 32, key)
    loss, metrics = jax.jit(lambda p, b: loss_fn(cfg, p, b))(params, batch)
    assert np.isfinite(float(loss))
    # output shape checks: hidden through unembed happens in loss; do grads
    grads = jax.jit(jax.grad(lambda p, b: loss_fn(cfg, p, b)[0]))(params, batch)
    for g in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(g, dtype=np.float32)))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    """prefill(x[:S]) + decode(x[S]) == prefill(x[:S+1]) — cache correctness.

    Validates KV caches, SSM states, RWKV shift/state carries and local
    window masks across the prefill/decode boundary.
    """
    import dataclasses

    cfg = smoke(get_config(arch))
    if cfg.num_experts:
        # capacity-based MoE legitimately drops different tokens when the
        # routing group changes (prefill groups over seq, decode over batch);
        # the *cache* consistency contract is tested dropless.
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    key = jax.random.PRNGKey(1)
    params, _ = init_model(cfg, key)
    B, S = 2, 16
    batch_full = make_batch(cfg, B, S + 1, key)
    # path A: prefill on S+1 tokens
    cache_a, _ = init_cache(cfg, B, S + 8, dtype=jnp.float32)
    logits_a, _ = jax.jit(lambda p, b, c: prefill(cfg, p, b, c))(
        params, batch_full, cache_a
    )
    # path B: prefill on S tokens, then one decode step with token S
    batch_prefix = dict(batch_full)
    batch_prefix["tokens"] = batch_full["tokens"][:, :-1]
    cache_b, _ = init_cache(cfg, B, S + 8, dtype=jnp.float32)
    _, cache_b = jax.jit(lambda p, b, c: prefill(cfg, p, b, c))(
        params, batch_prefix, cache_b
    )
    pos = S  # make_batch folds frontend tokens into S: stream length == S
    logits_b, _ = jax.jit(
        lambda p, c, t, q: decode_step(cfg, p, c, t, q)
    )(params, cache_b, batch_full["tokens"][:, -1:], jnp.int32(pos))
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), rtol=2e-3, atol=2e-3
    )


def test_shape_applicability_rules():
    long = SHAPES["long_500k"]
    runs = {a for a in ARCHS if shape_applicable(get_config(a), long)[0]}
    assert runs == {"jamba-v0.1-52b", "rwkv6-7b", "gemma2-9b"}
    for a in ARCHS:  # every other shape applies everywhere
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(get_config(a), SHAPES[s])[0]


def test_local_window_masks_differ_from_global():
    cfg = smoke(get_config("gemma2-9b"))
    key = jax.random.PRNGKey(2)
    params, _ = init_model(cfg, key)
    B, S = 1, 64  # longer than smoke sliding window (32)
    batch = make_batch(cfg, B, S, key)
    from repro.models.model import forward_hidden

    h, _ = jax.jit(lambda p, b: forward_hidden(cfg, p, b))(params, batch)
    assert np.all(np.isfinite(np.asarray(h, dtype=np.float32)))


def test_moe_dropless_at_high_capacity():
    import dataclasses
    from repro.models.moe import apply_moe, init_moe
    from repro.models.common import Init

    cfg = dataclasses.replace(
        smoke(get_config("dbrx-132b")), moe_capacity_factor=8.0
    )
    p, _ = init_moe(cfg, Init(jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    y, aux = apply_moe(cfg, p, x)
    assert float(aux["moe_drop_fraction"]) == 0.0
    assert y.shape == x.shape


def test_moe_decode_fold_matches_train_routing():
    """Decode (S=1, B>1) folds batch→groups; outputs stay finite & shaped."""
    from repro.models.moe import apply_moe, init_moe
    from repro.models.common import Init

    cfg = smoke(get_config("llama4-maverick-400b-a17b"))
    p, _ = init_moe(cfg, Init(jax.random.PRNGKey(0)))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model))
    y, aux = apply_moe(cfg, p, x)
    assert y.shape == (8, 1, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(y)))
