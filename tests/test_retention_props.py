"""Property tests for the retention subsystem (tests/_propcheck.py harness).

Two guarantees over random interleavings of ``ingest`` / ``evict`` /
``query`` (prefix-, interior-, and suffix-shaped evictions, gappy
monotone partition ids, both uniform and geometric ``T_node``):

* **bit-exactness vs a flat rebuild of only the retained partitions** —
  after any interleaving, the store's tree is structurally identical
  (base, depth, node keys) to a fresh store fed exactly the retained raw
  partitions, and every ``query``/``query_many`` answer (histogram AND
  reported ``eps_total``) is bit-identical to the rebuilt store's.  This
  holds because ``evict_leaves``'s lazy collapse always re-roots at the
  lowest surviving leaf, and node summaries are a deterministic function
  of the slot→leaf map (padding invariance, interval_tree.py docstring).
* **the composed error bound survives collapse** — measured bucket error
  (reported sizes and true pooled-value occupancy) stays within the
  reported ``eps_total`` after any amount of eviction and re-rooting.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import HistogramStore

settings.register_profile("ci", deadline=None, max_examples=12)
settings.load_profile("ci")

T = 16
BETA = 8


@st.composite
def interleaving_case(draw):
    geometric = draw(st.booleans())
    seed = draw(st.integers(0, 2**31 - 1))
    n_steps = draw(st.integers(4, 14))
    rng = np.random.default_rng(seed)
    store = HistogramStore(
        num_buckets=T, T_node="geometric" if geometric else None
    )
    raw: dict[int, np.ndarray] = {}
    next_pid = 0
    for _ in range(n_steps):
        op = int(rng.integers(0, 4))
        if op <= 1 or not raw:  # ingest a small burst (gappy monotone ids)
            parts = {}
            for _ in range(int(rng.integers(1, 4))):
                next_pid += int(rng.integers(1, 3))
                n = T * int(rng.integers(1, 5))
                parts[next_pid] = rng.normal(size=n).astype(np.float32)
            raw.update(parts)
            store.ingest_many(parts)
        elif op == 2:  # evict: prefix-biased (the policy shape) + interior
            ids = sorted(raw)
            k = int(rng.integers(1, len(ids) + 1))
            if rng.random() < 0.6:
                victims = ids[:k]
            else:
                victims = [
                    ids[i]
                    for i in rng.choice(len(ids), size=k, replace=False)
                ]
            assert store.evict(victims) == sorted(victims)
            for p in victims:
                raw.pop(p)
        else:  # query mid-interleaving: exercises + populates the LRU
            ids = sorted(raw)
            lo, hi = sorted(
                (int(rng.choice(ids)), int(rng.choice(ids)))
            )
            store.query(lo, hi, BETA, strict=False)
    return store, raw, geometric, seed


def _windows(raw, seed):
    ids = sorted(raw)
    rng = np.random.default_rng(seed + 1)
    out = [(ids[0], ids[-1]), (ids[0], ids[0]), (ids[-1], ids[-1])]
    for _ in range(3):
        lo, hi = sorted((int(rng.choice(ids)), int(rng.choice(ids))))
        out.append((lo, hi))
    return out


@given(interleaving_case())
def test_interleaved_evictions_bitexact_vs_flat_rebuild(case):
    store, raw, geometric, seed = case
    if not raw:  # everything evicted: the store must say so, not guess
        with pytest.raises(KeyError):
            store.query(0, 10**6, BETA, strict=False)
        assert store._tree.base is None
        return
    fresh = HistogramStore(
        num_buckets=T, T_node="geometric" if geometric else None
    )
    fresh.ingest_many(dict(raw))
    # the tree IS the flat rebuild of the retained window, structurally
    assert store._tree.base == fresh._tree.base
    assert store._tree.levels == fresh._tree.levels
    assert store._tree.nodes.keys() == fresh._tree.nodes.keys()
    windows = _windows(raw, seed)
    batched = store.query_many(windows, BETA, strict=False)
    for (lo, hi), (hb, eb) in zip(windows, batched):
        h1, e1 = store.query(lo, hi, BETA, strict=False)
        h2, e2 = fresh.query(lo, hi, BETA, strict=False)
        np.testing.assert_array_equal(
            np.asarray(h1.boundaries), np.asarray(h2.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(h1.sizes), np.asarray(h2.sizes)
        )
        assert e1 == e2  # eviction-aware eps ≡ rebuilt tree's eps
        np.testing.assert_array_equal(
            np.asarray(hb.sizes), np.asarray(h2.sizes)
        )
        assert eb == e2


@given(interleaving_case())
def test_measured_error_within_reported_eps_after_collapse(case):
    store, raw, geometric, seed = case
    if not raw:
        return
    for lo, hi in _windows(raw, seed):
        h, eps = store.query(lo, hi, BETA, strict=False)
        pids = [p for p in sorted(raw) if lo <= p <= hi]
        pooled = np.sort(np.concatenate([raw[p] for p in pids]))
        n = pooled.size
        sizes = np.asarray(h.sizes, np.float64)
        assert float(sizes.sum()) == pytest.approx(n, abs=0.5)
        # Theorem 1 on the reported sizes
        assert np.abs(sizes - n / BETA).max() <= eps + 1e-3
        # Theorem 1 on the TRUE occupancy of the answer's buckets
        # (normal draws: no ties, so true counts are unambiguous)
        b = np.asarray(h.boundaries, np.float64)
        lo_i = np.searchsorted(pooled, b[:-1], side="left")
        hi_i = np.searchsorted(pooled, b[1:], side="left")
        true_sizes = (hi_i - lo_i).astype(np.float64)
        true_sizes[-1] += np.sum(pooled == b[-1])  # last bucket right-closed
        assert np.abs(true_sizes - n / BETA).max() <= eps + 1e-3
