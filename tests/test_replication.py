"""Hot-standby replication (core/replication.py): WAL shipping over both
in-tree transports, bounded-staleness replica reads, epoch fencing and
zero-loss promote — plus the tail-reader vs ``truncate()`` race contract
(deterministic interleavings, no sleeps).
"""
import json
import os
import socket
import struct

import numpy as np
import pytest

from repro.core import faults
from repro.core.replication import (
    DirTransport,
    Follower,
    Replicator,
    StreamReceiver,
    StreamTransport,
    manifest_path,
)
from repro.core.resilience import (
    BreakerPolicy,
    IngestBackpressure,
    NotPrimary,
    PrimaryFenced,
    RetryPolicy,
)
from repro.core.scrub import scrub_divergence
from repro.core.tenant import TenantRegistry
from repro.core.workers import WriteAheadLog, read_segment_epoch
from repro.serve import HistogramService


def _vals(rng, n=96):
    return rng.normal(size=n).astype(np.float32)


def _primary(tmp_path, name="pwal", **kw):
    return TenantRegistry(num_buckets=8, wal_dir=str(tmp_path / name), **kw)


def _bitmatch(a, b, queries, beta=16):
    """Assert two registries answer ``queries`` identically, bit for bit."""
    ra = a.query_many(queries, beta, strict=False)
    rb = b.query_many(queries, beta, strict=False)
    for (ha, ea), (hb, eb) in zip(ra, rb):
        assert ea == eb
        assert (ha is None) == (hb is None)
        if ha is not None:
            np.testing.assert_array_equal(
                np.asarray(ha.boundaries), np.asarray(hb.boundaries)
            )
            np.testing.assert_array_equal(
                np.asarray(ha.sizes), np.asarray(hb.sizes)
            )


# --------------------------------------------------------------- transports
def test_dir_ship_tail_bitmatch(tmp_path):
    reg = _primary(tmp_path)
    standby = str(tmp_path / "standby")
    repl = Replicator(reg._wal, [DirTransport(standby)]).attach(reg)
    rng = np.random.default_rng(0)
    for pid in range(4):
        reg.ingest("t", pid, _vals(rng))  # sync path ships per ingest
    f = Follower(standby, num_buckets=8)
    assert f.tail() == 4
    _bitmatch(reg, f.registry, [("t", 0, 7)])
    lag = f.lag()
    assert lag["known"] and lag["records"] == 0 and lag["mass"] == 0
    st = repl.stats()
    assert st["shipped_lsn"] == 4 and st["ship_failures"] == 0
    f.close()
    reg.close()


def test_stream_ship_tail_bitmatch_and_fence(tmp_path):
    standby = str(tmp_path / "standby")
    a, b = socket.socketpair()
    recv = StreamReceiver(b, standby)
    reg = _primary(tmp_path)
    Replicator(reg._wal, [StreamTransport(a)]).attach(reg)
    rng = np.random.default_rng(1)
    reg.ingest("t", 0, _vals(rng))
    reg.ingest_async("t", 1, _vals(rng))  # async path ships via on_durable
    reg.flush()
    f = Follower(standby, num_buckets=8)
    assert f.tail() == 2
    _bitmatch(reg, f.registry, [("t", 0, 3)])
    # a promoted follower directory rejects the deposed primary's frames
    # at the receiver; the rejection surfaces at the *sender* as
    # PrimaryFenced, which fails the ingest ack
    with open(os.path.join(standby, "epoch.json"), "w") as fh:
        json.dump({"epoch": 7}, fh)
    with pytest.raises(PrimaryFenced):
        reg.ingest("t", 2, _vals(rng))
    assert recv.rejected >= 1
    recv.close()
    f.close()
    reg.close()


def test_frame_is_idempotent_and_torn_tail_refused(tmp_path):
    """A half-shipped record is refused by the follower's scan until the
    re-ship overwrites it — the byte-frame "content from offset is
    exactly this" contract converges instead of corrupting."""
    reg = _primary(tmp_path)
    standby = str(tmp_path / "standby")
    tr = DirTransport(standby)
    repl = Replicator(reg._wal, [tr]).attach(reg)
    rng = np.random.default_rng(2)
    reg.ingest("t", 0, _vals(rng))
    f = Follower(standby, num_buckets=8)
    assert f.tail() == 1
    # ship only half of the next record's bytes by hand
    reg._replication = None  # detach auto-ship for the manual frame
    reg._pool.on_durable = None
    reg.ingest("t", 1, _vals(rng))
    view = reg._wal.segment_view()[-1]
    shipped = repl._offsets[view["path"]]
    whole = reg._wal.read_active(shipped)[1]
    tr.send(view["path"], shipped, whole[: len(whole) // 2], epoch=0)
    assert f.tail() == 0  # torn tail: nothing consumed, nothing applied
    assert repl.ship() == len(whole)  # re-ship from the tracked offset
    assert f.tail() == 1  # the full frame overwrote the torn bytes
    _bitmatch(reg, f.registry, [("t", 0, 3)])
    f.close()
    reg.close()


def test_ship_is_incremental(tmp_path):
    reg = _primary(tmp_path)
    standby = str(tmp_path / "standby")
    repl = Replicator(reg._wal, [DirTransport(standby)]).attach(reg)
    rng = np.random.default_rng(3)
    reg.ingest("t", 0, _vals(rng))
    shipped = repl.bytes_shipped
    assert repl.ship() == 0  # nothing new: no bytes move
    assert repl.bytes_shipped == shipped
    reg.ingest("t", 1, _vals(rng))
    assert repl.bytes_shipped > shipped
    reg.close()


# ---------------------------------------- tail reader vs truncate() (race)
def test_read_segment_rotated_away_is_clean_none(tmp_path):
    """The deterministic interleaving of the historical race: a tail
    reader lists a closed segment, ``truncate()`` deletes it, the read
    lands after.  The reader gets the clean ``None`` signal — not a
    raw FileNotFoundError — and the shipper drops tracking."""
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_bytes=256)
    rng = np.random.default_rng(4)
    lsns = [wal.append("t", pid, _vals(rng)) for pid in range(6)]
    wal.commit()
    view = wal.segment_view()
    assert len(view) > 2, "segments must have rotated for this test"
    victim = view[0]["path"]
    # interleave: reader holds the view; truncation deletes the segment
    wal.mark_applied(lsns)
    assert victim in wal.truncate()
    assert wal.read_segment(victim, 0, 16) is None  # clean signal
    # a shipper holding stale tracking converges without error
    standby = str(tmp_path / "standby")
    repl = Replicator(wal, [DirTransport(standby)])
    repl._offsets[victim] = 7
    repl.ship()
    assert victim not in repl._offsets
    f = Follower(standby, num_buckets=8)
    f.tail()
    # the follower holds whatever survived truncation (the horizon
    # segment onward) — never a torn or misparsed suffix
    assert f.stats()["apply_failures"] == 0
    f.close()
    wal.close()


def test_vanished_tracked_segment_is_an_anomaly_not_masked(tmp_path):
    """Out-of-band deletion (not our truncate) must surface: the read
    raises and ``segment_view`` counts the vanished segment."""
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_bytes=256)
    rng = np.random.default_rng(5)
    for pid in range(6):
        wal.append("t", pid, _vals(rng))
    wal.commit()
    victim = wal.segment_view()[0]
    assert not victim["active"]
    os.remove(victim["path"])
    with pytest.raises(FileNotFoundError):
        wal.read_segment(victim["path"], 0, 16)
    before = len(wal.segment_view())
    assert wal.stats()["vanished_segments"] >= 1
    assert before == len(wal.segment_view())  # stable, just skipped
    wal.close()


def test_rewind_frame_shrinks_follower_copy(tmp_path):
    """``size < offset`` (append rollback rewound the active segment):
    the shipper sends an empty frame at the true boundary and the
    follower adopts the shorter length — both without consuming past a
    record boundary."""
    reg = _primary(tmp_path)
    standby = str(tmp_path / "standby")
    repl = Replicator(reg._wal, [DirTransport(standby)]).attach(reg)
    rng = np.random.default_rng(6)
    reg.ingest("t", 0, _vals(rng))
    f = Follower(standby, num_buckets=8)
    assert f.tail() == 1
    view = reg._wal.segment_view()[-1]
    true_off = repl._offsets[view["path"]]
    # poison the shipper's offset as if bytes beyond the boundary had
    # shipped and then been rolled back on the primary
    repl._offsets[view["path"]] = true_off + 64
    name = os.path.basename(view["path"])
    with open(os.path.join(standby, name), "ab") as fh:
        fh.write(b"\x00" * 64)  # the disowned bytes on the follower
    f._offsets[name] = f._offsets.get(name, 0)  # follower state unchanged
    repl.ship()
    assert repl._offsets[view["path"]] == true_off
    assert os.path.getsize(os.path.join(standby, name)) == true_off
    reg.ingest("t", 1, _vals(rng))
    assert f.tail() == 1  # tailing resumes cleanly at the boundary
    _bitmatch(reg, f.registry, [("t", 0, 3)])
    f.close()
    reg.close()


def test_ship_rotation_race_ships_closed_tail_same_round(tmp_path):
    """Deterministic interleaving of the ack-path race: the active
    segment rotates between ``segment_view()`` and ``read_active()``.
    The old segment is closed-and-immutable at that point, so its
    unshipped tail must ship in the SAME round — ship() returning (and
    the manifest/shipped_lsn it publishes) is what lets the ingest ack
    out, and zero acked loss forbids an ack the followers lack bytes
    for."""
    wal = WriteAheadLog(str(tmp_path / "wal"))
    rng = np.random.default_rng(20)
    for pid in range(3):
        wal.append("t", pid, _vals(rng))
    wal.commit()
    standby = str(tmp_path / "standby")
    repl = Replicator(wal, [DirTransport(standby)])
    real = wal.read_active

    def rotated(off):
        got = real(off)
        # simulate: by the time the shipper reads, a new segment is active
        return None if got is None else (got[0] + ".next", b"", 0)

    wal.read_active = rotated
    assert repl.ship() > 0  # the closed tail moved this round
    del wal.read_active
    assert repl.shipped_lsn == 3
    f = Follower(standby, num_buckets=8)
    assert f.tail() == 3  # every byte the manifest claims is present
    lag = f.lag()
    assert lag["known"] and lag["records"] == 0 and lag["mass"] == 0
    f.close()
    wal.close()


def test_receiver_fault_fails_sender_fast_instead_of_wedging(tmp_path):
    """A follower-side fault (malformed header / apply error) must not
    leave the primary blocked forever in its ack wait: the receiver
    shuts the stream down and the sender's submit fails fast."""
    a, b = socket.socketpair()
    recv = StreamReceiver(b, str(tmp_path / "standby"))
    tr = StreamTransport(a)
    a.settimeout(10.0)  # regression guard: error, never an infinite hang
    # a malformed header: the receiver's json parse raises ValueError
    a.sendall(struct.pack("<I", 8) + b"notjson!")
    with pytest.raises((ConnectionError, OSError)):
        tr.send("wal-x.log", 0, b"y", epoch=0)
    assert recv.faults >= 1
    recv.close()
    tr.close()


def test_fenced_skip_counter_quiet_on_idle_tails(tmp_path):
    """``fenced_segments_skipped`` counts fenced *bytes arriving*, not
    idle tail polls — it must not inflate unboundedly while nothing
    ships."""
    wal = WriteAheadLog(str(tmp_path / "wal"), epoch=2)
    rng = np.random.default_rng(21)
    wal.append("t", 0, _vals(rng))
    wal.commit()
    wal.close()
    f = Follower(str(tmp_path / "wal"), min_epoch=3, num_buckets=8)
    assert f.tail() == 0
    baseline = f.stats()["fenced_segments_skipped"]
    assert baseline == 1
    for _ in range(4):
        assert f.tail() == 0
    assert f.stats()["fenced_segments_skipped"] == baseline
    f.close()


def test_ship_failure_does_not_quarantine_tenant(tmp_path):
    """A replication transport outage is a cluster condition, not tenant
    poison: the sync ingest must fail (no ack) WITHOUT charging the
    tenant's circuit breaker — else a cluster-wide outage quarantines
    every healthy tenant."""

    class _Down:
        def send(self, *a, **k):
            raise OSError("replication down")

        def send_manifest(self, *a, **k):
            raise OSError("replication down")

        def close(self):
            pass

    reg = TenantRegistry(
        num_buckets=8,
        wal_dir=str(tmp_path / "wal"),
        breaker=BreakerPolicy(threshold=1, cooldown=1000.0),
    )
    repl = Replicator(reg._wal, [_Down()]).attach(reg)
    rng = np.random.default_rng(22)
    with pytest.raises(OSError):
        reg.ingest("t", 0, _vals(rng))  # ship failed: no ack
    assert repl.stats()["ship_failures"] == 1
    health = reg.health()
    assert health["quarantined"] == []
    assert health["breakers"]["t"]["state"] == "closed"
    # the tenant keeps serving once replication is detached/healed
    reg._replication = None
    reg._pool.on_durable = None
    reg.ingest("t", 1, _vals(rng))
    reg.close()


# --------------------------------------------- snapshot bootstrap (standby)
def test_wal_mass_survives_truncate_and_reopen(tmp_path):
    """Truncation removes record bytes but never their mass: the shed
    ledger (mass.json) keeps ``mass_by_tenant`` cumulative across a
    reopen, so ship manifests can never silently exclude the
    checkpoint-covered prefix."""
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_bytes=256)
    rng = np.random.default_rng(23)
    lsns = [wal.append("t", pid, _vals(rng)) for pid in range(6)]
    wal.commit()
    wal.mark_applied(lsns)
    total = wal.mass_by_tenant()["t"]
    assert wal.truncate(), "segments must actually be deleted"
    assert wal.mass_by_tenant()["t"] == total
    shed = wal.shed_mass_by_tenant()["t"]
    assert shed > 0
    wal.close()
    wal2 = WriteAheadLog(str(tmp_path / "wal"))
    assert wal2.mass_by_tenant()["t"] == total
    assert wal2.shed_mass_by_tenant()["t"] == shed
    wal2.close()


def test_standby_bootstrap_after_checkpoint(tmp_path):
    """A primary restarted with ``replicate_to`` *after* a checkpoint
    ships only the WAL suffix as bytes — the snapshot bootstrap must
    carry the truncated prefix, so the replica's answers are complete
    and non-degraded, and failover (plus a restart of the promoted
    service) still loses nothing."""
    pdir, sdir = str(tmp_path / "primary"), str(tmp_path / "standby")
    svc = HistogramService(pdir, num_buckets=8)
    svc.registry._wal.segment_bytes = 256  # rotate per record
    rng = np.random.default_rng(24)
    acked = {}
    for pid in range(4):
        v = _vals(rng)
        svc.record("m", pid, v)
        acked[pid] = v
    svc.checkpoint()  # truncates the covered segments out of the WAL
    assert svc.registry._wal.shed_mass_by_tenant(), "history must be shed"
    svc.close()
    svc = HistogramService(pdir, num_buckets=8, replicate_to=(sdir,))
    v = _vals(rng)
    svc.record("m", 4, v)
    acked[4] = v
    rep = HistogramService(sdir, role="replica", num_buckets=8)
    rep.sync()
    [ans] = rep.query_many([("m", 0, 7)], 16)
    assert not ans.degraded  # provably complete — not silently partial
    oracle = TenantRegistry(num_buckets=8)
    for pid, val in acked.items():
        oracle.ingest("m", pid, val)
    _bitmatch(oracle, rep.registry, [("m", 0, 7)])
    # failover: the promoted follower holds the full acked set, the
    # pre-checkpoint prefix included
    fence = svc.replicator.fence
    del svc
    rep.promote(fence=fence)
    _bitmatch(oracle, rep.registry, [("m", 0, 7)])
    rep.close()
    # a restart of the promoted service recovers the full state too
    svc2 = HistogramService(sdir, num_buckets=8)
    _bitmatch(oracle, svc2.registry, [("m", 0, 7)])
    svc2.close()
    oracle.close()


def test_replicate_to_refused_when_history_unshippable(tmp_path):
    """Shed mass with no snapshot to bootstrap from: attaching a
    follower must refuse loudly instead of shipping a silently partial
    history."""
    pdir, sdir = str(tmp_path / "primary"), str(tmp_path / "standby")
    svc = HistogramService(pdir, num_buckets=8)
    svc.registry._wal.segment_bytes = 256
    rng = np.random.default_rng(25)
    for pid in range(4):
        svc.record("m", pid, _vals(rng))
    svc.checkpoint()
    svc.close()
    os.remove(os.path.join(pdir, "registry.npz"))
    with pytest.raises(ValueError, match="bootstrap"):
        HistogramService(pdir, num_buckets=8, replicate_to=(sdir,))


def test_stream_blob_delivery_is_atomic(tmp_path):
    a, b = socket.socketpair()
    standby = str(tmp_path / "standby")
    recv = StreamReceiver(b, standby)
    tr = StreamTransport(a)
    tr.send_blob("bootstrap.json", b'{"mass": {}}', epoch=0)
    with open(os.path.join(standby, "bootstrap.json"), "rb") as f:
        assert f.read() == b'{"mass": {}}'
    assert not os.path.exists(os.path.join(standby, "bootstrap.json.tmp"))
    recv.close()
    tr.close()


# ------------------------------------------------- backpressure (satellite)
def test_backpressure_carries_retry_after_and_health_row(tmp_path):
    reg = _primary(tmp_path)
    reg._pool.retry = RetryPolicy(attempts=1, base=0.05, cap=1.0, jitter=0.0)
    rng = np.random.default_rng(7)
    with faults.inject("wal.append", exc=OSError(28, "ENOSPC")):
        with pytest.raises(IngestBackpressure) as ei:
            reg.ingest_async("t", 0, _vals(rng))
    assert ei.value.retry_after == pytest.approx(0.05)
    row = reg.health()["backpressure"]
    assert row["reason"] == "append"
    assert row["retry_after"] == pytest.approx(0.05)
    assert row["at"] > 0
    # healed: the resubmit is accepted, the row keeps the last reject
    reg.ingest_async("t", 0, _vals(rng))
    reg.flush()
    assert reg.health()["backpressure"]["reason"] == "append"
    reg.close()


# ------------------------------------------------------------ epoch fencing
def test_fence_rejects_appends_and_survives_reopen(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    rng = np.random.default_rng(8)
    wal.append("t", 0, _vals(rng))
    wal.commit()
    wal.fence(3)
    with pytest.raises(PrimaryFenced):
        wal.append("t", 1, _vals(rng))
    wal.close()
    # the fence is persisted: a deposed primary stays fenced across its
    # own restart...
    wal2 = WriteAheadLog(str(tmp_path / "wal"))
    with pytest.raises(PrimaryFenced):
        wal2.append("t", 1, _vals(rng))
    wal2.close()
    # ...until it is reopened AT the fencing epoch (rejoin as a new
    # primary after a failback)
    wal3 = WriteAheadLog(str(tmp_path / "wal"), epoch=3)
    assert wal3.append("t", 1, _vals(rng)) > 0
    assert wal3.stats()["epoch"] == 3
    wal3.close()


def test_segments_carry_writer_epoch(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"), epoch=2)
    rng = np.random.default_rng(9)
    wal.append("t", 0, _vals(rng))
    wal.commit()
    path = wal.segment_view()[0]["path"]
    with open(path, "rb") as fh:
        epoch, hdr = read_segment_epoch(fh.read())
    assert epoch == 2 and hdr > 0
    wal.close()
    # a follower configured past that epoch refuses to apply the records
    f = Follower(str(tmp_path / "wal"), min_epoch=3, num_buckets=8)
    assert f.tail() == 0
    assert f.stats()["fenced_segments_skipped"] >= 1
    f.close()


def test_dir_transport_fenced_after_promote_fails_the_ack(tmp_path):
    reg = _primary(tmp_path)
    standby = str(tmp_path / "standby")
    Replicator(reg._wal, [DirTransport(standby)]).attach(reg)
    rng = np.random.default_rng(10)
    reg.ingest("t", 0, _vals(rng))
    f = Follower(standby, num_buckets=8)
    f.tail()
    f.promote()  # no fence callable: the deposed primary is unreachable
    # the directory's epoch.json now outranks the old primary: its next
    # ingest fails at the ship (sync path raises the fence directly)
    with pytest.raises(PrimaryFenced):
        reg.ingest("t", 1, _vals(rng))
    f.close()
    reg.close()


# ------------------------------------------------------- failover (service)
def test_service_promote_zero_loss_and_plane_reattach(tmp_path):
    pdir = str(tmp_path / "primary")
    sdir = str(tmp_path / "standby")
    svc = HistogramService(pdir, num_buckets=8, replicate_to=(sdir,))
    rng = np.random.default_rng(11)
    acked = {}
    for pid in range(5):
        v = _vals(rng)
        svc.record("m", pid, v)  # returned = acked = shipped
        acked[pid] = v
    rep = HistogramService(sdir, role="replica", num_buckets=8)
    with pytest.raises(NotPrimary):
        rep.record("m", 9, _vals(rng))
    sub = rep.subscribe("m", 0, 7, beta=16)
    rep.sync()
    # kill -9 the primary: no close/checkpoint, just stop talking to it
    fence = svc.replicator.fence
    del svc
    rep.promote(fence=fence)
    assert rep.role == "primary"
    # every acked record survived the failover
    oracle = TenantRegistry(num_buckets=8)
    for pid, v in acked.items():
        oracle.ingest("m", pid, v)
    _bitmatch(oracle, rep.registry, [("m", 0, 7)])
    # the promoted service ingests at the new epoch and the re-homed
    # subscription plane pushes from the promoted registry
    rep.record("m", 5, _vals(rng))
    rep.subscriptions.flush()
    ups = sub.drain()
    assert ups and ups[-1].version == rep.registry["m"].version
    assert rep.health()["role"] == "primary"
    assert rep.health()["replication"]["role"] == "primary"
    # restart from the promoted directory as a plain primary: recovery
    # replays the adopted log
    rep.close()
    oracle.close()
    svc2 = HistogramService(sdir, num_buckets=8)
    assert svc2.registry["m"].version > 0
    svc2.close()


# ------------------------------------------------ bounded-staleness reads
def test_replica_reads_widen_eps_and_flag_degraded(tmp_path):
    reg = _primary(tmp_path)
    standby = str(tmp_path / "standby")
    repl = Replicator(reg._wal, [DirTransport(standby)]).attach(reg)
    rng = np.random.default_rng(12)
    for pid in range(3):
        reg.ingest("t", pid, _vals(rng, 128))
    now = [0.0]
    f = Follower(standby, num_buckets=8, staleness_slo=5.0, clock=lambda: now[0])
    f.tail()
    with open(manifest_path(standby)) as fh:
        now[0] = json.load(fh)["wall"]
    # fully caught up: plain eps, not degraded, finite lag attached
    fresh = f.query_many([("t", 0, 3)], 16)[0]
    base_eps = reg.query_many([("t", 0, 3)], 16, strict=False)[0][1]
    assert fresh.eps == base_eps and not fresh.degraded
    assert fresh.lag_seconds == pytest.approx(0.0, abs=1e-6)
    # primary advances, replica does not tail: eps widens by exactly the
    # un-scanned mass and the answer degrades
    reg.ingest("t", 3, _vals(rng, 200))
    stale = f.query_many([("t", 0, 3)], 16)[0]
    assert stale.degraded
    assert stale.eps == pytest.approx(base_eps + 200)
    assert f.drift_by_tenant()["t"] == 200
    # catching up heals it
    f.tail()
    healed = f.query_many([("t", 0, 3)], 16)[0]
    assert not healed.degraded and healed.eps < stale.eps
    # SLO breach degrades even a zero-drift replica
    now[0] += 100.0
    over = f.query_many([("t", 0, 3)], 16)[0]
    assert over.degraded and over.lag_seconds > 5.0
    # no manifest at all: widening is inf — never a guess
    os.remove(manifest_path(standby))
    unknown = f.query_many([("t", 0, 3)], 16)[0]
    assert unknown.degraded and unknown.eps == float("inf")
    assert f.lag()["known"] is False
    f.close()
    repl.close()
    reg.close()


# ------------------------------------------------------- scrub divergence
def test_scrub_divergence_detects_lag_and_corruption(tmp_path):
    reg = _primary(tmp_path)
    standby = str(tmp_path / "standby")
    Replicator(reg._wal, [DirTransport(standby)]).attach(reg)
    rng = np.random.default_rng(13)
    for pid in range(3):
        reg.ingest("t", pid, _vals(rng))
    f = Follower(standby, num_buckets=8)
    f.tail()
    rep = scrub_divergence(reg, f.registry)
    assert rep["ok"] and rep["checked"] == 3 and rep["diverged"] == {}
    # primary ahead: behind, not diverged
    reg._replication = None
    reg._pool.on_durable = None
    reg.ingest("t", 3, _vals(rng))
    rep = scrub_divergence(reg, f.registry)
    assert rep["ok"] and rep["behind"] == {"t": [3]}
    # bit-rot a follower summary: CRC mismatch is real divergence
    s = f.registry["t"].summaries[0]
    rotted = np.array(s.sizes, copy=True)
    rotted[0] += 1.0
    object.__setattr__(s, "sizes", rotted)
    rep = scrub_divergence(reg, f.registry)
    assert not rep["ok"] and rep["diverged"] == {"t": [0]}
    f.close()
    reg.close()
