"""Composed error bound of the hierarchical merge (DESIGN.md §5)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import build_exact, hierarchical_device_summary, merge_list

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


@given(
    st.integers(0, 2**31 - 1),
    st.sampled_from([(512, 64, 128), (1024, 128, 256), (256, 32, 64)]),
)
def test_two_level_bound(seed, dims):
    tile, T_tile, T_dev = dims
    rng = np.random.default_rng(seed)
    n = tile * int(rng.integers(4, 12)) + int(rng.integers(0, tile))
    x = (rng.gumbel(size=n) * rng.uniform(0.5, 5)).astype(np.float32)
    h = hierarchical_device_summary(jnp.asarray(x), tile, T_tile, T_dev)
    k_tiles = -(-n // tile)
    bound = 2 * n * (1 / T_tile + 1 / T_dev) + 2 * (k_tiles + 1)
    err = np.abs(np.asarray(h.sizes) - n / T_dev).max()
    assert err <= bound + 1e-3, (err, bound)


def test_three_level_composition():
    """tile → device → global, each level a paper merge; composed bound."""
    rng = np.random.default_rng(7)
    tile, T_tile, T_dev, T_glob = 512, 128, 256, 64
    n_dev, n_per = 8, 4096
    device_summaries = []
    allv = []
    for _ in range(n_dev):
        x = rng.normal(size=n_per).astype(np.float32)
        allv.append(x)
        device_summaries.append(
            hierarchical_device_summary(jnp.asarray(x), tile, T_tile, T_dev)
        )
    final = merge_list(device_summaries, T_glob)
    n = n_dev * n_per
    k_tiles = n_per // tile
    bound = (
        2 * n * (1 / T_tile + 1 / T_dev + 1 / T_glob)
        + 2 * (n_dev * k_tiles + n_dev)
    )
    err = np.abs(np.asarray(final.sizes) - n / T_glob).max()
    assert err <= bound, (err, bound)
    # and it should be far tighter than the trivial bound n/T_glob
    assert err < n / T_glob


def test_hierarchy_accuracy_improves_with_T():
    rng = np.random.default_rng(11)
    x = rng.gumbel(size=65536).astype(np.float32)
    errs = []
    for T_tile in (32, 128, 512):
        h = hierarchical_device_summary(jnp.asarray(x), 2048, T_tile, 64)
        errs.append(np.abs(np.asarray(h.sizes) - x.size / 64).max())
    assert errs[0] >= errs[1] >= errs[2] - 1e-6
