"""Checkpoint/restart: atomicity, LATEST pointer, elastic restore, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    t = tree()
    save_checkpoint(d, 7, t, {"m": t, "step": jnp.int32(7)})
    assert latest_step(d) == 7
    p, o, step = restore_checkpoint(d, None, t, {"m": t, "step": jnp.int32(0)})
    assert step == 7
    np.testing.assert_allclose(np.asarray(p["a"]), np.asarray(t["a"]))
    assert p["nested"]["b"].dtype == jnp.bfloat16
    assert int(o["step"]) == 7


def test_latest_pointer_advances(tmp_path):
    d = str(tmp_path)
    t = tree()
    save_checkpoint(d, 1, t)
    save_checkpoint(d, 5, t)
    assert latest_step(d) == 5


def test_gc_keeps_newest(tmp_path):
    d = str(tmp_path)
    t = tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, t)
    gc_checkpoints(d, keep=2)
    remaining = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert remaining == ["step_00000004", "step_00000005"]
    assert latest_step(d) == 5


def test_elastic_restore_resharded(tmp_path):
    """Save unsharded, restore onto an explicit (n,1) mesh — elastic."""
    d = str(tmp_path)
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(d, 3, t)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None)
    )} if len(jax.devices()) in (1, 2, 4) else None
    p, _, step = restore_checkpoint(d, None, t, shardings=sh)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(t["w"]))


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), None, tree())


def test_overwrite_same_step(tmp_path):
    d = str(tmp_path)
    t = tree()
    save_checkpoint(d, 2, t)
    t2 = {"a": t["a"] * 2, "nested": t["nested"]}
    save_checkpoint(d, 2, t2)
    p, _, _ = restore_checkpoint(d, 2, t)
    np.testing.assert_allclose(np.asarray(p["a"]), np.asarray(t["a"]) * 2)


def test_save_fsync_discipline(tmp_path, monkeypatch):
    """Regression (static-analysis fsync-order rule): the arrays payload
    is fsynced BEFORE the step-directory rename, and the checkpoint dir
    is fsynced AFTER each publish rename (step dir and LATEST pointer) —
    the atomic_savez contract.  Pre-fix, arrays.npz was never fsynced and
    no rename was followed by a directory fsync, so a crash could publish
    a manifest over torn array data (or lose the rename entirely)."""
    events = []
    real_fsync, real_replace = os.fsync, os.replace

    def recording_fsync(fd):
        try:  # classify what the fd points at (linux: /proc/self/fd)
            target = os.readlink(f"/proc/self/fd/{fd}")
        except OSError:
            target = "?"
        kind = "dir" if os.path.isdir(target) else os.path.basename(target)
        events.append(("fsync", kind))
        return real_fsync(fd)

    def recording_replace(src, dst):
        events.append(("replace", os.path.basename(dst)))
        return real_replace(src, dst)

    monkeypatch.setattr(os, "fsync", recording_fsync)
    monkeypatch.setattr(os, "replace", recording_replace)

    d = str(tmp_path)
    save_checkpoint(d, 3, tree())

    step_pub = events.index(("replace", "step_00000003"))
    latest_pub = events.index(("replace", "LATEST"))
    before_step = [k for op, k in events[:step_pub] if op == "fsync"]
    assert "arrays.npz" in before_step, events
    assert "manifest.json" in before_step, events
    # every publish rename is followed by a directory fsync
    assert ("fsync", "dir") in events[step_pub:latest_pub], events
    assert ("fsync", "dir") in events[latest_pub:], events
    # and the LATEST payload itself was durable before its rename
    latest_fsyncs = [k for op, k in events[step_pub:latest_pub]
                     if op == "fsync"]
    assert any(k not in ("dir",) for k in latest_fsyncs), events
    assert latest_step(d) == 3  # the recorded save still round-trips
