"""Checkpoint/restart: atomicity, LATEST pointer, elastic restore, GC."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    gc_checkpoints,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16)},
    }


def test_roundtrip(tmp_path):
    d = str(tmp_path)
    t = tree()
    save_checkpoint(d, 7, t, {"m": t, "step": jnp.int32(7)})
    assert latest_step(d) == 7
    p, o, step = restore_checkpoint(d, None, t, {"m": t, "step": jnp.int32(0)})
    assert step == 7
    np.testing.assert_allclose(np.asarray(p["a"]), np.asarray(t["a"]))
    assert p["nested"]["b"].dtype == jnp.bfloat16
    assert int(o["step"]) == 7


def test_latest_pointer_advances(tmp_path):
    d = str(tmp_path)
    t = tree()
    save_checkpoint(d, 1, t)
    save_checkpoint(d, 5, t)
    assert latest_step(d) == 5


def test_gc_keeps_newest(tmp_path):
    d = str(tmp_path)
    t = tree()
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, t)
    gc_checkpoints(d, keep=2)
    remaining = sorted(x for x in os.listdir(d) if x.startswith("step_"))
    assert remaining == ["step_00000004", "step_00000005"]
    assert latest_step(d) == 5


def test_elastic_restore_resharded(tmp_path):
    """Save unsharded, restore onto an explicit (n,1) mesh — elastic."""
    d = str(tmp_path)
    t = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(d, 3, t)
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((len(jax.devices()), 1), ("data", "model"))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None)
    )} if len(jax.devices()) in (1, 2, 4) else None
    p, _, step = restore_checkpoint(d, None, t, shardings=sh)
    np.testing.assert_allclose(np.asarray(p["w"]), np.asarray(t["w"]))


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path), None, tree())


def test_overwrite_same_step(tmp_path):
    d = str(tmp_path)
    t = tree()
    save_checkpoint(d, 2, t)
    t2 = {"a": t["a"] * 2, "nested": t["nested"]}
    save_checkpoint(d, 2, t2)
    p, _, _ = restore_checkpoint(d, 2, t)
    np.testing.assert_allclose(np.asarray(p["a"]), np.asarray(t["a"]) * 2)
