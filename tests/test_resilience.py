"""Unit tests for the self-healing primitives (core/resilience.py).

Everything here is deterministic by construction — seeded jitter,
injected clocks, event-driven waits — no test sleeps or depends on
wall-clock timing.
"""
import threading

import pytest

from repro.core.resilience import (
    Answer,
    BreakerPolicy,
    CircuitBreaker,
    RetryPolicy,
    retry_call,
)


# --------------------------------------------------------------- RetryPolicy
class TestRetryPolicy:
    def test_delays_deterministic_for_seed(self):
        p = RetryPolicy(attempts=5, base=0.01, cap=1.0, jitter=0.5, seed=7)
        assert list(p.delays()) == list(p.delays())
        assert list(p.delays()) != list(
            RetryPolicy(attempts=5, seed=8).delays()
        )

    def test_delays_exponential_and_capped(self):
        p = RetryPolicy(attempts=6, base=0.1, cap=0.3, jitter=0.0)
        assert list(p.delays()) == [0.1, 0.2, 0.3, 0.3, 0.3]

    def test_jitter_scales_down_only(self):
        p = RetryPolicy(attempts=50, base=1.0, cap=1.0, jitter=0.25, seed=3)
        for d in p.delays():
            assert 0.75 <= d <= 1.0

    def test_one_attempt_means_no_delays(self):
        assert list(RetryPolicy(attempts=1).delays()) == []


class TestRetryCall:
    def test_returns_first_success(self):
        calls = []
        out = retry_call(
            lambda: calls.append(0) or "ok",
            RetryPolicy(attempts=3),
            wait=lambda d: None,
        )
        assert out == "ok" and len(calls) == 1

    def test_heals_transient_failure(self):
        state = {"n": 0}

        def flaky():
            state["n"] += 1
            if state["n"] < 3:
                raise OSError("transient")
            return state["n"]

        waits = []
        out = retry_call(
            flaky, RetryPolicy(attempts=3, jitter=0.0), wait=waits.append
        )
        assert out == 3 and len(waits) == 2

    def test_reraises_after_budget(self):
        def always():
            raise ValueError("permanent")

        with pytest.raises(ValueError, match="permanent"):
            retry_call(always, RetryPolicy(attempts=3), wait=lambda d: None)

    def test_retryable_veto_skips_retry(self):
        calls = []

        def boom():
            calls.append(0)
            raise KeyError("not retryable")

        with pytest.raises(KeyError):
            retry_call(
                boom,
                RetryPolicy(attempts=5),
                wait=lambda d: None,
                retryable=lambda e: not isinstance(e, KeyError),
            )
        assert len(calls) == 1

    def test_on_retry_counts_attempts(self):
        seen = []

        def always():
            raise OSError("x")

        with pytest.raises(OSError):
            retry_call(
                always,
                RetryPolicy(attempts=4),
                wait=lambda d: None,
                on_retry=lambda attempt, exc: seen.append(attempt),
            )
        assert seen == [1, 2, 3]

    def test_interrupted_wait_still_runs_remaining_attempts(self):
        # an Event.wait-style interruptible wait returning immediately must
        # not cost any of the remaining attempts (close() semantics)
        ev = threading.Event()
        ev.set()
        state = {"n": 0}

        def flaky():
            state["n"] += 1
            if state["n"] < 3:
                raise OSError("transient")
            return "healed"

        out = retry_call(flaky, RetryPolicy(attempts=3), wait=ev.wait)
        assert out == "healed"


# ------------------------------------------------------------ CircuitBreaker
class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self):
        return self.now


class TestCircuitBreaker:
    def make(self, threshold=3, cooldown=30.0, probes=1):
        clock = FakeClock()
        b = CircuitBreaker(
            BreakerPolicy(
                threshold=threshold,
                cooldown=cooldown,
                probes=probes,
                clock=clock,
            )
        )
        return b, clock

    def test_closed_allows_and_failures_trip(self):
        b, _ = self.make(threshold=3)
        assert b.state == "closed"
        for _ in range(2):
            assert b.allow()
            b.record_failure()
        assert b.state == "closed"
        b.record_failure()
        assert b.state == "open" and b.trips == 1

    def test_success_resets_consecutive_count(self):
        b, _ = self.make(threshold=2)
        b.record_failure()
        b.record_success()
        b.record_failure()
        assert b.state == "closed"  # never 2 consecutive

    def test_open_rejects_until_cooldown(self):
        b, clock = self.make(threshold=1, cooldown=10.0)
        b.record_failure()
        assert b.state == "open"
        assert not b.allow()
        clock.now = 9.999
        assert not b.allow()
        clock.now = 10.0
        assert b.allow()  # half-open probe admitted
        assert b.state == "half_open"

    def test_half_open_probe_budget(self):
        b, clock = self.make(threshold=1, cooldown=1.0, probes=1)
        b.record_failure()
        clock.now = 1.0
        assert b.allow()  # the probe
        assert not b.allow()  # probe budget spent

    def test_probe_success_closes(self):
        b, clock = self.make(threshold=1, cooldown=1.0)
        b.record_failure()
        clock.now = 1.0
        assert b.allow()
        b.record_success()
        assert b.state == "closed"
        assert b.allow()

    def test_probe_failure_reopens_for_another_cooldown(self):
        b, clock = self.make(threshold=1, cooldown=5.0)
        b.record_failure()  # open at t=0
        clock.now = 5.0
        assert b.allow()
        b.record_failure()  # probe failed: re-open at t=5
        assert b.state == "open" and b.trips == 2
        clock.now = 9.0
        assert not b.allow()
        clock.now = 10.0
        assert b.allow()

    def test_snapshot_shape(self):
        b, _ = self.make()
        snap = b.snapshot()
        assert snap == {"state": "closed", "failures": 0, "trips": 0}


# -------------------------------------------------------------------- Answer
class TestAnswer:
    def test_unpacks_like_historical_two_tuple(self):
        a = Answer.make("hist", 12.5, degraded=True, stale_version=7)
        h, e = a
        assert h == "hist" and e == 12.5
        assert a[0] == "hist" and len(a) == 2

    def test_degraded_metadata(self):
        a = Answer.make("hist", 1.0, degraded=True, stale_version=3)
        assert a.degraded is True and a.stale_version == 3

    def test_plain_tuple_reads_not_degraded(self):
        # serving code checks `getattr(ans, "degraded", False)`-free:
        # plain Answers default the class attributes
        fresh = Answer(("hist", 1.0))
        assert fresh.degraded is False and fresh.stale_version is None

    def test_equality_with_plain_tuple(self):
        assert Answer.make("h", 2.0, degraded=True) == ("h", 2.0)
