"""Shared node-storage arena (core/arena.py + the arena-backed tree).

Four families of guarantees:

* **slot mechanics** — free-list alloc + geometric growth, write-once
  padded rows, GC-driven reclamation (a dropped handle's row returns to
  the free list; a held handle pins its row against reuse), and the
  machine-checked ``host_row_copies`` counter;
* **bit-equality** — a ``TenantRegistry(shared_arena=True)`` answers every
  ``query_many`` bit-identically to the per-tenant-array layout AND to the
  per-store ``query`` path, property-tested over random ingest/evict/query
  interleavings, uniform + geometric ``T_node``, tiny partitions included
  (the acceptance criterion of the arena PR);
* **zero-copy pack** — the shared-arena gather path serves a cold
  cross-tenant batch with ONE merge dispatch and ZERO host-side row
  copies, and a drained async batch pulls all touched trees up with one
  dispatch per level (not per tenant);
* **persistence** — a shared-arena registry saves its pools once
  (compacted: free-list fragmentation never reaches disk) and reloads
  bit-exact, geometric per-level planes included.
"""
import os

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import HistogramStore, NodeArena, TenantRegistry
from repro.core import interval_tree as it_mod

settings.register_profile("ci", deadline=None, max_examples=10)
settings.load_profile("ci")

T = 16
BETA = 8


def _close(*regs):
    for reg in regs:
        reg.close()


# --------------------------------------------------------------- mechanics
def test_arena_rows_are_padded_write_once_and_reclaimed_on_gc():
    arena = NodeArena()
    b = np.asarray([0.0, 1.0, 3.0], np.float32)
    s = np.asarray([4.0, 2.0], np.float32)
    row = arena.alloc(8, b, s)
    rb, rs = arena.view(8, row)
    np.testing.assert_array_equal(rb[:3], b)
    np.testing.assert_array_equal(rb[3:], np.full(6, 3.0, np.float32))
    np.testing.assert_array_equal(rs[:2], s)
    np.testing.assert_array_equal(rs[2:], np.zeros(6, np.float32))
    assert arena.live_rows() == 1
    assert arena.allocated_floats() == 2 * 8 + 1
    # a handle pins its row; dropping it reclaims the slot at the next alloc
    nd = it_mod.TreeNode(arena, 8, row, 2, 6.0, 0.0, 1)
    del nd
    arena.alloc(8, b, s)
    assert arena.live_rows() == 1  # the freed row was reused


def test_arena_grows_geometrically_and_oversize_rejected():
    arena = NodeArena()
    rows = [
        arena.alloc(4, np.arange(5, dtype=np.float32), np.ones(4, np.float32))
        for _ in range(200)
    ]
    assert len(set(rows)) == 200 and arena.live_rows() == 200
    cap = arena._planes[4].capacity
    assert cap >= 200 and (cap & (cap - 1)) == 0  # pow2 growth steps
    with pytest.raises(ValueError):
        arena.alloc(4, np.arange(9, dtype=np.float32), np.ones(8, np.float32))


def test_alloc_block_pads_rows_narrower_than_the_plane():
    arena = NodeArena()
    b = np.stack([np.arange(5, dtype=np.float32), np.arange(5, dtype=np.float32) + 7])
    s = np.ones((2, 4), np.float32)
    rows = arena.alloc_block(8, b, s)
    for i, row in enumerate(rows):
        rb, rs = arena.view(8, row)
        np.testing.assert_array_equal(rb[:5], b[i])
        np.testing.assert_array_equal(rb[5:], np.full(4, b[i, -1]))
        np.testing.assert_array_equal(rs, np.concatenate([s[i], np.zeros(4)]))


def test_rebase_rebuild_keeps_src_identity_no_double_rebuild():
    """The collapse/rebase (and below-base) rebuilds must carry each
    leaf's src token: losing it made the first query after every
    straddling eviction mark ALL leaves stale and silently rebuild the
    whole tree a second time on the serving path."""
    rng = np.random.default_rng(13)
    store = HistogramStore(num_buckets=T)
    for d in range(8):
        store.ingest(d, rng.normal(size=128).astype(np.float32))
    store.evict([0])  # straddling survivors → rebase-rebuild path
    v = store.version
    it_mod.reset_pullup_stats()
    store.query(1, 7, BETA)
    stats = it_mod.reset_pullup_stats()
    assert stats["pair_merges"] == 0, "query re-rebuilt the tree"
    assert store.version == v
    # and below-base re-ingest (the other rebuild path)
    store.ingest(-3, rng.normal(size=128).astype(np.float32))
    v = store.version
    it_mod.reset_pullup_stats()
    store.query(-3, 7, BETA, strict=False)
    assert it_mod.reset_pullup_stats()["pair_merges"] == 0
    assert store.version == v


def test_export_compacts_and_dedups_shared_rows():
    arena = NodeArena()
    r0 = arena.alloc(4, np.arange(5, dtype=np.float32), np.ones(4, np.float32))
    r1 = arena.alloc(4, np.arange(5, dtype=np.float32) + 9, 2 * np.ones(4, np.float32))
    arrays, slot_map = arena.export([(4, r1), (4, r0), (4, r1)])
    assert arrays["ab_4"].shape == (2, 5) and arrays["as_4"].shape == (2, 4)
    assert slot_map == {(4, r1): 0, (4, r0): 1}
    np.testing.assert_array_equal(arrays["as_4"][0], 2 * np.ones(4, np.float32))


# ------------------------------------------------------------ bit-equality
def _rand_parts(rng, pids, tiny_ok):
    parts = {}
    for pid in pids:
        if tiny_ok and rng.integers(0, 4) == 0:
            n = int(rng.integers(2, T))  # tiny: summarized at T = n
        else:
            n = int(rng.integers(1, 4)) * 64
        parts[int(pid)] = rng.normal(size=n).astype(np.float32)
    return parts


@st.composite
def interleaving(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    t_node = draw(st.sampled_from([None, "geometric"]))
    tiny_ok = draw(st.booleans())
    n_tenants = draw(st.sampled_from([2, 3, 5]))
    n_ops = draw(st.integers(3, 7))
    return seed, t_node, tiny_ok, n_tenants, n_ops


@given(interleaving())
def test_shared_arena_bitexact_vs_per_tenant_arrays(args):
    """THE acceptance property: over random ingest/evict/query
    interleavings, every shared-arena answer is bit-identical to the
    per-tenant layout's, which is itself bit-identical to the per-store
    query path."""
    seed, t_node, tiny_ok, n_tenants, n_ops = args
    rng = np.random.default_rng(seed)
    shared = TenantRegistry(num_buckets=T, T_node=t_node, shared_arena=True)
    legacy = TenantRegistry(num_buckets=T, T_node=t_node)
    names = [f"svc{i}" for i in range(n_tenants)]
    present = {n: set() for n in names}
    for _ in range(n_ops):
        op = rng.integers(0, 3)
        name = names[int(rng.integers(0, n_tenants))]
        if op == 0 or not present[name]:  # ingest a run of partitions
            lo = int(rng.integers(0, 12))
            pids = range(lo, lo + int(rng.integers(1, 5)))
            parts = _rand_parts(rng, pids, tiny_ok)
            shared.ingest_many(name, parts)
            legacy.ingest_many(name, parts)
            present[name].update(parts)
        elif op == 1:  # evict the oldest few
            k = int(rng.integers(1, len(present[name]) + 1))
            victims = sorted(present[name])[:k]
            assert shared[name].evict(victims) == legacy[name].evict(victims)
            present[name] -= set(victims)
        # cross-tenant query batch over random windows (some empty)
        qs = []
        for n in names:
            if not present[n]:
                continue
            ids = sorted(present[n])
            lo = int(rng.integers(ids[0], ids[-1] + 1))
            hi = int(rng.integers(lo, ids[-1] + 1))
            qs.append((n, lo, hi))
        if not qs:
            continue
        ans_s = shared.query_many(qs, BETA, strict=False)
        ans_l = legacy.query_many(qs, BETA, strict=False)
        for (name, lo, hi), (hs, es), (hl, el) in zip(qs, ans_s, ans_l):
            assert (hs is None) == (hl is None)
            if hs is None:
                continue
            np.testing.assert_array_equal(
                np.asarray(hs.boundaries), np.asarray(hl.boundaries)
            )
            np.testing.assert_array_equal(
                np.asarray(hs.sizes), np.asarray(hl.sizes)
            )
            assert es == el
            # and vs the single-store query path (its own pack shape)
            hq, eq = shared[name].query(lo, hi, BETA, strict=False)
            np.testing.assert_array_equal(
                np.asarray(hs.sizes), np.asarray(hq.sizes)
            )
            assert es == eq
    _close(shared, legacy)


# --------------------------------------------------------- zero-copy pack
def test_gather_path_one_dispatch_zero_host_row_copies():
    rng = np.random.default_rng(3)
    reg = TenantRegistry(num_buckets=T, shared_arena=True)
    for ti in range(12):
        reg.ingest_many(
            f"svc{ti}",
            {d: rng.normal(size=256).astype(np.float32) for d in range(6)},
        )
    qs = [(f"svc{ti}", 0, 5) for ti in range(12)]
    reg.query_many(qs, BETA)  # warm compile
    for name in reg.names():
        reg[name]._tree._cache.clear()
    reg.merge_dispatches = 0
    reg.reset_host_row_copies()
    reg.query_many(qs, BETA)
    assert reg.merge_dispatches == 1
    assert reg.host_row_copies == 0
    assert reg.cache_stats()["host_row_copies"] == 0
    # the per-tenant layout pays host copies for the same batch
    legacy = TenantRegistry(num_buckets=T)
    for ti in range(12):
        legacy.ingest_many(
            f"svc{ti}",
            {d: rng.normal(size=256).astype(np.float32) for d in range(6)},
        )
    legacy.reset_host_row_copies()
    legacy.query_many(qs, BETA)
    assert legacy.host_row_copies > 0
    _close(reg, legacy)


def test_async_batch_pulls_up_all_tenants_with_one_dispatch_per_level():
    """Cross-tenant batched pull-ups: a drained multi-tenant batch costs
    one merge dispatch per level (uniform T_node → one shape class), not
    one per tenant per level — and the resulting stores answer
    bit-identically to synchronous per-tenant ingest."""
    rng = np.random.default_rng(4)
    parts = {
        f"svc{ti}": {d: rng.normal(size=128).astype(np.float32) for d in range(8)}
        for ti in range(6)
    }
    sync = TenantRegistry(num_buckets=T, shared_arena=True)
    for name, p in parts.items():
        sync.ingest_many(name, p)
    reg = TenantRegistry(num_buckets=T, shared_arena=True)
    # force ONE drained batch spanning every tenant: enqueue while the
    # worker is blocked behind the first item's summarization is racy, so
    # instead drive the pool callback directly with a known batch
    batch = [
        (name, pid, v) for name, p in parts.items() for pid, v in p.items()
    ]
    it_mod.reset_pullup_stats()
    reg._apply_worker_batch(batch)
    stats = it_mod.reset_pullup_stats()
    # 8 leaves/tenant → 3 levels; one dispatch per level for ALL 6 tenants
    assert stats["dispatches"] == 3, stats
    assert stats["pair_merges"] == 6 * (4 + 2 + 1)
    qs = [(name, 0, 7) for name in parts]
    for (hs, es), (hl, el) in zip(
        reg.query_many(qs, BETA), sync.query_many(qs, BETA)
    ):
        np.testing.assert_array_equal(
            np.asarray(hs.sizes), np.asarray(hl.sizes)
        )
        assert es == el
    _close(reg, sync)


# ------------------------------------------------------------- persistence
@pytest.mark.parametrize("t_node", [None, "geometric"])
def test_shared_arena_registry_roundtrip_bit_exact(tmp_path, t_node):
    """Save/load of a shared-arena registry: pools written once, free-list
    fragmentation compacted away, slots remapped, geometric per-level
    planes preserved — answers bit-exact vs pre-save."""
    rng = np.random.default_rng(5)
    reg = TenantRegistry(num_buckets=T, T_node=t_node, shared_arena=True)
    for ti in range(5):
        reg.ingest_many(
            f"svc{ti}",
            {d: rng.normal(size=200).astype(np.float32) for d in range(9)},
        )
        # fragment the free list: evict then re-ingest a few days
        reg[f"svc{ti}"].evict([0, 1])
        reg.ingest_many(
            f"svc{ti}",
            {d: rng.normal(size=40 + 64 * ti).astype(np.float32) for d in (0, 1)},
        )
    qs = [(f"svc{ti}", lo, hi) for ti in range(5) for lo, hi in [(0, 8), (2, 6), (4, 4)]]
    before = reg.query_many(qs, BETA)
    path = str(tmp_path / "reg.npz")
    reg.save(path)
    with np.load(path, allow_pickle=False) as data:
        pool_keys = [k for k in data.files if k.startswith("arena_ab_")]
        assert pool_keys, "shared pools must be saved once, registry-level"
        # compaction: exported rows == unique live rows across all tenants
        exported = sum(data[k].shape[0] for k in pool_keys)
        live = len(
            {
                (nd.width, nd.row)
                for name in reg.names()
                for nd in reg[name]._tree.nodes.values()
            }
        )
        assert exported == live
        assert not any("tb_" in k for k in data.files)  # no per-node arrays
    loaded = TenantRegistry.load(path)
    assert loaded.arena is not None
    for name in reg.names():
        assert loaded[name]._tree.nodes.keys() == reg[name]._tree.nodes.keys()
        assert loaded[name]._tree.arena is loaded.arena
    after = loaded.query_many(qs, BETA)
    for (hb, eb), (ha, ea) in zip(before, after):
        np.testing.assert_array_equal(
            np.asarray(hb.boundaries), np.asarray(ha.boundaries)
        )
        np.testing.assert_array_equal(
            np.asarray(hb.sizes), np.asarray(ha.sizes)
        )
        assert eb == ea
    # geometric levels keep doubling after reload (plane config survived)
    if t_node == "geometric":
        assert loaded[reg.names()[0]]._tree.node_T(3) == T << 3
    _close(reg, loaded)


def test_standalone_store_roundtrip_uses_arena_layout(tmp_path):
    rng = np.random.default_rng(6)
    store = HistogramStore(num_buckets=T)
    for d in range(7):
        store.ingest(d, rng.normal(size=150).astype(np.float32))
    path = str(tmp_path / "store.npz")
    store.save(path)
    with np.load(path, allow_pickle=False) as data:
        assert any(k.startswith("ab_") for k in data.files)
    loaded = HistogramStore.load(path)
    h0, e0 = store.query(1, 6, BETA)
    h1, e1 = loaded.query(1, 6, BETA)
    np.testing.assert_array_equal(np.asarray(h0.sizes), np.asarray(h1.sizes))
    assert e0 == e1
    assert os.path.exists(path)
