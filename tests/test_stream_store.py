"""HistogramStore — the paper's Summarizer/Merger framework behaviours."""
import numpy as np
import pytest

from repro.core import HistogramStore, build_exact, quantile


def make_store(tmp_path=None, days=10, n=2000, T=256, seed=0):
    rng = np.random.default_rng(seed)
    store = HistogramStore(num_buckets=T)
    all_vals = []
    for d in range(days):
        v = rng.gumbel(loc=d * 0.1, size=n).astype(np.float32)
        store.ingest(d, v)
        all_vals.append(v)
    return store, all_vals


def test_ingest_and_query_interval():
    store, vals = make_store()
    h, eps = store.query(2, 6, beta=64)
    n = 5 * 2000
    assert float(np.asarray(h.sizes).sum()) == n
    assert np.abs(np.asarray(h.sizes) - n / 64).max() <= eps


def test_eps_guarantee_reported():
    store, _ = make_store(T=512)
    # the paper-literal flat Merger reports the single-level Theorem-1 bound
    _, eps_flat = store.query(0, 9, beta=64, engine="flat")
    assert eps_flat == pytest.approx(2 * 20000 / 512 + 2 * 10)
    # the segment-tree Merger reports its composed per-level bound — never
    # tighter than the flat bound, and still honoured by its own answer
    h, eps_tree = store.query(0, 9, beta=64, engine="tree")
    assert eps_tree >= eps_flat
    assert np.abs(np.asarray(h.sizes) - 20000 / 64).max() <= eps_tree


def test_p95_latency_query():
    """The paper's motivating question: p95 over any time interval."""
    store, vals = make_store(days=30, T=512, seed=3)
    got = store.quantile_query(0, 29, 0.95)
    true = np.quantile(np.concatenate(vals), 0.95)
    pooled = np.sort(np.concatenate(vals))
    # rank error bound: 2N/T (+slack) → translate to value tolerance
    r = np.searchsorted(pooled, got)
    assert abs(r - 0.95 * len(pooled)) <= 2 * len(pooled) / 512 + 2 * 30 + 2


def test_missing_partition_strict_raises():
    store, _ = make_store(days=5)
    del store.summaries[2]
    with pytest.raises(KeyError):
        store.query(0, 4, beta=16)


def test_missing_partition_graceful_degradation():
    store, _ = make_store(days=5)
    del store.summaries[2]
    h, eps = store.query(0, 4, beta=16, strict=False)
    assert float(np.asarray(h.sizes).sum()) == 4 * 2000  # 4 of 5 summaries


def test_persistence_roundtrip(tmp_path):
    store, _ = make_store(days=4)
    path = str(tmp_path / "summaries.npz")
    store.save(path)
    loaded = HistogramStore.load(path)
    assert loaded.ids() == store.ids()
    h1, _ = store.query(0, 3, beta=32)
    h2, _ = loaded.query(0, 3, beta=32)
    np.testing.assert_allclose(np.asarray(h1.boundaries), np.asarray(h2.boundaries))
    np.testing.assert_allclose(np.asarray(h1.sizes), np.asarray(h2.sizes))


def test_incremental_ingest_matches_batch():
    """Summaries are per-partition: ingest order must not matter."""
    rng = np.random.default_rng(5)
    vs = [rng.normal(size=500).astype(np.float32) for _ in range(6)]
    s1 = HistogramStore(num_buckets=128)
    for i, v in enumerate(vs):
        s1.ingest(i, v)
    s2 = HistogramStore(num_buckets=128)
    for i in reversed(range(6)):
        s2.ingest(i, vs[i])
    h1, _ = s1.query(0, 5, beta=32)
    h2, _ = s2.query(0, 5, beta=32)
    np.testing.assert_allclose(np.asarray(h1.boundaries), np.asarray(h2.boundaries))


def test_save_load_preserves_store_config(tmp_path):
    """T_node, engine, and cache_size survive the npz round trip — a store
    saved with a custom Merger config must not silently reload defaults."""
    rng = np.random.default_rng(9)
    store = HistogramStore(
        num_buckets=64, engine="flat", T_node=32, cache_size=7
    )
    for d in range(4):
        store.ingest(d, rng.normal(size=500).astype(np.float32))
    path = str(tmp_path / "cfg.npz")
    store.save(path)
    loaded = HistogramStore.load(path)
    assert loaded.engine == "flat"
    assert loaded.T_node == 32
    assert loaded.cache_size == 7
    assert loaded._tree.T_node == 32
    assert loaded._tree._cache_size == 7


def test_save_leaves_no_stray_tempfiles(tmp_path):
    """np.savez's implicit .npz suffix used to orphan the mkstemp file on
    every save — the directory must hold exactly the target afterwards."""
    import os

    store, _ = make_store(days=3, n=200, T=32)
    path = str(tmp_path / "summaries.npz")
    for _ in range(3):  # repeated saves must not accumulate anything
        store.save(path)
    assert sorted(os.listdir(tmp_path)) == ["summaries.npz"]
    loaded = HistogramStore.load(path)
    assert loaded.ids() == store.ids()


def test_ingest_external_summary():
    store = HistogramStore(num_buckets=64)
    v = np.random.default_rng(6).normal(size=1000).astype(np.float32)
    import jax.numpy as jnp

    store.ingest_summary(0, build_exact(jnp.asarray(v), 64))
    h, _ = store.query(0, 0, beta=16)
    assert float(np.asarray(h.sizes).sum()) == 1000
