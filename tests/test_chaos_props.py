"""Chaos property test: randomized fault schedules over the full plane.

Each drawn case runs a random multi-tenant script — sync ingest, async
ingest, dashboard queries, checkpoints, drains — under a *seeded* set of
armed failpoints (disk-full and torn WAL appends, flaky fsyncs, worker
crashes, poisoned applies, failed merge dispatches), then crashes the
process (drops the registry without close) and recovers.  Invariants:

* **zero acked-data loss** — every ingest that returned normally (and
  whose terminal apply failure, if any, was surfaced by drain — the WAL
  guards against crashes, not bad data) is present after recovery;
* **no hangs** — drain()/flush()/close() return under active fault
  schedules (the deterministic close-vs-retry interleaving is pinned
  separately in tests/test_faults.py);
* **honest serving** — under an armed merge failpoint, answers are
  either fresh or flagged ``degraded=True``; every NON-degraded answer
  bit-matches a fault-free replica fed the same partitions;
* **recovery fidelity** — the recovered registry's every partition
  bit-matches a never-faulted replica built from the submitted values;
* **honest pushes** — standing subscriptions (serve/subscriptions.py)
  survive armed ``subs.eval``/``subs.deliver`` failpoints: the delivery
  ledger balances (enqueued = drained + coalesced, i.e. zero
  *uncounted* loss), and once faults disarm every coalesce subscriber's
  final pushed answer is non-degraded, current-version, and bit-matches
  a fault-free replica fed the same partitions;
* **replication** (core/replication.py) — under armed ``repl.ship`` /
  ``repl.tail`` / ``repl.apply`` (plus the WAL faults), every
  non-degraded replica answer bit-matches a fault-free replica fed the
  same partitions, the reported mass-lag bounds the replica's true gap
  to the acked set, and after ``kill -9`` of the primary the promoted
  follower holds every acked record (zero acked loss) with the deposed
  primary fenced.

Runs in the fast lane: few cases, tiny arrays, one jit shape.
"""
import contextlib
import os
import shutil
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import IngestBackpressure, TenantRegistry, faults
from repro.core.replication import DirTransport, Follower, Replicator
from repro.core.resilience import PrimaryFenced
from repro.serve.subscriptions import SubscriptionPlane

settings.register_profile("chaos", deadline=None, max_examples=6)
settings.load_profile("chaos")

T = 8
BETA = 16
N_VALUES = 32  # one shape → one jit compile across all cases


@pytest.fixture(autouse=True)
def _disarm():
    faults.reset()
    yield
    faults.reset()


@st.composite
def chaos_case(draw):
    seed = draw(st.integers(0, 2**31 - 1))
    n_tenants = draw(st.integers(1, 3))
    n_ops = draw(st.integers(8, 14))
    return seed, n_tenants, n_ops


def _arm_faults(stack, seed):
    """Arm the full fault schedule, each failpoint on its own seeded
    probability stream — the same case replays the same schedule for a
    given hit sequence."""
    stack.enter_context(
        faults.inject(
            "wal.append", exc=OSError(28, "ENOSPC"), prob=0.08, seed=seed
        )
    )
    stack.enter_context(
        faults.inject(
            "wal.append.torn",
            action=lambda **ctx: min(9, ctx.get("size", 9)),
            prob=0.06,
            seed=seed + 1,
        )
    )
    stack.enter_context(
        faults.inject(
            "wal.fsync", exc=OSError(5, "EIO"), prob=0.08, seed=seed + 2
        )
    )
    stack.enter_context(
        faults.inject("pool.batch", prob=0.10, seed=seed + 3)
    )
    stack.enter_context(
        faults.inject("tenant.apply", prob=0.08, seed=seed + 4)
    )
    stack.enter_context(
        faults.inject("tenant.merge", prob=0.20, seed=seed + 5)
    )
    stack.enter_context(
        faults.inject("subs.eval", prob=0.15, seed=seed + 6)
    )
    stack.enter_context(
        faults.inject("subs.deliver", prob=0.15, seed=seed + 7)
    )


def _bit_match(reg, ref, tenant, lo, hi):
    [(gh, ge)] = reg.query_many([(tenant, lo, hi)], BETA, strict=False)
    [(wh, we)] = ref.query_many([(tenant, lo, hi)], BETA, strict=False)
    assert (gh is None) == (wh is None)
    if gh is not None:
        assert np.array_equal(
            np.asarray(gh.boundaries), np.asarray(wh.boundaries)
        )
        assert np.array_equal(np.asarray(gh.sizes), np.asarray(wh.sizes))
        assert ge == we


@given(chaos_case())
def test_chaos_no_acked_loss_no_hangs_honest_answers(case):
    seed, n_tenants, n_ops = case
    rng = np.random.default_rng(seed)
    tenants = [f"t{i}" for i in range(n_tenants)]
    base = tempfile.mkdtemp(prefix="chaos-")
    try:
        snap = os.path.join(base, "reg.npz")
        wal_dir = os.path.join(base, "wal")
        reg = TenantRegistry(num_buckets=T, wal_dir=wal_dir)
        plane = SubscriptionPlane(reg)  # every ingest below ticks it
        subs = []  # live standing queries (coalesce/drop — never block)
        drained: dict[int, int] = {}  # id(sub) → updates drained so far
        oracle: dict[tuple[str, int], np.ndarray] = {}  # every submit
        must: set[tuple[str, int]] = set()  # acked → survives the crash
        next_pid = {t: 0 for t in tenants}

        def draw_item():
            t = tenants[int(rng.integers(0, n_tenants))]
            next_pid[t] += int(rng.integers(1, 3))  # gappy monotone pids
            v = rng.normal(size=N_VALUES).astype(np.float32)
            oracle[(t, next_pid[t])] = v
            return t, next_pid[t], v

        with contextlib.ExitStack() as stack:
            _arm_faults(stack, seed)
            for _ in range(n_ops):
                op = rng.integers(0, 13)
                if op < 4:  # sync ingest: ack ⇒ logged + applied
                    t, pid, v = draw_item()
                    try:
                        reg.ingest(t, pid, v)
                        must.add((t, pid))
                    except (faults.FaultError, OSError):
                        pass  # rejected before the ack — caller owns it
                elif op < 7:  # async ingest: ack ⇒ durable (fsynced)
                    t, pid, v = draw_item()
                    try:
                        reg.ingest_async(t, pid, v)
                        must.add((t, pid))
                    except IngestBackpressure:
                        pass  # honest rejection — durability was refused
                elif op < 8:  # drain: terminal apply failures surface here
                    for t, pid, _e in reg._pool.drain():
                        # surfaced ⇒ not silent loss; the WAL guards
                        # against crashes, not bad data
                        must.discard((t, pid))
                elif op < 9:  # checkpoint: snapshot + WAL truncation
                    for t, pid, _e in reg._pool.drain():
                        must.discard((t, pid))
                    reg.save(snap)
                elif op < 10:  # dashboard query mid-chaos: must not raise
                    for t in tenants:
                        if t in reg and reg[t].ids():
                            ids = reg[t].ids()
                            [ans] = reg.query_many(
                                [(t, min(ids), max(ids))],
                                BETA,
                                strict=False,
                                degraded_ok=True,
                            )
                            assert len(ans) == 2  # well-formed either way
                elif op < 11:  # standing query joins mid-chaos
                    t = tenants[int(rng.integers(0, n_tenants))]
                    sub = plane.subscribe(
                        t,
                        0,
                        next_pid[t] + 4,
                        BETA,
                        policy=("coalesce", "drop")[int(rng.integers(0, 2))],
                    )
                    subs.append(sub)
                    drained[id(sub)] = 0
                elif op < 12 and subs:  # and leaves mid-chaos
                    sub = subs.pop(int(rng.integers(0, len(subs))))
                    plane.unsubscribe(sub)  # close FIRST: no more enqueues
                    drained[id(sub)] += len(sub.drain())
                    st = sub.stats()  # the closed endpoint's final ledger
                    assert (
                        drained[id(sub)]
                        == st["delivered"] - st["coalesced"]
                    )
                else:  # dashboard consumers drain under fire
                    for sub in subs:
                        drained[id(sub)] += len(sub.drain())

            # quiesce under the armed schedule: drain must return (no
            # hang) and surfaces every terminal apply failure
            for t, pid, _e in reg._pool.drain():
                must.discard((t, pid))
            reg.flush()  # errors already swapped out: returns clean

            # honest serving: query every tenant with the merge failpoint
            # still armed — each answer must come back fresh or flagged
            # degraded; record the fresh ones for verification below
            observed = []
            for t in tenants:
                if t not in reg or not reg[t].ids():
                    continue
                ids = reg[t].ids()
                [ans] = reg.query_many(
                    [(t, min(ids), max(ids))],
                    BETA,
                    strict=False,
                    degraded_ok=True,
                )
                if not getattr(ans, "degraded", False):
                    observed.append((t, list(ids), ans))
                # degraded answers are flagged honestly; the eps-widening
                # contract is pinned in tests/test_faults.py

        # faults disarmed: every non-degraded answer served under chaos
        # must bit-match a fault-free replica fed the same partitions
        for t, ids, (hist, eps) in observed:
            ref = TenantRegistry(num_buckets=T)
            ref.ingest_many(t, {pid: oracle[(t, pid)] for pid in ids})
            [(wh, we)] = ref.query_many(
                [(t, min(ids), max(ids))], BETA, strict=False
            )
            assert np.array_equal(
                np.asarray(hist.boundaries), np.asarray(wh.boundaries)
            )
            assert np.array_equal(
                np.asarray(hist.sizes), np.asarray(wh.sizes)
            )
            assert eps == we
            ref.close()

        # faults disarmed: one last flush pushes every stale subscriber a
        # fresh answer.  The delivery ledger must balance for every
        # policy, and each coalesce subscriber's final update must be
        # non-degraded, current-version, and bit-match a fault-free
        # replica fed the same window membership.
        plane.flush()
        for sub in subs:
            ups = sub.drain()
            drained[id(sub)] += len(ups)
            st = sub.stats()
            assert drained[id(sub)] == st["delivered"] - st["coalesced"]
            if sub.policy != "coalesce" or not ups:
                continue  # drop loses newest by contract; ledger above
            up = ups[-1]
            assert not up.degraded
            t, lo, hi, _beta = sub.key
            assert up.version == reg[t].version
            members = [p for p in reg[t].ids() if lo <= p <= hi]
            assert (up.hist is None) == (not members)
            if members:
                ref = TenantRegistry(num_buckets=T)
                ref.ingest_many(
                    t, {pid: oracle[(t, pid)] for pid in members}
                )
                [(wh, we)] = ref.query_many(
                    [(t, lo, hi)], BETA, strict=False
                )
                assert np.array_equal(
                    np.asarray(up.hist.boundaries),
                    np.asarray(wh.boundaries),
                )
                assert np.array_equal(
                    np.asarray(up.hist.sizes), np.asarray(wh.sizes)
                )
                assert up.eps == we
                ref.close()
        plane.close()  # the in-memory push plane dies with the process

        # a final acked burst that never gets flushed: recovery must
        # replay it from the log alone
        for _ in range(2):
            t, pid, v = draw_item()
            try:
                reg.ingest_async(t, pid, v)
                must.add((t, pid))
            except IngestBackpressure:
                pass

        del reg  # kill -9: in-memory state gone, snapshot + log survive

        rec = TenantRegistry.recover(
            snap, wal_dir, salvage=True, num_buckets=T
        )
        # zero acked-data loss
        for t, pid in sorted(must):
            assert t in rec, f"acked tenant {t} lost"
            assert pid in rec[t].summaries, f"acked ({t}, {pid}) lost"
        # recovery fidelity: every recovered partition (acked or the
        # harmless durable-but-unacked superset) bit-matches a replica
        # fed the same raw values
        for t in rec.names():
            ids = rec[t].ids()
            assert set(
                (t, pid) for pid in ids
            ) <= set(k for k in oracle if k[0] == t)
            if not ids:
                continue
            ref = TenantRegistry(num_buckets=T)
            ref.ingest_many(t, {pid: oracle[(t, pid)] for pid in ids})
            _bit_match(rec, ref, t, min(ids), max(ids))
            ref.close()
        rec.close()  # must return promptly — no hung close
    finally:
        faults.reset()
        shutil.rmtree(base, ignore_errors=True)


def _arm_repl_faults(stack, seed):
    stack.enter_context(
        faults.inject(
            "wal.append", exc=OSError(28, "ENOSPC"), prob=0.06, seed=seed
        )
    )
    stack.enter_context(
        faults.inject(
            "wal.fsync", exc=OSError(5, "EIO"), prob=0.06, seed=seed + 1
        )
    )
    stack.enter_context(
        faults.inject("repl.ship", prob=0.10, seed=seed + 2)
    )
    stack.enter_context(
        faults.inject("repl.tail", prob=0.15, seed=seed + 3)
    )
    stack.enter_context(
        faults.inject("repl.apply", prob=0.15, seed=seed + 4)
    )


@given(chaos_case())
def test_chaos_replication_bounded_staleness_and_zero_loss_failover(case):
    seed, n_tenants, n_ops = case
    rng = np.random.default_rng(seed)
    tenants = [f"t{i}" for i in range(n_tenants)]
    base = tempfile.mkdtemp(prefix="chaos-repl-")
    try:
        reg = TenantRegistry(
            num_buckets=T, wal_dir=os.path.join(base, "pwal")
        )
        standby = os.path.join(base, "standby")
        repl = Replicator(reg._wal, [DirTransport(standby)]).attach(reg)
        follower = Follower(standby, num_buckets=T)
        oracle: dict[tuple[str, int], np.ndarray] = {}
        must: set[tuple[str, int]] = set()  # acked ⇒ shipped ⇒ survives
        next_pid = {t: 0 for t in tenants}
        observed = []  # non-degraded replica answers served under chaos

        def draw_item():
            t = tenants[int(rng.integers(0, n_tenants))]
            next_pid[t] += int(rng.integers(1, 3))
            v = rng.normal(size=N_VALUES).astype(np.float32)
            oracle[(t, next_pid[t])] = v
            return t, next_pid[t], v

        with contextlib.ExitStack() as stack:
            _arm_repl_faults(stack, seed)
            for _ in range(n_ops):
                op = rng.integers(0, 10)
                if op < 4:  # sync ingest: ack ⇒ durable AND shipped
                    t, pid, v = draw_item()
                    try:
                        reg.ingest(t, pid, v)
                        must.add((t, pid))
                    except (faults.FaultError, OSError):
                        pass  # append OR ship failed: no ack issued
                elif op < 6:  # async ingest: ack ⇒ durable AND shipped
                    t, pid, v = draw_item()
                    try:
                        reg.ingest_async(t, pid, v)
                        must.add((t, pid))
                    except (IngestBackpressure, faults.FaultError):
                        pass
                elif op < 8:  # follower tails under fire
                    try:
                        follower.tail()
                    except faults.FaultError:
                        pass  # no scan state committed (pinned in
                        # tests/test_failpoint_sites.py)
                else:  # replica_query: bounded-staleness serving
                    t = tenants[int(rng.integers(0, n_tenants))]
                    hi = next_pid[t] + 1
                    [ans] = follower.query_many([(t, 0, hi)], BETA)
                    # the reported mass-lag must bound the true gap to
                    # the acked set: every acked record the follower
                    # hasn't applied is un-scanned mass
                    drift = follower.drift_by_tenant()
                    have = (
                        set(follower.registry[t].ids())
                        if t in follower.registry
                        else set()
                    )
                    gap = sum(
                        N_VALUES
                        for (mt, pid) in must
                        if mt == t and pid not in have
                    )
                    if drift is None:
                        assert ans.degraded  # unknown lag: never "fresh"
                    else:
                        assert drift.get(t, 0) >= gap
                        if gap > 0:
                            assert ans.degraded
                    if not ans.degraded:
                        observed.append((t, sorted(have), 0, hi, ans))

        # faults disarmed: every non-degraded replica answer bit-matches
        # a fault-free replica fed the partitions the follower held
        for t, ids, lo, hi, (hist, eps) in observed:
            members = [p for p in ids if lo <= p <= hi]
            ref = TenantRegistry(num_buckets=T)
            if members:
                ref.ingest_many(t, {p: oracle[(t, p)] for p in members})
            [(wh, we)] = ref.query_many([(t, lo, hi)], BETA, strict=False)
            assert (hist is None) == (wh is None)
            if hist is not None:
                assert np.array_equal(
                    np.asarray(hist.boundaries), np.asarray(wh.boundaries)
                )
                assert np.array_equal(
                    np.asarray(hist.sizes), np.asarray(wh.sizes)
                )
                assert eps == we
            ref.close()

        # kill -9 the primary (no close, no checkpoint) and fail over
        old_wal = reg._wal
        fence = repl.fence
        del reg
        promoted = follower.promote(fence=fence)
        # zero acked loss: the promoted follower holds every acked record
        for t, pid in sorted(must):
            assert t in promoted, f"acked tenant {t} lost in failover"
            assert (
                pid in promoted[t].summaries
            ), f"acked ({t}, {pid}) lost in failover"
        # failover fidelity: every promoted partition (acked or the
        # harmless shipped-but-unacked superset) bit-matches a replica
        for t in promoted.names():
            ids = promoted[t].ids()
            assert {(t, pid) for pid in ids} <= set(oracle)
            if not ids:
                continue
            ref = TenantRegistry(num_buckets=T)
            ref.ingest_many(t, {pid: oracle[(t, pid)] for pid in ids})
            _bit_match(promoted, ref, t, min(ids), max(ids))
            ref.close()
        # the deposed primary is fenced at its own log, and the promoted
        # registry ingests at the new epoch
        with pytest.raises(PrimaryFenced):
            old_wal.append(
                "t0", 10**6, np.zeros(N_VALUES, dtype=np.float32)
            )
        t, pid, v = draw_item()
        promoted.ingest(t, pid, v)
        assert pid in promoted[t].summaries
        old_wal.close()
        follower.close()  # closes the promoted registry too
    finally:
        faults.reset()
        shutil.rmtree(base, ignore_errors=True)
