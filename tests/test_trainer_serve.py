"""End-to-end trainer (restart determinism, stragglers) + serving engine."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, smoke
from repro.core.telemetry import StragglerDetector
from repro.models import init_model
from repro.optim import OptimizerConfig
from repro.serve import Engine, ServeConfig
from repro.train.trainer import Trainer, TrainerConfig

pytestmark = pytest.mark.slow  # multi-minute lane; fast lane: -m "not slow"


def make_trainer(tmp_path, steps, arch="smollm-135m", seed=0, resume=True):
    cfg = smoke(get_config(arch))
    # decay_steps must NOT depend on `steps`: the restart-determinism test
    # runs the same schedule to different horizons.
    opt = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=16,
                          clip_mode="global_norm")
    tcfg = TrainerConfig(
        total_steps=steps, log_every=2, checkpoint_every=4,
        checkpoint_dir=str(tmp_path / "ckpt"), seed=seed, resume=resume,
    )
    return Trainer(cfg, opt, tcfg, seq_len=32, global_batch=4)


def test_loss_decreases(tmp_path):
    tr = make_trainer(tmp_path, steps=12)
    losses = []
    tr.run(on_metrics=lambda s, m: losses.append(float(m["loss"])))
    first = float(tr.telemetry.scalars["loss"][0][1])
    last = float(tr.telemetry.scalars["loss"][-1][1])
    assert last < first


def test_restart_is_deterministic(tmp_path):
    # uninterrupted run to 8 steps
    trA = make_trainer(tmp_path / "a", steps=8)
    trA.run()
    lossA = float(trA.telemetry.scalars["loss"][-1][1])
    # interrupted: 4 steps (checkpoint), new Trainer resumes to 8
    trB1 = make_trainer(tmp_path / "b", steps=4)
    trB1.run()
    trB2 = make_trainer(tmp_path / "b", steps=8)
    assert trB2.start_step == 4
    trB2.run()
    lossB = float(trB2.telemetry.scalars["loss"][-1][1])
    assert lossA == pytest.approx(lossB, rel=1e-4), (lossA, lossB)


def test_straggler_detector_flags_slow_host():
    det = StragglerDetector(window=32, T=32, quantile_q=0.5, tolerance=1.3)
    rng = np.random.default_rng(0)
    for step in range(32):
        for host in range(8):
            base = 0.10 + 0.005 * rng.standard_normal()
            det.record(host, base * (3.0 if host == 5 else 1.0))
    flagged, cut = det.flag()
    assert flagged == [5]
    assert 0.1 < cut < 0.35


def test_engine_greedy_deterministic():
    cfg = smoke(get_config("smollm-135m"))
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_seq=48, max_new_tokens=8))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(2, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 12)]
    o1 = eng.generate(prompts)
    o2 = eng.generate(prompts)
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(a, b)
        assert len(a) > len(prompts[0]) - 1  # produced something


def test_engine_generate_ssm_arch():
    cfg = smoke(get_config("rwkv6-7b"))
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig(max_seq=32, max_new_tokens=4))
    outs = eng.generate([np.arange(2, 8, dtype=np.int32)])
    assert len(outs[0]) >= 7


def test_calibration_bound():
    cfg = smoke(get_config("qwen3-8b"))
    params, _ = init_model(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, ServeConfig())
    key = jax.random.PRNGKey(1)
    batches = []
    for i in range(3):
        k = jax.random.fold_in(key, i)
        batches.append({
            "tokens": jax.random.randint(k, (2, 16), 0, cfg.vocab_size),
        })
    out = eng.calibrate(batches, q=0.999, T=256)
    assert out["clip"] > 0
    assert out["int8_scale"] == pytest.approx(out["clip"] / 127.0)
    assert out["rank_error_bound"] == pytest.approx(
        2 * out["n_calibration_values"] / 256
    )


def test_preemption_checkpoint_on_sigterm(tmp_path):
    """SIGTERM mid-run → checkpoint written at the interrupted step, clean
    exit, and a fresh Trainer resumes exactly there (fault tolerance)."""
    import os, signal

    tr = make_trainer(tmp_path, steps=50)
    tr.install_signal_handler()

    def interrupt(step, metrics):
        if step >= 6:
            os.kill(os.getpid(), signal.SIGTERM)

    stopped_at = tr.run(on_metrics=interrupt)
    assert stopped_at < 50  # did not run to completion
    tr2 = make_trainer(tmp_path, steps=50)
    assert tr2.start_step == stopped_at
