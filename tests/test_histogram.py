"""Core histogram unit tests — including the paper's §4 worked example."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Histogram,
    build_exact,
    build_exact_batched,
    boundary_error,
    cdf_interp,
    cdf_left_collapse,
    merge,
    merge_list,
    quantile,
    range_count,
    sample_histogram,
    size_error,
)

P1 = jnp.asarray([2, 4, 5, 6, 7, 10, 13, 16, 18, 20, 21, 25], jnp.float32)
P2 = jnp.asarray(
    [3, 9, 11, 12, 14, 15, 17, 19, 22, 23, 24, 26, 27, 29, 30], jnp.float32
)


def test_build_exact_paper_example():
    h1 = build_exact(P1, 3)
    np.testing.assert_allclose(np.asarray(h1.boundaries), [2, 7, 18, 25])
    np.testing.assert_allclose(np.asarray(h1.sizes), [4, 4, 4])
    h2 = build_exact(P2, 3)
    np.testing.assert_allclose(np.asarray(h2.boundaries), [3, 15, 24, 30])
    np.testing.assert_allclose(np.asarray(h2.sizes), [5, 5, 5])


def test_merge_paper_example():
    """Section 4: H* = {(2,9), (7,9), (18,9), (30,0)}."""
    h = merge_list([build_exact(P1, 3), build_exact(P2, 3)], 3)
    np.testing.assert_allclose(np.asarray(h.boundaries), [2, 7, 18, 30])
    np.testing.assert_allclose(np.asarray(h.sizes), [9, 9, 9])


def test_build_exact_nondivisible():
    v = jnp.arange(10, dtype=jnp.float32)
    h = build_exact(v, 3)
    assert float(h.n) == 10
    sizes = np.asarray(h.sizes)
    assert sizes.min() >= 3 and sizes.max() <= 4


def test_build_exact_batched():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(5, 64)), jnp.float32)
    h = build_exact_batched(x, 8)
    assert h.boundaries.shape == (5, 9)
    assert h.sizes.shape == (5, 8)
    np.testing.assert_allclose(np.asarray(h.sizes).sum(-1), 64)


def test_quantile_and_cdf():
    v = jnp.arange(1000, dtype=jnp.float32)
    h = build_exact(v, 100)
    med = float(quantile(h, 0.5))
    assert abs(med - 499.5) < 20
    c = float(cdf_interp(h, jnp.float32(500.0)))
    assert abs(c - 500) < 20
    clc = float(cdf_left_collapse(h, jnp.float32(500.0)))
    assert abs(clc - 500) <= 2 * 1000 / 100 + 1


def test_range_count():
    v = jnp.asarray(np.random.default_rng(1).uniform(0, 1, 10000), jnp.float32)
    h = build_exact(v, 256)
    cnt = float(range_count(h, jnp.float32(0.25), jnp.float32(0.5)))
    assert abs(cnt - 2500) < 2 * 10000 / 256 + 50


def test_error_metrics_zero_for_exact():
    v = jnp.asarray(np.random.default_rng(2).normal(size=4096), jnp.float32)
    h = build_exact(v, 64)
    assert float(boundary_error(h, h)) == 0.0
    assert float(size_error(h, h)) == 0.0


def test_sample_histogram_includes_edges():
    import jax

    v = jnp.asarray(np.random.default_rng(3).normal(size=5000), jnp.float32)
    h = sample_histogram(v, 16, 256, jax.random.PRNGKey(0))
    assert float(h.boundaries[0]) == float(v.min())
    assert float(h.boundaries[-1]) == float(v.max())
    np.testing.assert_allclose(float(h.n), 5000, rtol=0.02)


def test_merge_list_mixed_T():
    hs = [build_exact(P1, 3), build_exact(P2, 5)]
    h = merge_list(hs, 3)
    assert float(h.n) == 27
    assert np.all(np.diff(np.asarray(h.boundaries)) >= 0)


def test_merge_beta_one():
    h = merge_list([build_exact(P1, 3), build_exact(P2, 3)], 1)
    np.testing.assert_allclose(float(h.sizes[0]), 27)
